"""Universal Scalability Law (USL) model — the analytical core of StreamInsight.

The paper (§IV-A) models streaming-system throughput as

    T(N) = gamma * N / (1 + sigma*(N - 1) + kappa*N*(N - 1))

where
  * ``N``      is the parallelism (number of partitions of the processing system),
  * ``sigma``  is the *contention* coefficient (serial fraction / shared-resource
               queueing — e.g. serialization, shared filesystem bandwidth),
  * ``kappa``  is the *coherence* coefficient (pairwise synchronization cost —
               e.g. all-to-all model-parameter sharing),
  * ``gamma``  is the throughput of a single worker (the paper normalizes
               T(1)=1, i.e. gamma fixed to the single-partition throughput; we
               expose both behaviours).

``sigma = kappa = 0`` is linear scaling; ``kappa = 0`` reduces to Amdahl's law;
``kappa > 0`` produces a throughput *peak* at ``N* = sqrt((1 - sigma)/kappa)``
followed by retrograde scaling — the behaviour the paper observes for
Kafka/Dask on HPC shared filesystems.

Fitting is nonlinear least squares: a coarse log-grid seed followed by a
Levenberg–Marquardt refinement with parameters projected onto the feasible
region (sigma >= 0, kappa >= 0, gamma > 0).  Pure numpy — no scipy/R
dependency (the paper uses the `usl` R package; this is a from-scratch
equivalent validated by property tests).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "usl_throughput",
    "USLFit",
    "fit_usl",
    "r_squared",
    "rmse",
]


def usl_throughput(n, sigma: float, kappa: float, gamma: float = 1.0):
    """Evaluate T(N) for scalar or array ``n``."""
    n = np.asarray(n, dtype=np.float64)
    denom = 1.0 + sigma * (n - 1.0) + kappa * n * (n - 1.0)
    return gamma * n / denom


def r_squared(y_true, y_pred) -> float:
    y_true = np.asarray(y_true, dtype=np.float64)
    y_pred = np.asarray(y_pred, dtype=np.float64)
    ss_res = float(np.sum((y_true - y_pred) ** 2))
    ss_tot = float(np.sum((y_true - np.mean(y_true)) ** 2))
    if ss_tot == 0.0:
        return 1.0 if ss_res == 0.0 else 0.0
    return 1.0 - ss_res / ss_tot


def rmse(y_true, y_pred) -> float:
    y_true = np.asarray(y_true, dtype=np.float64)
    y_pred = np.asarray(y_pred, dtype=np.float64)
    return float(np.sqrt(np.mean((y_true - y_pred) ** 2)))


@dataclass
class USLFit:
    """Result of fitting the USL to (N, T) observations."""

    sigma: float
    kappa: float
    gamma: float
    r2: float
    rmse: float
    n_obs: int
    fixed_gamma: bool = False
    history: list = field(default_factory=list, repr=False)

    def predict(self, n):
        return usl_throughput(n, self.sigma, self.kappa, self.gamma)

    @property
    def peak_n(self) -> float:
        """Parallelism that maximizes T(N); inf if scaling never retrogrades."""
        if self.kappa <= 0.0:
            return math.inf
        return math.sqrt(max(0.0, 1.0 - self.sigma) / self.kappa)

    @property
    def peak_throughput(self) -> float:
        n = self.peak_n
        if math.isinf(n):
            return math.inf
        return float(usl_throughput(max(n, 1.0), self.sigma, self.kappa, self.gamma))

    def efficiency(self, n):
        """Fraction of linear scaling retained at parallelism n."""
        return self.predict(n) / (self.gamma * np.asarray(n, dtype=np.float64))

    def summary(self) -> str:
        peak = self.peak_n
        peak_s = f"{peak:.1f}" if math.isfinite(peak) else "inf"
        return (
            f"USL(sigma={self.sigma:.4f}, kappa={self.kappa:.6f}, "
            f"gamma={self.gamma:.3f}) R2={self.r2:.4f} RMSE={self.rmse:.4g} "
            f"peak_N={peak_s}"
        )


def _solve_gamma(n, t, sigma: float, kappa: float) -> float:
    """Closed-form optimal gamma for fixed (sigma, kappa): linear LSQ."""
    base = usl_throughput(n, sigma, kappa, 1.0)
    denom = float(np.dot(base, base))
    if denom == 0.0:
        return 1.0
    return max(float(np.dot(base, t)) / denom, 1e-12)


def _residuals(params, n, t, fixed_gamma):
    sigma, kappa = params[0], params[1]
    gamma = fixed_gamma if fixed_gamma is not None else params[2]
    return usl_throughput(n, sigma, kappa, gamma) - t


def _jacobian(params, n, fixed_gamma):
    """Analytic Jacobian of T(N; sigma, kappa, gamma) wrt the free params."""
    sigma, kappa = params[0], params[1]
    gamma = fixed_gamma if fixed_gamma is not None else params[2]
    denom = 1.0 + sigma * (n - 1.0) + kappa * n * (n - 1.0)
    t_over_gamma = n / denom
    # dT/dsigma = -gamma * n * (n-1) / denom^2 ; dT/dkappa likewise with n(n-1)
    d_sigma = -gamma * n * (n - 1.0) / (denom**2)
    d_kappa = -gamma * n * n * (n - 1.0) / (denom**2)
    cols = [d_sigma, d_kappa]
    if fixed_gamma is None:
        cols.append(t_over_gamma)
    return np.stack(cols, axis=1)


def fit_usl(
    n,
    t,
    *,
    fix_gamma: bool = False,
    max_iter: int = 200,
    tol: float = 1e-12,
) -> USLFit:
    """Fit the USL to observations.

    Parameters
    ----------
    n : array of parallelism levels (>= 1)
    t : array of measured throughputs (same length)
    fix_gamma : if True, pin gamma to the mean throughput observed at the
        smallest N (the paper's normalization); otherwise gamma is fitted.

    Strategy: coarse log-grid over (sigma, kappa) with closed-form gamma,
    then Levenberg–Marquardt from the best seed, parameters projected to
    sigma >= 0, kappa >= 0 after each accepted step.
    """
    n = np.asarray(n, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    if n.shape != t.shape or n.ndim != 1:
        raise ValueError(f"n and t must be 1-D and same shape, got {n.shape} vs {t.shape}")
    if n.size < 2:
        raise ValueError("need at least 2 observations to fit USL")
    if np.any(n < 1.0):
        raise ValueError("parallelism N must be >= 1")
    if np.any(t < 0.0):
        raise ValueError("throughput must be non-negative")

    fixed_gamma = None
    if fix_gamma:
        n_min = n.min()
        fixed_gamma = float(np.mean(t[n == n_min]) / usl_throughput(n_min, 0.0, 0.0, 1.0))
        fixed_gamma = max(fixed_gamma, 1e-12)

    # --- coarse grid seed -------------------------------------------------
    sigma_grid = np.concatenate([[0.0], np.logspace(-4, 0, 17)])
    kappa_grid = np.concatenate([[0.0], np.logspace(-6, 0, 19)])
    best = None
    for s in sigma_grid:
        for k in kappa_grid:
            g = fixed_gamma if fixed_gamma is not None else _solve_gamma(n, t, s, k)
            res = usl_throughput(n, s, k, g) - t
            sse = float(np.dot(res, res))
            if best is None or sse < best[0]:
                best = (sse, s, k, g)
    _, s0, k0, g0 = best

    # --- Levenberg–Marquardt refinement ----------------------------------
    if fixed_gamma is not None:
        params = np.array([s0, k0], dtype=np.float64)
    else:
        params = np.array([s0, k0, g0], dtype=np.float64)
    lam = 1e-3
    res = _residuals(params, n, t, fixed_gamma)
    sse = float(np.dot(res, res))
    history = [(params.copy(), sse)]
    for _ in range(max_iter):
        jac = _jacobian(params, n, fixed_gamma)
        jtj = jac.T @ jac
        jtr = jac.T @ res
        try:
            step = np.linalg.solve(jtj + lam * np.diag(np.maximum(np.diag(jtj), 1e-12)), -jtr)
        except np.linalg.LinAlgError:
            break
        cand = params + step
        cand[0] = max(cand[0], 0.0)  # sigma >= 0
        cand[1] = max(cand[1], 0.0)  # kappa >= 0
        if fixed_gamma is None:
            cand[2] = max(cand[2], 1e-12)
        cand_res = _residuals(cand, n, t, fixed_gamma)
        cand_sse = float(np.dot(cand_res, cand_res))
        if cand_sse < sse:
            rel = (sse - cand_sse) / max(sse, 1e-30)
            params, res, sse = cand, cand_res, cand_sse
            lam = max(lam / 3.0, 1e-12)
            history.append((params.copy(), sse))
            if rel < tol:
                break
        else:
            lam *= 4.0
            if lam > 1e12:
                break

    sigma, kappa = float(params[0]), float(params[1])
    gamma = float(fixed_gamma if fixed_gamma is not None else params[2])
    pred = usl_throughput(n, sigma, kappa, gamma)
    return USLFit(
        sigma=sigma,
        kappa=kappa,
        gamma=gamma,
        r2=r_squared(t, pred),
        rmse=rmse(t, pred),
        n_obs=int(n.size),
        fixed_gamma=fix_gamma,
        history=history,
    )
