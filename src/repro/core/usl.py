"""Universal Scalability Law (USL) model — the analytical core of StreamInsight.

The paper (§IV-A) models streaming-system throughput as

    T(N) = gamma * N / (1 + sigma*(N - 1) + kappa*N*(N - 1))

where
  * ``N``      is the parallelism (number of partitions of the processing system),
  * ``sigma``  is the *contention* coefficient (serial fraction / shared-resource
               queueing — e.g. serialization, shared filesystem bandwidth),
  * ``kappa``  is the *coherence* coefficient (pairwise synchronization cost —
               e.g. all-to-all model-parameter sharing),
  * ``gamma``  is the throughput of a single worker (the paper normalizes
               T(1)=1, i.e. gamma fixed to the single-partition throughput; we
               expose both behaviours).

``sigma = kappa = 0`` is linear scaling; ``kappa = 0`` reduces to Amdahl's law;
``kappa > 0`` produces a throughput *peak* at ``N* = sqrt((1 - sigma)/kappa)``
followed by retrograde scaling — the behaviour the paper observes for
Kafka/Dask on HPC shared filesystems.

Fitting engine
--------------
The core is **batched**: ``fit_usl_batch(n, t)`` fits S scenarios at once on
stacked ``(S, P)`` observation matrices —

1. a fully vectorized grid seed: one broadcast evaluation of the
   ``(sigma_grid × kappa_grid × S × P)`` tensor (chunked over scenarios to
   bound memory) with the closed-form optimal gamma per grid cell;
2. batched Levenberg–Marquardt: stacked ``(S, 3)`` parameters, batched
   3×3 normal-equation solves (``np.linalg.solve`` on ``(S, 3, 3)`` stacks),
   per-scenario damping, and an active-scenario mask so converged fits stop
   paying for the stragglers' iterations;
3. optional per-observation ``weights`` — a 0/1 mask makes ragged scenario
   groups and train/test splits rectangular, and integer multiplicities make
   bootstrap resamples *just more rows in the batch*, which is how
   ``bootstrap=B`` produces nearly-free percentile confidence intervals for
   (sigma, kappa, peak_N).

``backend="numpy"`` (default, zero-dependency) and ``backend="jax"``
(``jit`` + ``vmap`` over the LM step with ``lax.while_loop`` for the damping
loop; float32 under JAX's default config, intended for very large batches)
share the same seed grids and damping schedule.  Scalar ``fit_usl`` is a thin
S=1 wrapper over the batch path — one code path, identical results.

Pure numpy by default — no scipy/R dependency (the paper uses the `usl` R
package; this is a from-scratch equivalent validated by property tests).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "usl_throughput",
    "USLFit",
    "fit_usl",
    "fit_usl_batch",
    "fit_usl_ragged",
    "r_squared",
    "rmse",
]

# Coarse (sigma, kappa) seed grids.  Flattened sigma-major so np.argmin's
# first-minimum tie-breaking matches the historical scalar loop order.
SIGMA_GRID = np.concatenate([[0.0], np.logspace(-4, 0, 17)])
KAPPA_GRID = np.concatenate([[0.0], np.logspace(-6, 0, 19)])

# Levenberg–Marquardt damping schedule (shared by both backends).
_LAM_INIT = 1e-3
_LAM_MIN = 1e-12
_LAM_MAX = 1e12
_GAMMA_MIN = 1e-12

# Bound on the (G, chunk, P) grid-seed broadcast tensor (elements), so huge
# bootstrap batches never materialize multi-GB intermediates.
_SEED_CHUNK_ELEMS = 8_000_000


def usl_throughput(n, sigma, kappa, gamma=1.0):
    """Evaluate T(N) for scalar or array ``n`` (coefficients broadcast)."""
    n = np.asarray(n, dtype=np.float64)
    denom = 1.0 + sigma * (n - 1.0) + kappa * n * (n - 1.0)
    return gamma * n / denom


def r_squared(y_true, y_pred) -> float:
    y_true = np.asarray(y_true, dtype=np.float64)
    y_pred = np.asarray(y_pred, dtype=np.float64)
    ss_res = float(np.sum((y_true - y_pred) ** 2))
    ss_tot = float(np.sum((y_true - np.mean(y_true)) ** 2))
    if ss_tot == 0.0:
        return 1.0 if ss_res == 0.0 else 0.0
    return 1.0 - ss_res / ss_tot


def rmse(y_true, y_pred) -> float:
    y_true = np.asarray(y_true, dtype=np.float64)
    y_pred = np.asarray(y_pred, dtype=np.float64)
    return float(np.sqrt(np.mean((y_true - y_pred) ** 2)))


def _fmt_ci(ci) -> str:
    lo, hi = ci
    def f(x):
        return "inf" if math.isinf(x) else f"{x:.4g}"
    return f"[{f(float(lo))}, {f(float(hi))}]"


@dataclass
class USLFit:
    """Result of fitting the USL to (N, T) observations.

    ``history`` is opt-in (``keep_history=True``): per-iteration
    ``(params, sse)`` snapshots are dead weight for thousands of batched
    fits, so by default it stays empty.  ``sigma_ci``/``kappa_ci``/
    ``peak_n_ci`` are percentile bootstrap confidence intervals, populated
    when the fit was made with ``bootstrap=B > 0``.
    """

    sigma: float
    kappa: float
    gamma: float
    r2: float
    rmse: float
    n_obs: int
    fixed_gamma: bool = False
    history: list = field(default_factory=list, repr=False)
    sigma_ci: tuple | None = None
    kappa_ci: tuple | None = None
    peak_n_ci: tuple | None = None
    n_bootstrap: int = 0
    ci_level: float = 0.95

    def predict(self, n):
        return usl_throughput(n, self.sigma, self.kappa, self.gamma)

    @property
    def peak_n(self) -> float:
        """Parallelism that maximizes T(N); inf if scaling never retrogrades."""
        if self.kappa <= 0.0:
            return math.inf
        return math.sqrt(max(0.0, 1.0 - self.sigma) / self.kappa)

    @property
    def peak_throughput(self) -> float:
        n = self.peak_n
        if math.isinf(n):
            return math.inf
        return float(usl_throughput(max(n, 1.0), self.sigma, self.kappa, self.gamma))

    def efficiency(self, n):
        """Fraction of linear scaling retained at parallelism n."""
        return self.predict(n) / (self.gamma * np.asarray(n, dtype=np.float64))

    def summary(self) -> str:
        peak = self.peak_n
        peak_s = f"{peak:.1f}" if math.isfinite(peak) else "inf"
        out = (
            f"USL(sigma={self.sigma:.4f}, kappa={self.kappa:.6f}, "
            f"gamma={self.gamma:.3f}) R2={self.r2:.4f} RMSE={self.rmse:.4g} "
            f"peak_N={peak_s}"
        )
        if self.n_bootstrap:
            pct = int(round(self.ci_level * 100))
            out += (
                f" CI{pct}(sigma={_fmt_ci(self.sigma_ci)}, "
                f"kappa={_fmt_ci(self.kappa_ci)}, "
                f"peak_N={_fmt_ci(self.peak_n_ci)}; B={self.n_bootstrap})"
            )
        return out


def _peak_n_arr(sigma, kappa):
    """Batched N* = sqrt((1-sigma)/kappa); inf where kappa <= 0."""
    sigma = np.asarray(sigma, dtype=np.float64)
    kappa = np.asarray(kappa, dtype=np.float64)
    safe = np.where(kappa > 0.0, kappa, 1.0)
    return np.where(kappa > 0.0,
                    np.sqrt(np.maximum(1.0 - sigma, 0.0) / safe), np.inf)


def _usl_batch_eval(n, sigma, kappa, gamma):
    """T(N) for (S, P) ``n`` with per-scenario (S,) coefficients."""
    s = np.asarray(sigma, dtype=np.float64)[:, None]
    k = np.asarray(kappa, dtype=np.float64)[:, None]
    g = np.asarray(gamma, dtype=np.float64)[:, None]
    return g * n / (1.0 + s * (n - 1.0) + k * n * (n - 1.0))


# -- batched numpy backend ----------------------------------------------------

def _grid_seed(n, t, w, fixed_gamma):
    """Vectorized coarse seed: argmin SSE over the whole (sigma, kappa)
    grid at once, with the closed-form weighted-LSQ gamma per cell.  One
    broadcast replaces the historical 360-iteration Python loop; chunked
    over scenarios to bound the (G, chunk, P) intermediate."""
    S, P = t.shape
    ss = np.repeat(SIGMA_GRID, KAPPA_GRID.size)[:, None, None]
    kk = np.tile(KAPPA_GRID, SIGMA_GRID.size)[:, None, None]
    G = ss.shape[0]
    chunk = max(1, _SEED_CHUNK_ELEMS // (G * P))
    params = np.empty((S, 3), dtype=np.float64)
    for lo in range(0, S, chunk):
        hi = min(lo + chunk, S)
        nc, tc, wc = n[lo:hi], t[lo:hi], w[lo:hi]
        denom = 1.0 + ss * (nc - 1.0) + kk * nc * (nc - 1.0)   # (G, C, P)
        base = nc / denom
        if fixed_gamma is not None:
            g = np.broadcast_to(fixed_gamma[lo:hi], (G, hi - lo))
        else:
            num = (wc * base * tc).sum(axis=-1)
            den = (wc * base * base).sum(axis=-1)
            g = np.where(den > 0.0,
                         np.maximum(num / np.where(den > 0.0, den, 1.0),
                                    _GAMMA_MIN),
                         1.0)
        r = g[..., None] * base - tc
        sse = (wc * r * r).sum(axis=-1)                        # (G, C)
        ib = np.argmin(sse, axis=0)
        params[lo:hi, 0] = ss[ib, 0, 0]
        params[lo:hi, 1] = kk[ib, 0, 0]
        params[lo:hi, 2] = g[ib, np.arange(hi - lo)]
    return params


def _fit_batch_numpy(n, t, w, fixed_gamma, max_iter, tol, keep_history,
                     seed_params=None):
    """Batched LM refinement from the vectorized grid seed.

    Per-scenario damping ``lam`` and an ``active`` mask reproduce the
    scalar control flow exactly: each global iteration is one damped step
    *attempt* per still-active scenario (accept → lam/3, reject → lam*4),
    and scenarios leave the batch on convergence, damping blow-up, or a
    singular normal matrix — so converged fits stop paying.

    ``seed_params`` (S, 3) warm-starts LM from a caller-supplied
    (sigma, kappa, gamma) per scenario instead of the grid seed — the
    online re-fitting path starts each refit from the previous fit, so a
    refit pays only the LM polish, not the full grid broadcast.
    """
    S, P = t.shape
    free_gamma = fixed_gamma is None
    if seed_params is None:
        params = _grid_seed(n, t, w, fixed_gamma)
    else:
        params = np.array(seed_params, dtype=np.float64, copy=True)
        params[:, 0] = np.clip(params[:, 0], 0.0, 1.0)
        params[:, 1] = np.maximum(params[:, 1], 0.0)
        params[:, 2] = (np.maximum(params[:, 2], _GAMMA_MIN) if free_gamma
                        else np.asarray(fixed_gamma, dtype=np.float64))
    res = _usl_batch_eval(n, params[:, 0], params[:, 1], params[:, 2]) - t
    sse = (w * res * res).sum(axis=1)
    lam = np.full(S, _LAM_INIT)
    active = np.ones(S, dtype=bool)
    histories = ([[(params[i].copy(), float(sse[i]))] for i in range(S)]
                 if keep_history else None)
    eye = np.eye(3)
    for _ in range(max_iter):
        idx = np.flatnonzero(active)
        if idx.size == 0:
            break
        p = params[idx]
        na, ta, wa, ra = n[idx], t[idx], w[idx], res[idx]
        gam = p[:, 2:3]
        denom = 1.0 + p[:, 0:1] * (na - 1.0) + p[:, 1:2] * na * (na - 1.0)
        inv2 = denom ** -2
        d_sig = -gam * na * (na - 1.0) * inv2
        d_kap = -gam * na * na * (na - 1.0) * inv2
        d_gam = (na / denom) if free_gamma else np.zeros_like(na)
        jac = np.stack([d_sig, d_kap, d_gam], axis=2)          # (A, P, 3)
        wj = wa[:, :, None] * jac
        jtj = np.einsum("apk,apm->akm", wj, jac)
        jtr = np.einsum("apk,ap->ak", wj, ra)
        diag = np.maximum(np.einsum("akk->ak", jtj), 1e-12)
        A = jtj + (lam[idx, None] * diag)[:, :, None] * eye
        singular = np.zeros(idx.size, dtype=bool)
        try:
            step = np.linalg.solve(A, -jtr[..., None])[..., 0]
        except np.linalg.LinAlgError:
            # the stacked solve fails as a whole: redo per scenario and
            # retire only the truly singular ones (scalar path: break)
            step = np.zeros_like(jtr)
            for j in range(idx.size):
                try:
                    step[j] = np.linalg.solve(A[j], -jtr[j][:, None])[:, 0]
                except np.linalg.LinAlgError:
                    singular[j] = True
        cand = p + step
        # sigma is a serial *fraction*: clamp to [0, 1] (an unconstrained
        # LM step on noisy saturated data can wander past 1, which models
        # negative capacity growth from N=1 and breaks peak reasoning)
        cand[:, 0] = np.clip(cand[:, 0], 0.0, 1.0)
        cand[:, 1] = np.maximum(cand[:, 1], 0.0)
        cand[:, 2] = (np.maximum(cand[:, 2], _GAMMA_MIN) if free_gamma
                      else p[:, 2])
        cdenom = 1.0 + cand[:, 0:1] * (na - 1.0) + cand[:, 1:2] * na * (na - 1.0)
        cres = cand[:, 2:3] * na / cdenom - ta
        csse = (wa * cres * cres).sum(axis=1)
        better = ~singular & (csse < sse[idx])
        rel = (sse[idx] - csse) / np.maximum(sse[idx], 1e-30)
        acc = idx[better]
        params[acc] = cand[better]
        res[acc] = cres[better]
        sse[acc] = csse[better]
        lam[acc] = np.maximum(lam[acc] / 3.0, _LAM_MIN)
        lam[idx[~better & ~singular]] *= 4.0
        if histories is not None:
            for i_glob in acc:
                histories[i_glob].append((params[i_glob].copy(),
                                          float(sse[i_glob])))
        done = singular | (better & (rel < tol)) \
            | (~better & ~singular & (lam[idx] > _LAM_MAX))
        active[idx[done]] = False
    gamma = params[:, 2] if free_gamma else np.asarray(fixed_gamma)
    return params[:, 0], params[:, 1], gamma, histories


# -- jax backend --------------------------------------------------------------

_JAX_FIT_CACHE: dict = {}


def _jax_fit_fn(free_gamma: bool, max_iter: int):
    """Build (and cache) the jitted, vmapped per-scenario fit: grid seed +
    an LM damping loop as ``lax.while_loop``.  Compiled once per
    (free_gamma, max_iter, P) — jit handles the shape axis."""
    key = (free_gamma, max_iter)
    if key in _JAX_FIT_CACHE:
        return _JAX_FIT_CACHE[key]
    import jax
    import jax.numpy as jnp
    from jax import lax

    ss = jnp.asarray(np.repeat(SIGMA_GRID, KAPPA_GRID.size))
    kk = jnp.asarray(np.tile(KAPPA_GRID, SIGMA_GRID.size))

    def single(n, t, w, fg, tol):
        denom = 1.0 + ss[:, None] * (n - 1.0) + kk[:, None] * n * (n - 1.0)
        base = n / denom                                       # (G, P)
        if free_gamma:
            num = (w * base * t).sum(-1)
            den = (w * base * base).sum(-1)
            g = jnp.where(den > 0.0,
                          jnp.maximum(num / jnp.where(den > 0.0, den, 1.0),
                                      _GAMMA_MIN),
                          1.0)
        else:
            g = jnp.full(ss.shape, fg)
        r = g[:, None] * base - t
        i0 = jnp.argmin((w * r * r).sum(-1))
        p0 = jnp.stack([ss[i0], kk[i0], g[i0]])

        def model_res(p):
            d = 1.0 + p[0] * (n - 1.0) + p[1] * n * (n - 1.0)
            return p[2] * n / d - t

        def wsse(r):
            return (w * r * r).sum()

        def body(state):
            p, lam, sse, it, done = state
            d = 1.0 + p[0] * (n - 1.0) + p[1] * n * (n - 1.0)
            d_sig = -p[2] * n * (n - 1.0) / d ** 2
            d_kap = -p[2] * n * n * (n - 1.0) / d ** 2
            d_gam = n / d if free_gamma else jnp.zeros_like(n)
            jac = jnp.stack([d_sig, d_kap, d_gam], axis=1)     # (P, 3)
            wj = w[:, None] * jac
            jtj = wj.T @ jac
            jtr = wj.T @ model_res(p)
            diag = jnp.maximum(jnp.diag(jtj), 1e-12)
            step = jnp.linalg.solve(jtj + lam * jnp.diag(diag), -jtr)
            cand = p + step
            cand = cand.at[0].set(jnp.clip(cand[0], 0.0, 1.0))
            cand = cand.at[1].set(jnp.maximum(cand[1], 0.0))
            cand = cand.at[2].set(jnp.maximum(cand[2], _GAMMA_MIN)
                                  if free_gamma else p[2])
            csse = wsse(model_res(cand))
            # a singular solve surfaces as non-finite csse → rejected step
            ok = jnp.isfinite(csse) & (csse < sse)
            rel = (sse - csse) / jnp.maximum(sse, 1e-30)
            p_new = jnp.where(ok, cand, p)
            sse_new = jnp.where(ok, csse, sse)
            lam_new = jnp.where(ok, jnp.maximum(lam / 3.0, _LAM_MIN), lam * 4.0)
            done_new = done | (ok & (rel < tol)) | (~ok & (lam_new > _LAM_MAX))
            return (p_new, lam_new, sse_new, it + 1, done_new)

        def cond(state):
            _p, _lam, _sse, it, done = state
            return (it < max_iter) & (~done)

        state = (p0, jnp.asarray(_LAM_INIT, p0.dtype), wsse(model_res(p0)),
                 0, False)
        p_fin, *_ = lax.while_loop(cond, body, state)
        return p_fin

    fit = jax.jit(jax.vmap(single, in_axes=(0, 0, 0, 0, None)))
    _JAX_FIT_CACHE[key] = fit
    return fit


def _fit_batch_jax(n, t, w, fixed_gamma, max_iter, tol):
    try:
        fit = _jax_fit_fn(fixed_gamma is None, int(max_iter))
    except ImportError as exc:   # pragma: no cover - jax is baked into CI
        raise RuntimeError(
            "fit_usl_batch(backend='jax') requires jax; use the default "
            "backend='numpy' instead") from exc
    fg = fixed_gamma if fixed_gamma is not None else np.zeros(len(t))
    p = np.asarray(fit(n, t, w, fg, tol), dtype=np.float64)
    gamma = (np.asarray(fixed_gamma, dtype=np.float64)
             if fixed_gamma is not None else p[:, 2])
    return p[:, 0], p[:, 1], gamma


def _dispatch_fit(backend, n, t, w, fixed_gamma, max_iter, tol, keep_history,
                  seed_params=None):
    if backend == "numpy":
        return _fit_batch_numpy(n, t, w, fixed_gamma, max_iter, tol,
                                keep_history, seed_params)
    if backend == "jax":
        if seed_params is not None:
            raise ValueError(
                "seed_params warm starts are numpy-only; the jax path "
                "always runs its own grid seed")
        sig, kap, gam = _fit_batch_jax(n, t, w, fixed_gamma, max_iter, tol)
        return sig, kap, gam, None
    raise ValueError(f"unknown backend {backend!r}; expected 'numpy' or 'jax'")


def _bootstrap_cis(backend, n, t, w, fixed_gamma, max_iter, tol,
                   n_boot, seed, ci_level):
    """Percentile bootstrap over observation resamples.  A resample with
    replacement is exactly a multinomial weight vector over the observed
    points, so B resamples of S scenarios are one (B*S, P) weighted batch
    through the same fit core — nearly free next to S scalar refits."""
    S, P = t.shape
    rng = np.random.default_rng(seed)
    wsum = w.sum(axis=1)
    counts = np.maximum(np.rint(wsum).astype(np.int64), 2)
    pvals = w / wsum[:, None]
    wb = rng.multinomial(counts, pvals, size=(n_boot, S))
    wb = wb.astype(np.float64).reshape(n_boot * S, P)
    nb = np.broadcast_to(n, (n_boot, S, P)).reshape(n_boot * S, P)
    tb = np.broadcast_to(t, (n_boot, S, P)).reshape(n_boot * S, P)
    fgb = np.tile(fixed_gamma, n_boot) if fixed_gamma is not None else None
    sig, kap, _gam, _ = _dispatch_fit(backend, nb, tb, wb, fgb,
                                      max_iter, tol, False)
    sig = sig.reshape(n_boot, S)
    kap = kap.reshape(n_boot, S)
    peak = _peak_n_arr(sig, kap)
    q = [(1.0 - ci_level) / 2.0 * 100.0, (1.0 + ci_level) / 2.0 * 100.0]
    out = {}
    for name, arr in (("sigma", sig), ("kappa", kap), ("peak_n", peak)):
        # method="nearest" returns actual samples, so inf peak_N bounds
        # never hit inf-minus-inf interpolation
        lo, hi = np.percentile(arr, q, axis=0, method="nearest")
        out[name] = (lo, hi)
    return out


def fit_usl_batch(
    n,
    t,
    *,
    weights=None,
    fix_gamma: bool = False,
    max_iter: int = 200,
    tol: float = 1e-12,
    backend: str = "numpy",
    keep_history: bool = False,
    bootstrap: int = 0,
    bootstrap_seed: int = 0,
    ci_level: float = 0.95,
    seed_params=None,
) -> list[USLFit]:
    """Fit the USL to S scenarios at once.

    Parameters
    ----------
    n : ``(P,)`` shared parallelism levels or ``(S, P)`` per scenario.
    t : ``(S, P)`` measured throughputs.
    weights : optional ``(S, P)`` non-negative per-observation weights.
        Zeros exclude padded cells (ragged groups, train/test masks);
        integer multiplicities express resampling.  Padded cells may hold
        any values — they are neutralized before validation.
    fix_gamma : pin gamma per scenario to the mean throughput observed at
        that scenario's smallest N (the paper's normalization).
    backend : ``"numpy"`` (default) or ``"jax"`` (jit + vmap LM with a
        ``lax.while_loop`` damping loop; float32 under JAX defaults, meant
        for very large batches; ``history`` is not recorded).
    keep_history : record per-iteration ``(params, sse)`` snapshots on each
        ``USLFit`` (off by default — dead weight for large batches).
    bootstrap : number of bootstrap resamples per scenario (0 = off).
        Populates ``sigma_ci``/``kappa_ci``/``peak_n_ci`` with ``ci_level``
        percentile intervals.
    seed_params : optional ``(S, 3)`` per-scenario (sigma, kappa, gamma)
        warm start.  Skips the grid seed and runs LM from the given point —
        the online re-fitting loop passes its previous fit here so each
        refit costs only the polish iterations (numpy backend only;
        bootstrap resamples still seed from the grid).

    Returns one ``USLFit`` per scenario, in input order.
    """
    t = np.asarray(t, dtype=np.float64)
    if t.ndim != 2:
        raise ValueError(
            f"t must be 2-D (scenarios, observations), got shape {t.shape}")
    S, P = t.shape
    if S == 0:
        return []
    n = np.asarray(n, dtype=np.float64)
    if n.ndim == 1:
        n = np.broadcast_to(n, (S, P))
    if n.shape != t.shape:
        raise ValueError(
            f"n and t must have the same shape, got {n.shape} vs {t.shape}")
    if weights is None:
        w = np.ones((S, P), dtype=np.float64)
    else:
        w = np.asarray(weights, dtype=np.float64)
        if w.shape != t.shape:
            raise ValueError(
                f"weights must match t's shape {t.shape}, got {w.shape}")
        if np.any(w < 0.0):
            raise ValueError("weights must be non-negative")
    valid = w > 0.0
    if np.any(valid.sum(axis=1) < 2):
        raise ValueError("need at least 2 observations to fit USL")
    if np.any(valid & (n < 1.0)):
        raise ValueError("parallelism N must be >= 1")
    if np.any(valid & (t < 0.0)):
        raise ValueError("throughput must be non-negative")
    # neutralize padded cells so they cannot poison the broadcasts
    n = np.where(valid, n, 1.0)
    t = np.where(valid, t, 0.0)

    fixed_gamma = None
    if fix_gamma:
        n_min = np.min(np.where(valid, n, np.inf), axis=1)
        at_min = valid & (n == n_min[:, None])
        wm = w * at_min
        fixed_gamma = (wm * t).sum(axis=1) / wm.sum(axis=1) / n_min
        fixed_gamma = np.maximum(fixed_gamma, _GAMMA_MIN)

    if seed_params is not None:
        seed_params = np.asarray(seed_params, dtype=np.float64)
        if seed_params.shape != (S, 3):
            raise ValueError(
                f"seed_params must have shape ({S}, 3), got {seed_params.shape}")

    sigma, kappa, gamma, histories = _dispatch_fit(
        backend, n, t, w, fixed_gamma, max_iter, tol, keep_history,
        seed_params)

    pred = _usl_batch_eval(n, sigma, kappa, gamma)
    wsum = w.sum(axis=1)
    sse = (w * (pred - t) ** 2).sum(axis=1)
    rmse_v = np.sqrt(sse / wsum)
    tmean = (w * t).sum(axis=1) / wsum
    sst = (w * (t - tmean[:, None]) ** 2).sum(axis=1)
    r2_v = np.where(sst > 0.0, 1.0 - sse / np.where(sst > 0.0, sst, 1.0),
                    np.where(sse == 0.0, 1.0, 0.0))
    n_obs = valid.sum(axis=1)

    cis = None
    if bootstrap:
        cis = _bootstrap_cis(backend, n, t, w, fixed_gamma, max_iter, tol,
                             bootstrap, bootstrap_seed, ci_level)

    fits = []
    for i in range(S):
        fits.append(USLFit(
            sigma=float(sigma[i]),
            kappa=float(kappa[i]),
            gamma=float(gamma[i]),
            r2=float(r2_v[i]),
            rmse=float(rmse_v[i]),
            n_obs=int(n_obs[i]),
            fixed_gamma=fix_gamma,
            history=histories[i] if histories is not None else [],
            sigma_ci=(float(cis["sigma"][0][i]), float(cis["sigma"][1][i]))
            if cis else None,
            kappa_ci=(float(cis["kappa"][0][i]), float(cis["kappa"][1][i]))
            if cis else None,
            peak_n_ci=(float(cis["peak_n"][0][i]), float(cis["peak_n"][1][i]))
            if cis else None,
            n_bootstrap=bootstrap if cis else 0,
            ci_level=ci_level,
        ))
    return fits


def fit_usl_ragged(ns, ts, **kwargs) -> list[USLFit]:
    """Fit scenarios with *different* observation counts in one batch.

    ``ns``/``ts`` are sequences of 1-D arrays; rows are padded to the
    longest scenario and masked out via zero weights, then handed to
    ``fit_usl_batch`` (all keyword options forwarded).
    """
    if len(ns) != len(ts):
        raise ValueError("ns and ts must have the same length")
    S = len(ns)
    if S == 0:
        return []
    P = max(len(a) for a in ns)
    n = np.ones((S, P), dtype=np.float64)
    t = np.zeros((S, P), dtype=np.float64)
    w = np.zeros((S, P), dtype=np.float64)
    for i, (a, b) in enumerate(zip(ns, ts)):
        a = np.asarray(a, dtype=np.float64)
        b = np.asarray(b, dtype=np.float64)
        if a.ndim != 1 or a.shape != b.shape:
            raise ValueError(
                f"scenario {i}: n and t must be 1-D and same shape, "
                f"got {a.shape} vs {b.shape}")
        n[i, :a.size] = a
        t[i, :b.size] = b
        w[i, :a.size] = 1.0
    return fit_usl_batch(n, t, weights=w, **kwargs)


def fit_usl(
    n,
    t,
    *,
    fix_gamma: bool = False,
    max_iter: int = 200,
    tol: float = 1e-12,
    keep_history: bool = False,
    bootstrap: int = 0,
    bootstrap_seed: int = 0,
    backend: str = "numpy",
) -> USLFit:
    """Fit the USL to one scenario's observations.

    Parameters
    ----------
    n : array of parallelism levels (>= 1)
    t : array of measured throughputs (same length)
    fix_gamma : if True, pin gamma to the mean throughput observed at the
        smallest N (the paper's normalization); otherwise gamma is fitted.

    A thin S=1 wrapper over ``fit_usl_batch`` — scalar and batched fits
    share one code path by construction.
    """
    n = np.asarray(n, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    if n.shape != t.shape or n.ndim != 1:
        raise ValueError(
            f"n and t must be 1-D and same shape, got {n.shape} vs {t.shape}")
    if n.size < 2:
        raise ValueError("need at least 2 observations to fit USL")
    return fit_usl_batch(
        n[None, :], t[None, :], fix_gamma=fix_gamma, max_iter=max_iter,
        tol=tol, keep_history=keep_history, bootstrap=bootstrap,
        bootstrap_seed=bootstrap_seed, backend=backend)[0]
