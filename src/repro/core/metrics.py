"""Run-id tracing and metric collection (StreamInsight instrumentation layer).

The paper (§IV): "the framework assigns a unique run id, which is propagated
to all involved components. This way events can be attributed to a specific
benchmark run."  The instrumentation system is modular — collectors can be
added/removed per component (producer, broker, processing engine, pilots).
"""

from __future__ import annotations

import itertools
import threading
import uuid
from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np

__all__ = ["new_run_id", "TraceEvent", "MetricRegistry", "Timer", "percentile_summary"]

_counter = itertools.count()


def new_run_id(prefix: str = "run") -> str:
    """Unique run id propagated through producer → broker → processor."""
    return f"{prefix}-{next(_counter)}-{uuid.uuid4().hex[:8]}"


@dataclass(frozen=True)
class TraceEvent:
    """A single traced event, attributable to a run id.

    ``component`` is e.g. 'producer', 'broker', 'engine', 'pilot'.
    ``kind`` is e.g. 'produce', 'append', 'dispatch', 'complete'.
    Timestamps are in the owning clock's seconds (virtual or wall).
    """

    run_id: str
    component: str
    kind: str
    ts: float
    attrs: dict = field(default_factory=dict)


class MetricRegistry:
    """Thread-safe, modular metric/trace collector.

    Collectors register interest in (component, kind) pairs; every component
    publishes events through a shared registry instance so a benchmark run
    sees a single coherent trace.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: list[TraceEvent] = []
        self._series: dict[str, list[tuple[float, float]]] = defaultdict(list)
        self._counters: dict[str, float] = defaultdict(float)

    # -- events ------------------------------------------------------------
    def emit(self, event: TraceEvent) -> None:
        with self._lock:
            self._events.append(event)

    def record(self, run_id: str, component: str, kind: str, ts: float, **attrs) -> None:
        self.emit(TraceEvent(run_id, component, kind, ts, attrs))

    def events(self, run_id: str | None = None, component: str | None = None,
               kind: str | None = None) -> list[TraceEvent]:
        with self._lock:
            evs = list(self._events)
        if run_id is not None:
            evs = [e for e in evs if e.run_id == run_id]
        if component is not None:
            evs = [e for e in evs if e.component == component]
        if kind is not None:
            evs = [e for e in evs if e.kind == kind]
        return evs

    # -- time series + counters ---------------------------------------------
    def observe(self, name: str, ts: float, value: float) -> None:
        with self._lock:
            self._series[name].append((ts, value))

    def series(self, name: str) -> np.ndarray:
        with self._lock:
            return np.asarray(self._series.get(name, []), dtype=np.float64).reshape(-1, 2)

    def incr(self, name: str, amount: float = 1.0) -> None:
        with self._lock:
            self._counters[name] += amount

    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0.0)

    # -- derived metrics -----------------------------------------------------
    def latencies(self, run_id: str, start_kind: str, end_kind: str,
                  key: str = "msg_id") -> np.ndarray:
        """Per-message latency between two event kinds, joined on attrs[key].

        E.g. L^br = append - produce; L^px = complete - append.
        """
        starts = {e.attrs.get(key): e.ts for e in self.events(run_id=run_id, kind=start_kind)}
        out = []
        for e in self.events(run_id=run_id, kind=end_kind):
            k = e.attrs.get(key)
            if k in starts:
                out.append(e.ts - starts[k])
        return np.asarray(out, dtype=np.float64)

    def throughput(self, run_id: str, kind: str) -> float:
        """Events/sec of a given kind over the run's active window."""
        evs = self.events(run_id=run_id, kind=kind)
        if len(evs) < 2:
            return 0.0
        ts = sorted(e.ts for e in evs)
        span = ts[-1] - ts[0]
        if span <= 0:
            return 0.0
        return (len(evs) - 1) / span


class Timer:
    """Context manager recording wall-clock duration into a registry series."""

    def __init__(self, registry: MetricRegistry, name: str, clock=None) -> None:
        import time

        self.registry = registry
        self.name = name
        self.clock = clock or time.perf_counter
        self.elapsed = 0.0

    def __enter__(self):
        self._t0 = self.clock()
        return self

    def __exit__(self, *exc):
        self.elapsed = self.clock() - self._t0
        self.registry.observe(self.name, self._t0, self.elapsed)
        return False


def percentile_summary(values) -> dict:
    values = np.asarray(values, dtype=np.float64)
    if values.size == 0:
        return {"count": 0}
    return {
        "count": int(values.size),
        "mean": float(values.mean()),
        "std": float(values.std()),
        "p50": float(np.percentile(values, 50)),
        "p95": float(np.percentile(values, 95)),
        "p99": float(np.percentile(values, 99)),
        "min": float(values.min()),
        "max": float(values.max()),
    }
