"""Run-id tracing and metric collection (StreamInsight instrumentation layer).

The paper (§IV): "the framework assigns a unique run id, which is propagated
to all involved components. This way events can be attributed to a specific
benchmark run."  The instrumentation system is modular — collectors can be
added/removed per component (producer, broker, processing engine, pilots).

Storage is *columnar*: events append to per-``(run_id, component, kind)``
columns of ``(ts, attrs)`` rows with interned component/kind strings,
instead of one global list of event objects.  ``record`` is the simulation hot path and is
lock-free — a single C-level ``list.append`` per event, atomic under the
GIL, so the single-threaded simulators pay no lock and the threaded engine
still cannot tear a row (each row is one tuple in one list).  Derived
queries (``latencies``, ``throughput``, ``steady_state_throughput``) read a
column directly and join/aggregate with numpy, instead of copying and
re-filtering the full event list per query.  ``TraceEvent`` objects are
materialized lazily, only when ``events()`` is called.

Pooled experiment sweeps run in worker processes with private registries;
``export_summary`` / ``merge_summary`` are the compact return channel that
carries per-(component, kind) event summaries back into the caller's
registry (see ``streaminsight.run_cells``).
"""

from __future__ import annotations

import itertools
import sys
import threading
import uuid
from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np

__all__ = ["new_run_id", "TraceEvent", "MetricRegistry", "Timer", "percentile_summary"]

_counter = itertools.count()


def new_run_id(prefix: str = "run") -> str:
    """Unique run id propagated through producer → broker → processor."""
    return f"{prefix}-{next(_counter)}-{uuid.uuid4().hex[:8]}"


@dataclass(frozen=True, slots=True)
class TraceEvent:
    """A single traced event, attributable to a run id.

    ``component`` is e.g. 'producer', 'broker', 'engine', 'pilot'.
    ``kind`` is e.g. 'produce', 'append', 'dispatch', 'complete'.
    Timestamps are in the owning clock's seconds (virtual or wall).
    """

    run_id: str
    component: str
    kind: str
    ts: float
    attrs: dict = field(default_factory=dict)


class _Column:
    """Append-only event column for one (run_id, component, kind) triple."""

    __slots__ = ("component", "rows")

    def __init__(self, component: str) -> None:
        self.component = component
        self.rows: list[tuple[float, dict]] = []   # (ts, attrs)


class MetricRegistry:
    """Modular metric/trace collector (columnar storage, see module docs).

    Collectors register interest in (component, kind) pairs; every component
    publishes events through a shared registry instance so a benchmark run
    sees a single coherent trace.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._cols: dict[tuple[str, str, str], _Column] = {}
        self._series: dict[str, list[tuple[float, float]]] = defaultdict(list)
        self._counters: dict[str, float] = defaultdict(float)
        self._merged_summaries: dict[str, dict[str, list]] = {}

    # -- events ------------------------------------------------------------
    def record(self, run_id: str, component: str, kind: str, ts: float, **attrs) -> None:
        """Hot path: one dict lookup + one atomic list append, no lock."""
        col = self._cols.get((run_id, component, kind))
        if col is None:
            # setdefault is atomic; interning keeps key hashing cheap and
            # lets identical kind strings share storage across runs
            col = self._cols.setdefault(
                (sys.intern(run_id), sys.intern(component), sys.intern(kind)),
                _Column(sys.intern(component)))
        col.rows.append((ts, attrs))

    def emit(self, event: TraceEvent) -> None:
        self.record(event.run_id, event.component, event.kind, event.ts,
                    **event.attrs)

    def recorder(self, run_id: str, component: str, kind: str):
        """Pre-resolved emit function for one (run_id, component, kind)
        column.

        Hot emitters (producer, engine) publish hundreds of events per run
        into a column that is fixed for the run's lifetime; binding the
        column append once removes the per-event dict lookup.  The returned
        callable has ``record``'s tail signature: ``rec(ts, **attrs)``."""
        col = self._cols.setdefault(
            (sys.intern(run_id), sys.intern(component), sys.intern(kind)),
            _Column(sys.intern(component)))
        append = col.rows.append

        def rec(ts: float, **attrs) -> None:
            append((ts, attrs))

        return rec

    def events(self, run_id: str | None = None, component: str | None = None,
               kind: str | None = None) -> list[TraceEvent]:
        """Materialize matching events (lazy — only built when asked for)."""
        out = []
        for (rid, comp, knd), col in list(self._cols.items()):
            if run_id is not None and rid != run_id:
                continue
            if kind is not None and knd != kind:
                continue
            if component is not None and comp != component:
                continue
            out.extend(TraceEvent(rid, comp, knd, ts, attrs)
                       for ts, attrs in list(col.rows))
        return out

    def _kind_rows(self, run_id: str, kind: str) -> list[tuple[float, dict]]:
        """All rows of one kind in a run, across components (usually one
        column; multiple components emitting the same kind are merged)."""
        rows: list[tuple[float, dict]] = []
        for (rid, _comp, knd), col in list(self._cols.items()):
            if rid == run_id and knd == kind:
                rows.extend(list(col.rows))
        return rows

    # -- time series + counters ---------------------------------------------
    def observe(self, name: str, ts: float, value: float) -> None:
        with self._lock:
            self._series[name].append((ts, value))

    def series(self, name: str) -> np.ndarray:
        with self._lock:
            return np.asarray(self._series.get(name, []), dtype=np.float64).reshape(-1, 2)

    def incr(self, name: str, amount: float = 1.0) -> None:
        with self._lock:
            self._counters[name] += amount

    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0.0)

    # -- derived metrics -----------------------------------------------------
    def latencies(self, run_id: str, start_kind: str, end_kind: str,
                  key: str = "msg_id") -> np.ndarray:
        """Per-message latency between two event kinds, joined on attrs[key].

        E.g. L^br = append - produce; L^px = complete - append.
        """
        start_rows = self._kind_rows(run_id, start_kind)
        end_rows = self._kind_rows(run_id, end_kind)
        if not start_rows or not end_rows:
            return np.empty(0, dtype=np.float64)
        starts = {attrs.get(key): ts for ts, attrs in start_rows}
        get = starts.get
        out = [ts - s for ts, attrs in end_rows
               if (s := get(attrs.get(key))) is not None]
        return np.asarray(out, dtype=np.float64)

    def kind_count(self, run_id: str, kind: str) -> int:
        """Events of one kind recorded so far — O(columns), not O(events).

        The adaptation control loop computes windowed throughput as the
        delta of this counter between control ticks, so observation cost
        stays independent of trace length."""
        return sum(len(col.rows)
                   for (rid, _comp, knd), col in list(self._cols.items())
                   if rid == run_id and knd == kind)

    def kind_timestamps(self, run_id: str, kind: str) -> np.ndarray:
        """Sorted timestamps of one event kind (the throughput primitive)."""
        rows = self._kind_rows(run_id, kind)
        ts = np.fromiter((t for t, _ in rows), dtype=np.float64, count=len(rows))
        ts.sort()
        return ts

    def throughput(self, run_id: str, kind: str) -> float:
        """Events/sec of a given kind over the run's active window."""
        ts = self.kind_timestamps(run_id, kind)
        if ts.size < 2:
            return 0.0
        span = float(ts[-1] - ts[0])
        if span <= 0:
            return 0.0
        return (ts.size - 1) / span

    def steady_state_throughput(self, run_id: str, kind: str = "complete",
                                warmup_frac: float = 0.25) -> float:
        """Events/sec over the post-warmup window (max sustained throughput)."""
        ts = self.kind_timestamps(run_id, kind)
        if ts.size < 4:
            return 0.0
        window = ts[int(ts.size * warmup_frac):]
        span = float(window[-1] - window[0])
        if span <= 0:
            return 0.0
        return (window.size - 1) / span

    # -- compact cross-process trace channel ---------------------------------
    def export_summary(self) -> dict[str, dict[str, list]]:
        """Compact, picklable per-run trace summary:
        ``{run_id: {"component/kind": [count, t_min, t_max]}}``.

        This is what a pooled sweep worker sends back instead of its full
        event columns (see ``streaminsight.run_cells``).
        """
        out: dict[str, dict[str, list]] = {}
        for (rid, comp, kind), col in list(self._cols.items()):
            rows = list(col.rows)
            if not rows:
                continue
            ts = [t for t, _ in rows]
            out.setdefault(rid, {})[f"{comp}/{kind}"] = [
                len(rows), min(ts), max(ts)]
        return out

    def merge_summary(self, summary: dict[str, dict[str, list]]) -> None:
        """Merge a worker's ``export_summary`` into this registry."""
        with self._lock:
            for rid, kinds in summary.items():
                dst = self._merged_summaries.setdefault(rid, {})
                for ck, (count, t_min, t_max) in kinds.items():
                    if ck in dst:
                        old = dst[ck]
                        dst[ck] = [old[0] + count, min(old[1], t_min),
                                   max(old[2], t_max)]
                    else:
                        dst[ck] = [count, t_min, t_max]

    def trace_summary(self, run_id: str) -> dict[str, list]:
        """Per-(component/kind) ``[count, t_min, t_max]`` for one run —
        computed from local columns for runs traced in-process, or served
        from merged worker summaries for pooled runs."""
        local = self.export_summary().get(run_id)
        if local:
            return local
        with self._lock:
            return dict(self._merged_summaries.get(run_id, {}))

    def run_ids(self) -> list[str]:
        """All run ids this registry knows about (local or merged)."""
        with self._lock:
            merged = set(self._merged_summaries)
        return sorted({key[0] for key in self._cols} | merged)


class Timer:
    """Context manager recording wall-clock duration into a registry series."""

    def __init__(self, registry: MetricRegistry, name: str, clock=None) -> None:
        import time

        self.registry = registry
        self.name = name
        self.clock = clock or time.perf_counter
        self.elapsed = 0.0

    def __enter__(self):
        self._t0 = self.clock()
        return self

    def __exit__(self, *exc):
        self.elapsed = self.clock() - self._t0
        self.registry.observe(self.name, self._t0, self.elapsed)
        return False


def percentile_summary(values) -> dict:
    values = np.asarray(values, dtype=np.float64)
    if values.size == 0:
        return {"count": 0}
    p50, p95, p99 = np.percentile(values, (50, 95, 99))
    return {
        "count": int(values.size),
        "mean": float(values.mean()),
        "std": float(values.std()),
        "p50": float(p50),
        "p95": float(p95),
        "p99": float(p99),
        "min": float(values.min()),
        "max": float(values.max()),
    }
