from repro.core.usl import USLFit, fit_usl, usl_throughput, r_squared, rmse

__all__ = ["USLFit", "fit_usl", "usl_throughput", "r_squared", "rmse"]
