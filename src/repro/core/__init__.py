from repro.core.usl import (USLFit, fit_usl, fit_usl_batch, fit_usl_ragged,
                            usl_throughput, r_squared, rmse)

__all__ = ["USLFit", "fit_usl", "fit_usl_batch", "fit_usl_ragged",
           "usl_throughput", "r_squared", "rmse"]
