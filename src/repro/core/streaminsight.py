"""StreamInsight: end-to-end performance experimentation and modeling.

Supports the paper's workflow (§IV): experimental design (parameter grids
over machine M, parallelism N, message size MS, workload complexity WC,
container memory — plus, beyond the paper, micro-batch size ``batch_max``
and the model-sharing consistency ``policy``), automated execution on the
Streaming Mini-App, USL model fitting per scenario, and model evaluation on
unseen configurations (train/test split, RMSE vs number of training
configurations — Fig 7).

The modeling loop is batched end-to-end: ``fit_models`` stacks every
scenario group into one ``fit_usl_batch`` call (vectorized grid seed +
batched Levenberg–Marquardt; see ``repro.core.usl``), and ``evaluate``
accepts a *list* of training-set sizes, building the full
``(n_train_configs × scenario)`` train-split matrix and fitting it in a
single batch — thousands of scenario models cost one vectorized pass
instead of a Python loop of scalar fits.  ``bootstrap=B`` threads through
to percentile confidence intervals for (sigma, kappa, peak_N), which are
just B more rows in the same batch, and ``backend="jax"`` routes the fits
through the jit+vmap LM path for very large sweeps.

Execution model: every ``StreamExperiment`` cell builds its own
``PilotComputeService`` / ``Simulator`` seeded by ``exp.seed``, so cells are
fully independent — like Pilot-Streaming's independently managed resource
containers, they are embarrassingly parallel.  ``run_cells`` exploits that
with a *persistent* process pool: workers are spawned lazily on the first
pooled sweep and reused across ``run_cells`` calls for the life of the
process, amortizing pool startup the way Pilot-Streaming keeps resource
containers warm across workloads.  Because the seed travels inside the
dataclass, parallel results are bit-identical to serial ones.

``parallel="auto"`` (the default, and what ``parallel=True`` resolves to)
switches between serial and pooled execution on an estimated-work heuristic
(``n_messages × points × centroids`` summed over uncached cells): cheap
grids run serially — on small sweeps pool IPC costs more than the cells —
and only heavy grids fan out, so parallel mode is never a pessimization.
``parallel="force"`` always uses the pool; ``parallel=False`` never does.
Cells are submitted in contiguous chunks (several cells per task) to keep
IPC overhead sublinear in grid size.

Pooled workers collect trace events in private ``MetricRegistry``s; the
summaries inside ``ExperimentResult`` are computed in-worker, so results
are identical either way, and each worker additionally returns a compact
per-(component, kind) event summary that ``run_cells`` merges into the
caller's registry (``MetricRegistry.trace_summary(run_id)``).  Run serially
when you need raw per-event traces; pooled sweeps surface merged summaries.

An optional on-disk ``ResultCache`` keyed by the experiment dataclass makes
re-runs of a sweep free.
"""

from __future__ import annotations

import atexit
import concurrent.futures
import dataclasses
import hashlib
import itertools
import json
import multiprocessing
import os
import threading
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.core.metrics import MetricRegistry
from repro.core.miniapp import ExperimentResult, StreamExperiment, run_experiment
from repro.core.usl import USLFit, fit_usl_batch, fit_usl_ragged, rmse

__all__ = ["ExperimentDesign", "ScenarioModel", "StreamInsight", "ResultCache",
           "run_cells", "estimated_cost", "PARALLEL_COST_THRESHOLD"]

_CACHE_VERSION = 1


@dataclass
class ExperimentDesign:
    """Cartesian experiment grid (the paper's control variables).

    ``batch_max`` and ``policy`` accept either a scalar (one level, the
    seed behaviour) or a list of levels — first-class grid axes, so e.g.
    the three model-sharing policies become directly comparable in one
    design.
    """

    machines: list = field(default_factory=lambda: ["serverless", "wrangler"])
    partitions: list = field(default_factory=lambda: [1, 2, 4, 8, 12, 16])
    points: list = field(default_factory=lambda: [16000])       # MS
    centroids: list = field(default_factory=lambda: [1024])     # WC
    memory_mb: list = field(default_factory=lambda: [3008])
    n_messages: int = 80
    seed: int = 0
    policy: str | list | None = None
    batch_max: int | list = 1

    @staticmethod
    def _levels(axis) -> list:
        return list(axis) if isinstance(axis, (list, tuple)) else [axis]

    def experiments(self) -> list[StreamExperiment]:
        out = []
        for m, n, p, c, mem, pol, bm in itertools.product(
                self.machines, self.partitions, self.points, self.centroids,
                self.memory_mb, self._levels(self.policy),
                self._levels(self.batch_max)):
            out.append(StreamExperiment(
                machine=m, partitions=n, points=p, centroids=c, memory_mb=mem,
                n_messages=self.n_messages, seed=self.seed, policy=pol,
                batch_max=bm))
        return out


# -- cell execution: cache + process pool -------------------------------------

_RESULT_FIELDS = ("run_id", "throughput", "latency_px", "latency_br",
                  "runtime_summary", "processed", "failed", "retried",
                  "wall_virtual_s", "des_events")


class ResultCache:
    """On-disk memo of ``ExperimentResult``s keyed by the experiment
    dataclass (all fields, stable-JSON-hashed), so re-running a sweep only
    pays for cells whose parameters changed."""

    def __init__(self, root: str | Path) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    @staticmethod
    def key(exp: StreamExperiment) -> str:
        payload = json.dumps(dataclasses.asdict(exp), sort_keys=True,
                             default=repr)
        digest = hashlib.sha256(f"v{_CACHE_VERSION}:{payload}".encode())
        return digest.hexdigest()[:24]

    def path(self, exp: StreamExperiment) -> Path:
        return self.root / f"{self.key(exp)}.json"

    def get(self, exp: StreamExperiment) -> ExperimentResult | None:
        path = self.path(exp)
        if not path.exists():
            return None
        try:
            doc = json.loads(path.read_text())
            return ExperimentResult(
                experiment=StreamExperiment(**doc["experiment"]),
                **{k: doc[k] for k in _RESULT_FIELDS})
        except (KeyError, TypeError, ValueError, json.JSONDecodeError):
            return None          # stale/corrupt entry: fall through to a run

    def _tmp_path(self, exp: StreamExperiment) -> Path:
        """Writer-unique staging file: two processes (or threads) sharing a
        cache dir must never clobber each other's in-flight tmp before the
        atomic ``replace``."""
        final = self.path(exp)
        return final.with_name(
            f"{final.name}.{os.getpid()}-{threading.get_ident()}.tmp")

    def put(self, exp: StreamExperiment, res: ExperimentResult) -> None:
        doc = {"experiment": dataclasses.asdict(res.experiment)}
        doc.update({k: getattr(res, k) for k in _RESULT_FIELDS})
        try:
            payload = json.dumps(doc)
        except TypeError:
            return   # non-JSON experiment (e.g. exotic backend_attrs): a
            #          memo that can't round-trip is skipped, never fatal
        tmp = self._tmp_path(exp)
        tmp.write_text(payload)
        tmp.replace(self.path(exp))


def _run_cell_chunk(exps: list[StreamExperiment]) -> list[tuple[ExperimentResult, dict]]:
    """Pool worker: a contiguous chunk of cells, one private registry per
    cell (results are self-contained); each cell also ships back its
    compact trace summary for the caller's registry."""
    out = []
    for exp in exps:
        registry = MetricRegistry()
        res = run_experiment(exp, registry)
        out.append((res, registry.export_summary()))
    return out


def _mp_context():
    """Never fork a potentially JAX-multithreaded parent (fork after jax
    import is a documented deadlock hazard); forkserver forks workers from
    a clean helper process, spawn is the portable fallback."""
    try:
        return multiprocessing.get_context("forkserver")
    except ValueError:
        return multiprocessing.get_context("spawn")


# -- persistent worker pool ---------------------------------------------------
#
# Pool startup on a small container costs ~0.3 s — more than an entire
# light sweep (the exact failure mode the ROADMAP flagged: PR 1's
# per-sweep pool was 27x slower than serial on cheap grids).  The pool is
# created lazily on the first sweep heavy enough to want it and reused for
# the life of the process, like Pilot-Streaming's warm resource containers.

_pool_lock = threading.Lock()
_pool: concurrent.futures.ProcessPoolExecutor | None = None
_pool_workers = 0

# Auto-switch threshold on the summed cell cost estimate
# (n_messages × points × centroids).  Calibrated on the 2-core reference
# container: the perf-smoke sweep (~6e10) runs in ~0.1 s serially — far
# below pool IPC break-even — while grids an order of magnitude heavier
# amortize the warm pool.
PARALLEL_COST_THRESHOLD = 2e11


def estimated_cost(experiments: list[StreamExperiment]) -> float:
    """Work estimate driving the serial-vs-pooled auto-switch."""
    return float(sum(e.n_messages * e.points * e.centroids
                     for e in experiments))


def _get_pool(workers: int) -> concurrent.futures.ProcessPoolExecutor:
    global _pool, _pool_workers
    with _pool_lock:
        if _pool is None or _pool_workers < workers:
            if _pool is not None:
                _pool.shutdown(wait=False, cancel_futures=True)
            _pool = concurrent.futures.ProcessPoolExecutor(
                max_workers=workers, mp_context=_mp_context())
            _pool_workers = workers
        return _pool


def _reset_pool() -> None:
    global _pool, _pool_workers
    with _pool_lock:
        if _pool is not None:
            _pool.shutdown(wait=False, cancel_futures=True)
        _pool = None
        _pool_workers = 0


atexit.register(_reset_pool)


def _use_pool(parallel, pending: list[tuple[int, StreamExperiment]]) -> bool:
    if parallel is False or len(pending) < 2:
        return False
    if parallel == "force":
        return True
    # True and "auto" both auto-switch: pooling a cheap grid would be a
    # pessimization, never a win
    return estimated_cost([exp for _i, exp in pending]) >= PARALLEL_COST_THRESHOLD


def run_cells(experiments: list[StreamExperiment], *,
              metrics: MetricRegistry | None = None,
              parallel: bool | str = "auto",
              max_workers: int | None = None,
              cache: ResultCache | str | Path | None = None,
              on_result=None) -> list[ExperimentResult]:
    """Execute experiment cells via the persistent pool and/or cache.

    ``parallel``: ``"auto"`` (default) and ``True`` pick serial or pooled
    execution from the grid's estimated work; ``"force"`` always pools;
    ``False`` never does.  Results are returned in input order regardless
    of completion order, and are bit-identical between serial and parallel
    execution (each cell's DES is seeded from its own dataclass).
    ``on_result(exp, res)`` is invoked as each cell lands (live progress;
    in pooled mode that is completion order, not input order).  When
    ``metrics`` is given, serial runs trace into it directly and pooled
    runs merge back compact per-cell event summaries
    (``metrics.trace_summary(run_id)``).
    """
    if isinstance(cache, (str, Path)):
        cache = ResultCache(cache)
    notify = on_result or (lambda exp, res: None)
    results: dict[int, ExperimentResult] = {}
    pending: list[tuple[int, StreamExperiment]] = []
    for i, exp in enumerate(experiments):
        hit = cache.get(exp) if cache is not None else None
        if hit is not None:
            results[i] = hit
            notify(exp, hit)
        else:
            pending.append((i, exp))
    if _use_pool(parallel, pending):
        workers = max_workers or min(len(pending), os.cpu_count() or 1)
        # chunked submission: several cells per task bounds IPC round-trips
        # while leaving enough tasks (~4 per worker) for load balancing
        chunk = max(1, len(pending) // (workers * 4))
        chunks = [pending[k:k + chunk] for k in range(0, len(pending), chunk)]
        for attempt in (1, 2):
            pool = _get_pool(workers)
            futures = {pool.submit(_run_cell_chunk, [exp for _i, exp in grp]): grp
                       for grp in chunks}
            try:
                for fut in concurrent.futures.as_completed(futures):
                    grp = futures[fut]
                    for (i, exp), (res, summary) in zip(grp, fut.result()):
                        results[i] = res
                        if metrics is not None:
                            metrics.merge_summary(summary)
                        notify(exp, res)
                break
            except concurrent.futures.process.BrokenProcessPool:
                # a worker died (OOM/kill): restart the pool once and retry
                # only the cells that never landed — completed cells keep
                # their results and are not re-notified; cells are pure so
                # re-running the missing ones is safe
                _reset_pool()
                if attempt == 2:
                    raise
                done = set(results)
                chunks = [[(i, exp) for i, exp in grp if i not in done]
                          for grp in chunks]
                chunks = [grp for grp in chunks if grp]
    else:
        for i, exp in pending:
            results[i] = run_experiment(
                exp, metrics if metrics is not None else MetricRegistry())
            notify(exp, results[i])
    if cache is not None:
        for i, _exp in pending:
            cache.put(_exp, results[i])
    return [results[i] for i in range(len(experiments))]


@dataclass
class ScenarioModel:
    """USL model for one (machine, MS, WC, memory, policy, batch) scenario."""

    key: tuple
    fit: USLFit
    n: np.ndarray
    t: np.ndarray

    def __str__(self) -> str:
        m, p, c, mem, pol, bm = self.key
        return (f"{m:>10} pts={p:<6} c={c:<5} mem={mem:<5} "
                f"policy={str(pol):<16} b={bm:<3} -> {self.fit.summary()}")


class StreamInsight:
    """Run a design, fit USL per scenario, evaluate prediction quality.

    ``parallel`` is forwarded to ``run_cells`` (default ``"auto"``: heavy
    grids fan out over the persistent process pool, cheap ones run
    serially); ``cache_dir`` memoizes finished cells on disk (see
    ``ResultCache``).  Pooled sweeps merge compact per-cell trace
    summaries into ``self.metrics``.
    """

    def __init__(self, metrics: MetricRegistry | None = None,
                 cache_dir: str | Path | None = None,
                 max_workers: int | None = None) -> None:
        self.metrics = metrics or MetricRegistry()
        self.cache = ResultCache(cache_dir) if cache_dir is not None else None
        self.max_workers = max_workers
        self.results: list[ExperimentResult] = []

    # -- execution -----------------------------------------------------------
    def run(self, design: ExperimentDesign, verbose: bool = False,
            parallel: bool | str = "auto") -> list[ExperimentResult]:
        exps = design.experiments()

        def progress(exp, res):
            print(f"  ran {exp.machine} N={exp.partitions} pts={exp.points} "
                  f"c={exp.centroids} mem={exp.memory_mb} "
                  f"policy={exp.effective_policy} b={exp.batch_max} "
                  f"-> T={res.throughput:.3f}", flush=True)

        batch = run_cells(exps, metrics=self.metrics, parallel=parallel,
                          max_workers=self.max_workers, cache=self.cache,
                          on_result=progress if verbose else None)
        self.results.extend(batch)
        return self.results

    def records(self) -> list[dict]:
        return [r.record() for r in self.results]

    # -- modeling --------------------------------------------------------------
    @staticmethod
    def scenario_key(rec: dict) -> tuple:
        return (rec["machine"], rec["points"], rec["centroids"],
                rec["memory_mb"], rec.get("policy"), rec.get("batch_max", 1))

    def _scenario_arrays(self, records: list[dict]) -> list[tuple]:
        """Sorted (key, n, t) triples, one per scenario group."""
        groups: dict[tuple, list[dict]] = {}
        for rec in records:
            groups.setdefault(self.scenario_key(rec), []).append(rec)
        out = []
        for key, recs in sorted(groups.items()):
            n = np.array([r["partitions"] for r in recs], dtype=np.float64)
            t = np.array([r["throughput"] for r in recs], dtype=np.float64)
            out.append((key, n, t))
        return out

    def fit_models(self, records: list[dict] | None = None, *,
                   bootstrap: int = 0, bootstrap_seed: int = 0,
                   backend: str = "numpy") -> list[ScenarioModel]:
        """Fit one USL model per scenario — all scenarios in a single
        batched call (ragged groups are padded and masked).  ``bootstrap=B``
        adds percentile CIs for (sigma, kappa, peak_N) to every fit;
        ``backend="jax"`` routes through the jit+vmap LM path."""
        records = records if records is not None else self.records()
        keys, ns, ts = [], [], []
        for key, n, t in self._scenario_arrays(records):
            if len(np.unique(n)) < 2:
                continue
            keys.append(key)
            ns.append(n)
            ts.append(t)
        fits = fit_usl_ragged(ns, ts, bootstrap=bootstrap,
                              bootstrap_seed=bootstrap_seed, backend=backend)
        return [ScenarioModel(key=k, fit=f, n=n, t=t)
                for k, f, n, t in zip(keys, fits, ns, ts)]

    # -- model evaluation (paper Fig 7) ----------------------------------------
    def evaluate(self, n_train_configs, records: list[dict] | None = None,
                 seed: int = 0, backend: str = "numpy"):
        """Train on ``n_train_configs`` partition levels per scenario, report
        RMSE of throughput predictions on the held-out levels.

        ``n_train_configs`` may be an int (returns one aggregate dict, the
        historical behaviour) or a sequence of ints (returns a list of
        aggregate dicts).  Either way every (training-set size × scenario)
        train split becomes one row of a single ``fit_usl_batch`` call —
        train membership is just a 0/1 weight row — so a full Fig-7 curve
        costs one vectorized fit instead of a double loop of scalar fits.
        Scenarios whose partition grid is too sparse for the requested
        training-set size are skipped, never fatal."""
        records = records if records is not None else self.records()
        multi = isinstance(n_train_configs, (list, tuple, np.ndarray))
        wanted = [int(x) for x in
                  (n_train_configs if multi else [n_train_configs])]
        scenarios = self._scenario_arrays(records)
        jobs = []      # (n_train, key, n, t, train_mask)
        for n_train in wanted:
            # a fresh generator per training-set size keeps the level choice
            # identical to the historical one-size-per-call behaviour
            rng = np.random.default_rng(seed)
            for key, n, t in scenarios:
                levels = np.unique(n)
                if len(levels) <= n_train or n_train < 2:
                    continue
                # anchor the design range (min AND max level), sample the middle
                middle = levels[(levels > levels.min()) & (levels < levels.max())]
                n_mid = max(n_train - 2, 0)
                if n_mid > len(middle):
                    # defensive: with unique levels the earlier size check
                    # already implies enough interior levels; this keeps a
                    # future anchor-selection change from turning a sparse
                    # grid into a rng.choice ValueError mid-sweep
                    continue
                chosen = (rng.choice(middle, size=n_mid, replace=False)
                          if n_mid else np.array([]))
                train_levels = np.concatenate(
                    [[levels.min(), levels.max()], chosen])
                jobs.append((n_train, key, n, t, np.isin(n, train_levels)))
        fits = []
        if jobs:
            width = max(job[2].size for job in jobs)
            n_mat = np.ones((len(jobs), width))
            t_mat = np.zeros((len(jobs), width))
            w_mat = np.zeros((len(jobs), width))
            for i, (_nt, _key, n, t, tr) in enumerate(jobs):
                n_mat[i, :n.size] = n
                t_mat[i, :t.size] = t
                w_mat[i, :n.size] = tr         # held-out levels: weight 0
            fits = fit_usl_batch(n_mat, t_mat, weights=w_mat, backend=backend)
        per_size: dict[int, dict] = {nt: {} for nt in wanted}
        for (n_train, key, n, t, tr), fit in zip(jobs, fits):
            pred = fit.predict(n[~tr])
            err = rmse(t[~tr], pred)
            per_size[n_train][key] = dict(
                rmse=err,
                rel_rmse=err / max(float(np.mean(t[~tr])), 1e-12),
                n_train=int(tr.sum()), n_test=int((~tr).sum()),
                sigma=fit.sigma, kappa=fit.kappa)
        aggs = []
        for n_train in wanted:
            per_scenario = per_size[n_train]
            aggs.append({
                "n_train_configs": n_train,
                "mean_rmse": float(np.mean(
                    [v["rmse"] for v in per_scenario.values()]))
                if per_scenario else float("nan"),
                "mean_rel_rmse": float(np.mean(
                    [v["rel_rmse"] for v in per_scenario.values()]))
                if per_scenario else float("nan"),
                "scenarios": per_scenario,
            })
        return aggs if multi else aggs[0]

    def report(self, *, bootstrap: int = 0, bootstrap_seed: int = 0) -> str:
        """Per-scenario model summaries; ``bootstrap=B`` appends percentile
        confidence intervals for (sigma, kappa, peak_N) to every line."""
        lines = ["StreamInsight scenario models (USL):"]
        for m in self.fit_models(bootstrap=bootstrap,
                                 bootstrap_seed=bootstrap_seed):
            lines.append("  " + str(m))
        return "\n".join(lines)
