"""StreamInsight: end-to-end performance experimentation and modeling.

Supports the paper's workflow (§IV): experimental design (parameter grids
over machine M, parallelism N, message size MS, workload complexity WC,
container memory), automated execution on the Streaming Mini-App, USL model
fitting per scenario, and model evaluation on unseen configurations
(train/test split, RMSE vs number of training configurations — Fig 7).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

from repro.core.metrics import MetricRegistry
from repro.core.miniapp import ExperimentResult, StreamExperiment, run_experiment
from repro.core.usl import USLFit, fit_usl, rmse

__all__ = ["ExperimentDesign", "ScenarioModel", "StreamInsight"]


@dataclass
class ExperimentDesign:
    """Cartesian experiment grid (the paper's control variables)."""

    machines: list = field(default_factory=lambda: ["serverless", "wrangler"])
    partitions: list = field(default_factory=lambda: [1, 2, 4, 8, 12, 16])
    points: list = field(default_factory=lambda: [16000])       # MS
    centroids: list = field(default_factory=lambda: [1024])     # WC
    memory_mb: list = field(default_factory=lambda: [3008])
    n_messages: int = 80
    seed: int = 0
    policy: str | None = None

    def experiments(self) -> list[StreamExperiment]:
        out = []
        for m, n, p, c, mem in itertools.product(
                self.machines, self.partitions, self.points, self.centroids,
                self.memory_mb):
            out.append(StreamExperiment(
                machine=m, partitions=n, points=p, centroids=c, memory_mb=mem,
                n_messages=self.n_messages, seed=self.seed, policy=self.policy))
        return out


@dataclass
class ScenarioModel:
    """USL model for one (machine, MS, WC, memory) scenario."""

    key: tuple
    fit: USLFit
    n: np.ndarray
    t: np.ndarray

    def __str__(self) -> str:
        m, p, c, mem = self.key
        return (f"{m:>10} pts={p:<6} c={c:<5} mem={mem:<5} -> {self.fit.summary()}")


class StreamInsight:
    """Run a design, fit USL per scenario, evaluate prediction quality."""

    def __init__(self, metrics: MetricRegistry | None = None) -> None:
        self.metrics = metrics or MetricRegistry()
        self.results: list[ExperimentResult] = []

    # -- execution -----------------------------------------------------------
    def run(self, design: ExperimentDesign, verbose: bool = False) -> list[ExperimentResult]:
        for exp in design.experiments():
            res = run_experiment(exp, self.metrics)
            self.results.append(res)
            if verbose:
                print(f"  ran {exp.machine} N={exp.partitions} pts={exp.points} "
                      f"c={exp.centroids} mem={exp.memory_mb} -> T={res.throughput:.3f}")
        return self.results

    def records(self) -> list[dict]:
        return [r.record() for r in self.results]

    # -- modeling --------------------------------------------------------------
    @staticmethod
    def scenario_key(rec: dict) -> tuple:
        return (rec["machine"], rec["points"], rec["centroids"], rec["memory_mb"])

    def fit_models(self, records: list[dict] | None = None) -> list[ScenarioModel]:
        records = records if records is not None else self.records()
        groups: dict[tuple, list[dict]] = {}
        for rec in records:
            groups.setdefault(self.scenario_key(rec), []).append(rec)
        models = []
        for key, recs in sorted(groups.items()):
            n = np.array([r["partitions"] for r in recs], dtype=np.float64)
            t = np.array([r["throughput"] for r in recs], dtype=np.float64)
            if len(np.unique(n)) < 2:
                continue
            models.append(ScenarioModel(key=key, fit=fit_usl(n, t), n=n, t=t))
        return models

    # -- model evaluation (paper Fig 7) ----------------------------------------
    def evaluate(self, n_train_configs: int, records: list[dict] | None = None,
                 seed: int = 0) -> dict:
        """Train on ``n_train_configs`` partition levels per scenario, report
        RMSE of throughput predictions on the held-out levels."""
        records = records if records is not None else self.records()
        rng = np.random.default_rng(seed)
        groups: dict[tuple, list[dict]] = {}
        for rec in records:
            groups.setdefault(self.scenario_key(rec), []).append(rec)
        per_scenario = {}
        for key, recs in sorted(groups.items()):
            n = np.array([r["partitions"] for r in recs], dtype=np.float64)
            t = np.array([r["throughput"] for r in recs], dtype=np.float64)
            levels = np.unique(n)
            if len(levels) <= n_train_configs or n_train_configs < 2:
                continue
            # anchor the design range (min AND max level), sample the middle
            middle = levels[(levels > levels.min()) & (levels < levels.max())]
            n_mid = max(n_train_configs - 2, 0)
            chosen = (rng.choice(middle, size=n_mid, replace=False)
                      if n_mid else np.array([]))
            train_levels = np.concatenate([[levels.min(), levels.max()], chosen])
            tr = np.isin(n, train_levels)
            fit = fit_usl(n[tr], t[tr])
            pred = fit.predict(n[~tr])
            per_scenario[key] = dict(
                rmse=rmse(t[~tr], pred),
                rel_rmse=rmse(t[~tr], pred) / max(float(np.mean(t[~tr])), 1e-12),
                n_train=int(tr.sum()), n_test=int((~tr).sum()),
                sigma=fit.sigma, kappa=fit.kappa)
        agg = {
            "n_train_configs": n_train_configs,
            "mean_rmse": float(np.mean([v["rmse"] for v in per_scenario.values()]))
            if per_scenario else float("nan"),
            "mean_rel_rmse": float(np.mean([v["rel_rmse"] for v in per_scenario.values()]))
            if per_scenario else float("nan"),
            "scenarios": per_scenario,
        }
        return agg

    def report(self) -> str:
        lines = ["StreamInsight scenario models (USL):"]
        for m in self.fit_models():
            lines.append("  " + str(m))
        return "\n".join(lines)
