"""StreamInsight: end-to-end performance experimentation and modeling.

Supports the paper's workflow (§IV): experimental design (parameter grids
over machine M, parallelism N, message size MS, workload complexity WC,
container memory — plus, beyond the paper, micro-batch size ``batch_max``
and the model-sharing consistency ``policy``), automated execution on the
Streaming Mini-App, USL model fitting per scenario, and model evaluation on
unseen configurations (train/test split, RMSE vs number of training
configurations — Fig 7).

The modeling loop is batched end-to-end: ``fit_models`` stacks every
scenario group into one ``fit_usl_batch`` call (vectorized grid seed +
batched Levenberg–Marquardt; see ``repro.core.usl``), and ``evaluate``
accepts a *list* of training-set sizes, building the full
``(n_train_configs × scenario)`` train-split matrix and fitting it in a
single batch — thousands of scenario models cost one vectorized pass
instead of a Python loop of scalar fits.  ``bootstrap=B`` threads through
to percentile confidence intervals for (sigma, kappa, peak_N), which are
just B more rows in the same batch, and ``backend="jax"`` routes the fits
through the jit+vmap LM path for very large sweeps.

Execution model: every ``StreamExperiment`` cell builds its own
``PilotComputeService`` / ``Simulator`` seeded by ``exp.seed``, so cells are
fully independent — like Pilot-Streaming's independently managed resource
containers, they are embarrassingly parallel.  ``run_cells`` exploits that
with a *persistent* process pool: workers are spawned lazily on the first
pooled sweep and reused across ``run_cells`` calls for the life of the
process, amortizing pool startup the way Pilot-Streaming keeps resource
containers warm across workloads.  Because the seed travels inside the
dataclass, parallel results are bit-identical to serial ones.

``parallel="auto"`` (the default, and what ``parallel=True`` resolves to)
switches between serial and pooled execution on an estimated-work heuristic
(``n_messages × points × centroids`` summed over uncached cells): cheap
grids run serially — on small sweeps pool IPC costs more than the cells —
and only heavy grids fan out, so parallel mode is never a pessimization.
``parallel="force"`` always uses the pool; ``parallel=False`` never does.
Cells are submitted in contiguous chunks (several cells per task) to keep
IPC overhead sublinear in grid size.

Pooled workers collect trace events in private ``MetricRegistry``s; the
summaries inside ``ExperimentResult`` are computed in-worker, so results
are identical either way, and each worker additionally returns a compact
per-(component, kind) event summary that ``run_cells`` merges into the
caller's registry (``MetricRegistry.trace_summary(run_id)``).  Run serially
when you need raw per-event traces; pooled sweeps surface merged summaries.

An optional on-disk ``ResultCache`` keyed by the experiment dataclass makes
re-runs of a sweep free.

Beyond the paper's characterize-then-model workflow, StreamInsight closes
the EILC loop (§V future work): ``AdaptationDesign`` /
``StreamInsight.run_adaptation`` execute *adaptation cells*
(``AdaptationExperiment``: a time-varying rate trace in → allocation trace,
lag trace, SLO-violation count and cost integral out) where a live
``ControlLoop`` resizes the elastic backends mid-run.  Predictive cells are
parameterized automatically from the USL models fitted on this insight's
own characterization sweep, so ``run(design)`` →
``run_adaptation(adaptation_design)`` is the paper's full characterize →
model → adapt pipeline in two calls.  Adaptation cells ride the same
``run_cells`` pool, auto-switch and typed ``ResultCache`` as
characterization cells.
"""

from __future__ import annotations

import atexit
import concurrent.futures
import dataclasses
import hashlib
import itertools
import json
import multiprocessing
import os
import threading
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any

import numpy as np

from repro.core.metrics import MetricRegistry
from repro.core.miniapp import (AdaptationExperiment, AdaptationPlan,
                                AdaptationResult, AdaptationSummary,
                                ExperimentResult, StreamExperiment,
                                default_consistency, run_adaptation,
                                run_experiment, run_plan)
from repro.core.usl import USLFit, fit_usl_batch, fit_usl_ragged, rmse

__all__ = ["ExperimentDesign", "AdaptationDesign", "ScenarioModel",
           "StreamInsight", "ResultCache", "run_cells", "estimated_cost",
           "cache_key", "CACHE_SCHEMA_VERSION", "PARALLEL_COST_THRESHOLD"]

# One constant, bumped once per on-disk schema change (v2: adaptation
# cells; v3: fault ledger; v5: federation member ledger + tick-error ring;
# v6: what-if plan summaries).  Every cache key derives from it through
# ``cache_key`` below — bumping it invalidates the whole memo at once.
CACHE_SCHEMA_VERSION = 6


@dataclass
class ExperimentDesign:
    """Cartesian experiment grid (the paper's control variables).

    ``batch_max`` and ``policy`` accept either a scalar (one level, the
    seed behaviour) or a list of levels — first-class grid axes, so e.g.
    the three model-sharing policies become directly comparable in one
    design.
    """

    machines: list = field(default_factory=lambda: ["serverless", "wrangler"])
    partitions: list = field(default_factory=lambda: [1, 2, 4, 8, 12, 16])
    points: list = field(default_factory=lambda: [16000])       # MS
    centroids: list = field(default_factory=lambda: [1024])     # WC
    memory_mb: list = field(default_factory=lambda: [3008])
    n_messages: int = 80
    seed: int = 0
    policy: str | list | None = None
    batch_max: int | list = 1

    @staticmethod
    def _levels(axis) -> list:
        return list(axis) if isinstance(axis, (list, tuple)) else [axis]

    def experiments(self) -> list[StreamExperiment]:
        out = []
        for m, n, p, c, mem, pol, bm in itertools.product(
                self.machines, self.partitions, self.points, self.centroids,
                self.memory_mb, self._levels(self.policy),
                self._levels(self.batch_max)):
            out.append(StreamExperiment(
                machine=m, partitions=n, points=p, centroids=c, memory_mb=mem,
                n_messages=self.n_messages, seed=self.seed, policy=pol,
                batch_max=bm))
        return out


@dataclass
class AdaptationDesign:
    """Grid of closed-loop adaptation cells (the EILC design space).

    The cartesian axes are machine × scaling policy × rate trace; the
    workload/SLO knobs are shared.  ``experiments(usl_params=...)`` fills
    each machine's fitted USL coefficients into the predictive cells —
    ``StreamInsight.run_adaptation`` does that automatically from the
    models it fitted on the characterization sweep (characterize → model →
    adapt, end to end).
    """

    machines: list = field(default_factory=lambda: ["serverless", "wrangler"])
    scaling_policies: list = field(
        default_factory=lambda: ["usl", "reactive", "static"])
    rates: list = field(default_factory=lambda: [
        dict(kind="step", base_hz=2.0, high_hz=12.0, t_step=40.0)])
    horizon_s: float = 120.0
    initial_partitions: int = 2
    max_partitions: int = 16
    static_partitions: int | None = None
    control_interval_s: float = 2.0
    slo_lag: int = 32
    migration_s_per_delta: float = 0.05
    points: int = 8000
    centroids: int = 1024
    memory_mb: int = 3008
    policy: str | None = None      # model-sharing consistency
    batch_max: int = 1
    seed: int = 0
    engine: str = "sim"            # sim | threaded (wall clock)
    drift_t_s: float | None = None  # mid-run per-message cost shift ...
    drift_factor: float = 1.0       # ... by this multiplier
    refit_interval_s: float = 10.0  # usl_online knobs (see miniapp)
    refit_window: int = 128
    refit_half_life_s: float = 45.0
    threaded_service_s: float | None = None
    faults: dict | None = None      # FaultPlan spec — failure-semantics axis
    max_retries: int = 2            # retry budget before poisoning a batch
    retry_backoff_s: float = 0.0    # exponential-backoff base (0 = immediate)

    def experiments(self, usl_params: dict | None = None) -> list[AdaptationExperiment]:
        """``usl_params``: machine → (sigma, kappa, gamma) for the
        predictive cells, both frozen (``"usl"``) and online re-fitting
        (``"usl_online"``) (other policies ignore it)."""
        usl_params = usl_params or {}
        out = []
        for m, sp, rate in itertools.product(self.machines,
                                             self.scaling_policies, self.rates):
            sigma = kappa = gamma = None
            if sp in ("usl", "usl_online"):
                if m not in usl_params:
                    raise ValueError(
                        f"no USL params for machine {m!r}: run a "
                        "characterization sweep first (or pass usl_params)")
                sigma, kappa, gamma = usl_params[m]
            out.append(AdaptationExperiment(
                machine=m, scaling_policy=sp, rate=dict(rate),
                horizon_s=self.horizon_s,
                initial_partitions=self.initial_partitions,
                max_partitions=self.max_partitions,
                static_partitions=self.static_partitions,
                usl_sigma=sigma, usl_kappa=kappa, usl_gamma=gamma,
                control_interval_s=self.control_interval_s,
                slo_lag=self.slo_lag,
                migration_s_per_delta=self.migration_s_per_delta,
                points=self.points, centroids=self.centroids,
                memory_mb=self.memory_mb, policy=self.policy,
                batch_max=self.batch_max, seed=self.seed,
                engine=self.engine,
                drift_t_s=self.drift_t_s, drift_factor=self.drift_factor,
                refit_interval_s=self.refit_interval_s,
                refit_window=self.refit_window,
                refit_half_life_s=self.refit_half_life_s,
                threaded_service_s=self.threaded_service_s,
                faults=dict(self.faults) if self.faults else None,
                max_retries=self.max_retries,
                retry_backoff_s=self.retry_backoff_s))
        return out


# -- cell execution: cache + process pool -------------------------------------

_RESULT_FIELDS = ("run_id", "throughput", "latency_px", "latency_br",
                  "runtime_summary", "processed", "failed", "retried",
                  "wall_virtual_s", "des_events")

_ADAPT_RESULT_FIELDS = ("run_id", "slo_violations", "ticks", "cost_integral",
                        "scale_events", "produced", "processed", "throughput",
                        "latency_px", "alloc_trace", "lag_trace",
                        "final_allocation", "drained", "drain_s",
                        "wall_virtual_s", "des_events", "refits",
                        "abandoned", "dup_delivered", "faults_injected",
                        "preemptions", "fault_windows", "lost",
                        "tick_error_log", "member_ledger")

# summary cells: everything AdaptationSummary carries except the plan
# itself (reconstructed from the cache doc's experiment payload)
_PLAN_SUMMARY_FIELDS = ("slo_violations", "ticks", "cost_integral",
                        "scale_events", "produced", "processed", "throughput",
                        "latency_px", "final_allocation", "drained",
                        "drain_s", "refits", "abandoned", "dup_delivered",
                        "faults_injected", "preemptions", "fault_windows",
                        "lost", "member_ledger", "fast_path",
                        "fallback_reason")

# cell-type registry: run_cells / ResultCache dispatch on the experiment
# dataclass, so characterization, adaptation and what-if plan cells share
# the runner, pool, and on-disk memo.
# name -> (experiment cls, result cls, fields, fn)
_CELL_TYPES = {
    "StreamExperiment": (StreamExperiment, ExperimentResult,
                         _RESULT_FIELDS, run_experiment),
    "AdaptationExperiment": (AdaptationExperiment, AdaptationResult,
                             _ADAPT_RESULT_FIELDS, run_adaptation),
    "AdaptationPlan": (AdaptationPlan, AdaptationSummary,
                       _PLAN_SUMMARY_FIELDS, run_plan),
}


def _execute(exp, registry: MetricRegistry):
    """Run one cell of whichever registered type."""
    return _CELL_TYPES[type(exp).__name__][3](exp, registry)


def cache_key(exp) -> str:
    """The one key-derivation path for every cell type: cell type + all
    experiment fields, stable-JSON-hashed under ``CACHE_SCHEMA_VERSION``.

    ``AdaptationPlan.fast`` is an execution *hint* (the fast replay is
    bit-identical to the scalar DES by contract), so it is excluded: a
    plan's summary is the same value however it was computed, and the
    what-if dedupe in ``core.whatif`` keys on this too.  That contract
    now spans fault-plan cells and the wrangler/stampede2 coupling
    chains (``sim.batched``), so cache entries written by either path
    stay interchangeable across all of them — only the replay's
    *declining* shapes (threaded engine, federation) are ever scalar-only,
    and they hash identically regardless."""
    payload_dict = dataclasses.asdict(exp)
    if type(exp).__name__ == "AdaptationPlan":
        payload_dict.pop("fast", None)
    payload = json.dumps(payload_dict, sort_keys=True, default=repr)
    digest = hashlib.sha256(
        f"v{CACHE_SCHEMA_VERSION}:{type(exp).__name__}:{payload}".encode())
    return digest.hexdigest()[:24]


class ResultCache:
    """On-disk memo of experiment results keyed by the experiment dataclass
    (cell type + all fields, stable-JSON-hashed), so re-running a sweep only
    pays for cells whose parameters changed.  Holds characterization
    (``ExperimentResult``), adaptation (``AdaptationResult``) and what-if
    plan (``AdaptationSummary``) cells."""

    def __init__(self, root: str | Path) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    key = staticmethod(cache_key)

    def path(self, exp) -> Path:
        return self.root / f"{self.key(exp)}.json"

    def get(self, exp):
        path = self.path(exp)
        if not path.exists():
            return None
        try:
            doc = json.loads(path.read_text())
            exp_cls, res_cls, fields, _fn = _CELL_TYPES[
                doc.get("cell_type", "StreamExperiment")]
            return res_cls(experiment=exp_cls(**doc["experiment"]),
                           **{k: doc[k] for k in fields})
        except (KeyError, TypeError, ValueError, json.JSONDecodeError):
            return None          # stale/corrupt entry: fall through to a run

    def _tmp_path(self, exp) -> Path:
        """Writer-unique staging file: two processes (or threads) sharing a
        cache dir must never clobber each other's in-flight tmp before the
        atomic ``replace``."""
        final = self.path(exp)
        return final.with_name(
            f"{final.name}.{os.getpid()}-{threading.get_ident()}.tmp")

    def put(self, exp, res) -> None:
        cell_type = type(exp).__name__
        fields = _CELL_TYPES[cell_type][2]
        doc = {"cell_type": cell_type,
               "experiment": dataclasses.asdict(res.experiment)}
        doc.update({k: getattr(res, k) for k in fields})
        try:
            payload = json.dumps(doc)
        except TypeError:
            return   # non-JSON experiment (e.g. exotic backend_attrs): a
            #          memo that can't round-trip is skipped, never fatal
        tmp = self._tmp_path(exp)
        tmp.write_text(payload)
        tmp.replace(self.path(exp))


def _run_cell_chunk(exps: list) -> list[tuple]:
    """Pool worker: a contiguous chunk of cells, one private registry per
    cell (results are self-contained); each cell also ships back its
    compact trace summary for the caller's registry."""
    out = []
    for exp in exps:
        registry = MetricRegistry()
        res = _execute(exp, registry)
        out.append((res, registry.export_summary()))
    return out


def _mp_context():
    """Never fork a potentially JAX-multithreaded parent (fork after jax
    import is a documented deadlock hazard); forkserver forks workers from
    a clean helper process, spawn is the portable fallback."""
    try:
        return multiprocessing.get_context("forkserver")
    except ValueError:
        return multiprocessing.get_context("spawn")


# -- persistent worker pool ---------------------------------------------------
#
# Pool startup on a small container costs ~0.3 s — more than an entire
# light sweep (the exact failure mode the ROADMAP flagged: PR 1's
# per-sweep pool was 27x slower than serial on cheap grids).  The pool is
# created lazily on the first sweep heavy enough to want it and reused for
# the life of the process, like Pilot-Streaming's warm resource containers.

_pool_lock = threading.Lock()
_pool: concurrent.futures.ProcessPoolExecutor | None = None
_pool_workers = 0

# Auto-switch threshold on the summed cell cost estimate
# (n_messages × points × centroids).  Calibrated on the 2-core reference
# container: the perf-smoke sweep (~6e10) runs in ~0.1 s serially — far
# below pool IPC break-even — while grids an order of magnitude heavier
# amortize the warm pool.
PARALLEL_COST_THRESHOLD = 2e11


def estimated_cost(experiments: list) -> float:
    """Work estimate driving the serial-vs-pooled auto-switch.  Adaptation
    cells expose ``cost_estimate()`` (expected messages from the rate-trace
    integral × per-message work); characterization cells use the historical
    ``n_messages × points × centroids``."""
    total = 0.0
    for e in experiments:
        est = getattr(e, "cost_estimate", None)
        total += est() if est is not None else e.n_messages * e.points * e.centroids
    return float(total)


def _get_pool(workers: int) -> concurrent.futures.ProcessPoolExecutor:
    global _pool, _pool_workers
    with _pool_lock:
        if _pool is None or _pool_workers < workers:
            if _pool is not None:
                _pool.shutdown(wait=False, cancel_futures=True)
            _pool = concurrent.futures.ProcessPoolExecutor(
                max_workers=workers, mp_context=_mp_context())
            _pool_workers = workers
        return _pool


def _reset_pool() -> None:
    global _pool, _pool_workers
    with _pool_lock:
        if _pool is not None:
            _pool.shutdown(wait=False, cancel_futures=True)
        _pool = None
        _pool_workers = 0


atexit.register(_reset_pool)


def _use_pool(parallel, pending: list[tuple[int, StreamExperiment]]) -> bool:
    if parallel is False or len(pending) < 2:
        return False
    if parallel == "force":
        return True
    # True and "auto" both auto-switch: pooling a cheap grid would be a
    # pessimization, never a win
    return estimated_cost([exp for _i, exp in pending]) >= PARALLEL_COST_THRESHOLD


def run_cells(experiments: list, *,
              metrics: MetricRegistry | None = None,
              parallel: bool | str = "auto",
              max_workers: int | None = None,
              cache: ResultCache | str | Path | None = None,
              on_result=None) -> list[ExperimentResult]:
    """Execute experiment cells via the persistent pool and/or cache.

    ``parallel``: ``"auto"`` (default) and ``True`` pick serial or pooled
    execution from the grid's estimated work; ``"force"`` always pools;
    ``False`` never does.  Results are returned in input order regardless
    of completion order, and are bit-identical between serial and parallel
    execution (each cell's DES is seeded from its own dataclass).
    ``on_result(exp, res)`` is invoked as each cell lands (live progress;
    in pooled mode that is completion order, not input order).  When
    ``metrics`` is given, serial runs trace into it directly and pooled
    runs merge back compact per-cell event summaries
    (``metrics.trace_summary(run_id)``).
    """
    if isinstance(cache, (str, Path)):
        cache = ResultCache(cache)
    notify = on_result or (lambda exp, res: None)
    results: dict[int, ExperimentResult] = {}
    pending: list[tuple[int, Any]] = []
    for i, exp in enumerate(experiments):
        hit = cache.get(exp) if cache is not None else None
        if hit is not None:
            results[i] = hit
            notify(exp, hit)
        else:
            pending.append((i, exp))
    if _use_pool(parallel, pending):
        workers = max_workers or min(len(pending), os.cpu_count() or 1)
        # chunked submission: several cells per task bounds IPC round-trips
        # while leaving enough tasks (~4 per worker) for load balancing
        chunk = max(1, len(pending) // (workers * 4))
        chunks = [pending[k:k + chunk] for k in range(0, len(pending), chunk)]
        for attempt in (1, 2):
            pool = _get_pool(workers)
            futures = {pool.submit(_run_cell_chunk, [exp for _i, exp in grp]): grp
                       for grp in chunks}
            try:
                for fut in concurrent.futures.as_completed(futures):
                    grp = futures[fut]
                    for (i, exp), (res, summary) in zip(grp, fut.result()):
                        results[i] = res
                        if metrics is not None:
                            metrics.merge_summary(summary)
                        notify(exp, res)
                break
            except concurrent.futures.process.BrokenProcessPool:
                # a worker died (OOM/kill): restart the pool once and retry
                # only the cells that never landed — completed cells keep
                # their results and are not re-notified; cells are pure so
                # re-running the missing ones is safe
                _reset_pool()
                if attempt == 2:
                    raise
                done = set(results)
                chunks = [[(i, exp) for i, exp in grp if i not in done]
                          for grp in chunks]
                chunks = [grp for grp in chunks if grp]
    else:
        for i, exp in pending:
            results[i] = _execute(
                exp, metrics if metrics is not None else MetricRegistry())
            notify(exp, results[i])
    if cache is not None:
        for i, _exp in pending:
            cache.put(_exp, results[i])
    return [results[i] for i in range(len(experiments))]


@dataclass
class ScenarioModel:
    """USL model for one (machine, MS, WC, memory, policy, batch) scenario."""

    key: tuple
    fit: USLFit
    n: np.ndarray
    t: np.ndarray

    def __str__(self) -> str:
        m, p, c, mem, pol, bm = self.key
        return (f"{m:>10} pts={p:<6} c={c:<5} mem={mem:<5} "
                f"policy={str(pol):<16} b={bm:<3} -> {self.fit.summary()}")


class StreamInsight:
    """Run a design, fit USL per scenario, evaluate prediction quality.

    ``parallel`` is forwarded to ``run_cells`` (default ``"auto"``: heavy
    grids fan out over the persistent process pool, cheap ones run
    serially); ``cache_dir`` memoizes finished cells on disk (see
    ``ResultCache``).  Pooled sweeps merge compact per-cell trace
    summaries into ``self.metrics``.
    """

    def __init__(self, metrics: MetricRegistry | None = None,
                 cache_dir: str | Path | None = None,
                 max_workers: int | None = None) -> None:
        self.metrics = metrics or MetricRegistry()
        self.cache = ResultCache(cache_dir) if cache_dir is not None else None
        self.max_workers = max_workers
        self.results: list[ExperimentResult] = []
        self.adaptation_results: list[AdaptationResult] = []

    # -- execution -----------------------------------------------------------
    def run(self, design: ExperimentDesign, verbose: bool = False,
            parallel: bool | str = "auto") -> list[ExperimentResult]:
        exps = design.experiments()

        def progress(exp, res):
            print(f"  ran {exp.machine} N={exp.partitions} pts={exp.points} "
                  f"c={exp.centroids} mem={exp.memory_mb} "
                  f"policy={exp.effective_policy} b={exp.batch_max} "
                  f"-> T={res.throughput:.3f}", flush=True)

        batch = run_cells(exps, metrics=self.metrics, parallel=parallel,
                          max_workers=self.max_workers, cache=self.cache,
                          on_result=progress if verbose else None)
        self.results.extend(batch)
        return self.results

    def records(self) -> list[dict]:
        return [r.record() for r in self.results]

    # -- adaptation (EILC: characterize -> model -> adapt) --------------------
    def usl_params(self, *, points: int = 8000, centroids: int = 1024,
                   memory_mb: int = 3008, policy: str | None = None,
                   batch_max: int = 1) -> dict:
        """Per-machine fitted (sigma, kappa, gamma) for the scenario
        matching the given workload knobs, from this insight's
        characterization results."""
        out = {}
        for m in self.fit_models():
            machine, p, c, mem, pol, bm = m.key
            eff = policy if policy is not None else default_consistency(machine)
            if (p, c, mem, bm) == (points, centroids, memory_mb, batch_max) \
                    and pol == eff:
                out[machine] = (m.fit.sigma, m.fit.kappa, m.fit.gamma)
        return out

    def run_adaptation(self, design: AdaptationDesign | list, *,
                       verbose: bool = False,
                       parallel: bool | str = "auto") -> list[AdaptationResult]:
        """Execute adaptation cells (a design grid or an explicit list).

        For a design, predictive cells are parameterized automatically from
        the USL models fitted on this insight's characterization sweep —
        the full paper §V loop in two calls: ``run(design)`` then
        ``run_adaptation(adaptation_design)``.
        """
        if isinstance(design, AdaptationDesign):
            needs_usl = any(sp in ("usl", "usl_online")
                            for sp in design.scaling_policies)
            params = self.usl_params(
                points=design.points, centroids=design.centroids,
                memory_mb=design.memory_mb, policy=design.policy,
                batch_max=design.batch_max) if needs_usl else {}
            cells = design.experiments(usl_params=params)
        else:
            cells = list(design)

        def progress(exp, res):
            print(f"  ran {exp.machine} {exp.scaling_policy:>8} "
                  f"rate={exp.rate.get('kind')} -> "
                  f"viol={res.slo_violations}/{res.ticks} "
                  f"cost={res.cost_integral:.0f}", flush=True)

        batch = run_cells(cells, metrics=self.metrics, parallel=parallel,
                          max_workers=self.max_workers, cache=self.cache,
                          on_result=progress if verbose else None)
        self.adaptation_results.extend(batch)
        return batch

    def adaptation_records(self) -> list[dict]:
        return [r.record() for r in self.adaptation_results]

    # -- modeling --------------------------------------------------------------
    @staticmethod
    def scenario_key(rec: dict) -> tuple:
        return (rec["machine"], rec["points"], rec["centroids"],
                rec["memory_mb"], rec.get("policy"), rec.get("batch_max", 1))

    def _scenario_arrays(self, records: list[dict]) -> list[tuple]:
        """Sorted (key, n, t) triples, one per scenario group."""
        groups: dict[tuple, list[dict]] = {}
        for rec in records:
            groups.setdefault(self.scenario_key(rec), []).append(rec)
        out = []
        for key, recs in sorted(groups.items()):
            n = np.array([r["partitions"] for r in recs], dtype=np.float64)
            t = np.array([r["throughput"] for r in recs], dtype=np.float64)
            out.append((key, n, t))
        return out

    def fit_models(self, records: list[dict] | None = None, *,
                   bootstrap: int = 0, bootstrap_seed: int = 0,
                   backend: str = "numpy") -> list[ScenarioModel]:
        """Fit one USL model per scenario — all scenarios in a single
        batched call (ragged groups are padded and masked).  ``bootstrap=B``
        adds percentile CIs for (sigma, kappa, peak_N) to every fit;
        ``backend="jax"`` routes through the jit+vmap LM path."""
        records = records if records is not None else self.records()
        keys, ns, ts = [], [], []
        for key, n, t in self._scenario_arrays(records):
            if len(np.unique(n)) < 2:
                continue
            keys.append(key)
            ns.append(n)
            ts.append(t)
        fits = fit_usl_ragged(ns, ts, bootstrap=bootstrap,
                              bootstrap_seed=bootstrap_seed, backend=backend)
        return [ScenarioModel(key=k, fit=f, n=n, t=t)
                for k, f, n, t in zip(keys, fits, ns, ts)]

    # -- model evaluation (paper Fig 7) ----------------------------------------
    def evaluate(self, n_train_configs, records: list[dict] | None = None,
                 seed: int = 0, backend: str = "numpy"):
        """Train on ``n_train_configs`` partition levels per scenario, report
        RMSE of throughput predictions on the held-out levels.

        ``n_train_configs`` may be an int (returns one aggregate dict, the
        historical behaviour) or a sequence of ints (returns a list of
        aggregate dicts).  Either way every (training-set size × scenario)
        train split becomes one row of a single ``fit_usl_batch`` call —
        train membership is just a 0/1 weight row — so a full Fig-7 curve
        costs one vectorized fit instead of a double loop of scalar fits.
        Scenarios whose partition grid is too sparse for the requested
        training-set size are skipped, never fatal."""
        records = records if records is not None else self.records()
        multi = isinstance(n_train_configs, (list, tuple, np.ndarray))
        wanted = [int(x) for x in
                  (n_train_configs if multi else [n_train_configs])]
        scenarios = self._scenario_arrays(records)
        jobs = []      # (n_train, key, n, t, train_mask)
        for n_train in wanted:
            # a fresh generator per training-set size keeps the level choice
            # identical to the historical one-size-per-call behaviour
            rng = np.random.default_rng(seed)
            for key, n, t in scenarios:
                levels = np.unique(n)
                if len(levels) <= n_train or n_train < 2:
                    continue
                # anchor the design range (min AND max level), sample the middle
                middle = levels[(levels > levels.min()) & (levels < levels.max())]
                n_mid = max(n_train - 2, 0)
                if n_mid > len(middle):
                    # defensive: with unique levels the earlier size check
                    # already implies enough interior levels; this keeps a
                    # future anchor-selection change from turning a sparse
                    # grid into a rng.choice ValueError mid-sweep
                    continue
                chosen = (rng.choice(middle, size=n_mid, replace=False)
                          if n_mid else np.array([]))
                train_levels = np.concatenate(
                    [[levels.min(), levels.max()], chosen])
                jobs.append((n_train, key, n, t, np.isin(n, train_levels)))
        fits = []
        if jobs:
            width = max(job[2].size for job in jobs)
            n_mat = np.ones((len(jobs), width))
            t_mat = np.zeros((len(jobs), width))
            w_mat = np.zeros((len(jobs), width))
            for i, (_nt, _key, n, t, tr) in enumerate(jobs):
                n_mat[i, :n.size] = n
                t_mat[i, :t.size] = t
                w_mat[i, :n.size] = tr         # held-out levels: weight 0
            fits = fit_usl_batch(n_mat, t_mat, weights=w_mat, backend=backend)
        per_size: dict[int, dict] = {nt: {} for nt in wanted}
        for (n_train, key, n, t, tr), fit in zip(jobs, fits):
            pred = fit.predict(n[~tr])
            err = rmse(t[~tr], pred)
            per_size[n_train][key] = dict(
                rmse=err,
                rel_rmse=err / max(float(np.mean(t[~tr])), 1e-12),
                n_train=int(tr.sum()), n_test=int((~tr).sum()),
                sigma=fit.sigma, kappa=fit.kappa)
        aggs = []
        for n_train in wanted:
            per_scenario = per_size[n_train]
            aggs.append({
                "n_train_configs": n_train,
                "mean_rmse": float(np.mean(
                    [v["rmse"] for v in per_scenario.values()]))
                if per_scenario else float("nan"),
                "mean_rel_rmse": float(np.mean(
                    [v["rel_rmse"] for v in per_scenario.values()]))
                if per_scenario else float("nan"),
                "scenarios": per_scenario,
            })
        return aggs if multi else aggs[0]

    def report(self, *, bootstrap: int = 0, bootstrap_seed: int = 0) -> str:
        """Per-scenario model summaries; ``bootstrap=B`` appends percentile
        confidence intervals for (sigma, kappa, peak_N) to every line."""
        lines = ["StreamInsight scenario models (USL):"]
        for m in self.fit_models(bootstrap=bootstrap,
                                 bootstrap_seed=bootstrap_seed):
            lines.append("  " + str(m))
        return "\n".join(lines)
