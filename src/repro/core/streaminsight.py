"""StreamInsight: end-to-end performance experimentation and modeling.

Supports the paper's workflow (§IV): experimental design (parameter grids
over machine M, parallelism N, message size MS, workload complexity WC,
container memory — plus, beyond the paper, micro-batch size ``batch_max``
and the model-sharing consistency ``policy``), automated execution on the
Streaming Mini-App, USL model fitting per scenario, and model evaluation on
unseen configurations (train/test split, RMSE vs number of training
configurations — Fig 7).

Execution model: every ``StreamExperiment`` cell builds its own
``PilotComputeService`` / ``Simulator`` seeded by ``exp.seed``, so cells are
fully independent — like Pilot-Streaming's independently managed resource
containers, they are embarrassingly parallel.  ``run_cells`` exploits that
with a *persistent* process pool: workers are spawned lazily on the first
pooled sweep and reused across ``run_cells`` calls for the life of the
process, amortizing pool startup the way Pilot-Streaming keeps resource
containers warm across workloads.  Because the seed travels inside the
dataclass, parallel results are bit-identical to serial ones.

``parallel="auto"`` (the default, and what ``parallel=True`` resolves to)
switches between serial and pooled execution on an estimated-work heuristic
(``n_messages × points × centroids`` summed over uncached cells): cheap
grids run serially — on small sweeps pool IPC costs more than the cells —
and only heavy grids fan out, so parallel mode is never a pessimization.
``parallel="force"`` always uses the pool; ``parallel=False`` never does.
Cells are submitted in contiguous chunks (several cells per task) to keep
IPC overhead sublinear in grid size.

Pooled workers collect trace events in private ``MetricRegistry``s; the
summaries inside ``ExperimentResult`` are computed in-worker, so results
are identical either way, and each worker additionally returns a compact
per-(component, kind) event summary that ``run_cells`` merges into the
caller's registry (``MetricRegistry.trace_summary(run_id)``).  Run serially
when you need raw per-event traces; pooled sweeps surface merged summaries.

An optional on-disk ``ResultCache`` keyed by the experiment dataclass makes
re-runs of a sweep free.
"""

from __future__ import annotations

import atexit
import concurrent.futures
import dataclasses
import hashlib
import itertools
import json
import multiprocessing
import os
import threading
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.core.metrics import MetricRegistry
from repro.core.miniapp import ExperimentResult, StreamExperiment, run_experiment
from repro.core.usl import USLFit, fit_usl, rmse

__all__ = ["ExperimentDesign", "ScenarioModel", "StreamInsight", "ResultCache",
           "run_cells", "estimated_cost", "PARALLEL_COST_THRESHOLD"]

_CACHE_VERSION = 1


@dataclass
class ExperimentDesign:
    """Cartesian experiment grid (the paper's control variables).

    ``batch_max`` and ``policy`` accept either a scalar (one level, the
    seed behaviour) or a list of levels — first-class grid axes, so e.g.
    the three model-sharing policies become directly comparable in one
    design.
    """

    machines: list = field(default_factory=lambda: ["serverless", "wrangler"])
    partitions: list = field(default_factory=lambda: [1, 2, 4, 8, 12, 16])
    points: list = field(default_factory=lambda: [16000])       # MS
    centroids: list = field(default_factory=lambda: [1024])     # WC
    memory_mb: list = field(default_factory=lambda: [3008])
    n_messages: int = 80
    seed: int = 0
    policy: str | list | None = None
    batch_max: int | list = 1

    @staticmethod
    def _levels(axis) -> list:
        return list(axis) if isinstance(axis, (list, tuple)) else [axis]

    def experiments(self) -> list[StreamExperiment]:
        out = []
        for m, n, p, c, mem, pol, bm in itertools.product(
                self.machines, self.partitions, self.points, self.centroids,
                self.memory_mb, self._levels(self.policy),
                self._levels(self.batch_max)):
            out.append(StreamExperiment(
                machine=m, partitions=n, points=p, centroids=c, memory_mb=mem,
                n_messages=self.n_messages, seed=self.seed, policy=pol,
                batch_max=bm))
        return out


# -- cell execution: cache + process pool -------------------------------------

_RESULT_FIELDS = ("run_id", "throughput", "latency_px", "latency_br",
                  "runtime_summary", "processed", "failed", "retried",
                  "wall_virtual_s", "des_events")


class ResultCache:
    """On-disk memo of ``ExperimentResult``s keyed by the experiment
    dataclass (all fields, stable-JSON-hashed), so re-running a sweep only
    pays for cells whose parameters changed."""

    def __init__(self, root: str | Path) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    @staticmethod
    def key(exp: StreamExperiment) -> str:
        payload = json.dumps(dataclasses.asdict(exp), sort_keys=True,
                             default=repr)
        digest = hashlib.sha256(f"v{_CACHE_VERSION}:{payload}".encode())
        return digest.hexdigest()[:24]

    def path(self, exp: StreamExperiment) -> Path:
        return self.root / f"{self.key(exp)}.json"

    def get(self, exp: StreamExperiment) -> ExperimentResult | None:
        path = self.path(exp)
        if not path.exists():
            return None
        try:
            doc = json.loads(path.read_text())
            return ExperimentResult(
                experiment=StreamExperiment(**doc["experiment"]),
                **{k: doc[k] for k in _RESULT_FIELDS})
        except (KeyError, TypeError, ValueError, json.JSONDecodeError):
            return None          # stale/corrupt entry: fall through to a run

    def put(self, exp: StreamExperiment, res: ExperimentResult) -> None:
        doc = {"experiment": dataclasses.asdict(res.experiment)}
        doc.update({k: getattr(res, k) for k in _RESULT_FIELDS})
        try:
            payload = json.dumps(doc)
        except TypeError:
            return   # non-JSON experiment (e.g. exotic backend_attrs): a
            #          memo that can't round-trip is skipped, never fatal
        tmp = self.path(exp).with_suffix(".tmp")
        tmp.write_text(payload)
        tmp.replace(self.path(exp))


def _run_cell_chunk(exps: list[StreamExperiment]) -> list[tuple[ExperimentResult, dict]]:
    """Pool worker: a contiguous chunk of cells, one private registry per
    cell (results are self-contained); each cell also ships back its
    compact trace summary for the caller's registry."""
    out = []
    for exp in exps:
        registry = MetricRegistry()
        res = run_experiment(exp, registry)
        out.append((res, registry.export_summary()))
    return out


def _mp_context():
    """Never fork a potentially JAX-multithreaded parent (fork after jax
    import is a documented deadlock hazard); forkserver forks workers from
    a clean helper process, spawn is the portable fallback."""
    try:
        return multiprocessing.get_context("forkserver")
    except ValueError:
        return multiprocessing.get_context("spawn")


# -- persistent worker pool ---------------------------------------------------
#
# Pool startup on a small container costs ~0.3 s — more than an entire
# light sweep (the exact failure mode the ROADMAP flagged: PR 1's
# per-sweep pool was 27x slower than serial on cheap grids).  The pool is
# created lazily on the first sweep heavy enough to want it and reused for
# the life of the process, like Pilot-Streaming's warm resource containers.

_pool_lock = threading.Lock()
_pool: concurrent.futures.ProcessPoolExecutor | None = None
_pool_workers = 0

# Auto-switch threshold on the summed cell cost estimate
# (n_messages × points × centroids).  Calibrated on the 2-core reference
# container: the perf-smoke sweep (~6e10) runs in ~0.1 s serially — far
# below pool IPC break-even — while grids an order of magnitude heavier
# amortize the warm pool.
PARALLEL_COST_THRESHOLD = 2e11


def estimated_cost(experiments: list[StreamExperiment]) -> float:
    """Work estimate driving the serial-vs-pooled auto-switch."""
    return float(sum(e.n_messages * e.points * e.centroids
                     for e in experiments))


def _get_pool(workers: int) -> concurrent.futures.ProcessPoolExecutor:
    global _pool, _pool_workers
    with _pool_lock:
        if _pool is None or _pool_workers < workers:
            if _pool is not None:
                _pool.shutdown(wait=False, cancel_futures=True)
            _pool = concurrent.futures.ProcessPoolExecutor(
                max_workers=workers, mp_context=_mp_context())
            _pool_workers = workers
        return _pool


def _reset_pool() -> None:
    global _pool, _pool_workers
    with _pool_lock:
        if _pool is not None:
            _pool.shutdown(wait=False, cancel_futures=True)
        _pool = None
        _pool_workers = 0


atexit.register(_reset_pool)


def _use_pool(parallel, pending: list[tuple[int, StreamExperiment]]) -> bool:
    if parallel is False or len(pending) < 2:
        return False
    if parallel == "force":
        return True
    # True and "auto" both auto-switch: pooling a cheap grid would be a
    # pessimization, never a win
    return estimated_cost([exp for _i, exp in pending]) >= PARALLEL_COST_THRESHOLD


def run_cells(experiments: list[StreamExperiment], *,
              metrics: MetricRegistry | None = None,
              parallel: bool | str = "auto",
              max_workers: int | None = None,
              cache: ResultCache | str | Path | None = None,
              on_result=None) -> list[ExperimentResult]:
    """Execute experiment cells via the persistent pool and/or cache.

    ``parallel``: ``"auto"`` (default) and ``True`` pick serial or pooled
    execution from the grid's estimated work; ``"force"`` always pools;
    ``False`` never does.  Results are returned in input order regardless
    of completion order, and are bit-identical between serial and parallel
    execution (each cell's DES is seeded from its own dataclass).
    ``on_result(exp, res)`` is invoked as each cell lands (live progress;
    in pooled mode that is completion order, not input order).  When
    ``metrics`` is given, serial runs trace into it directly and pooled
    runs merge back compact per-cell event summaries
    (``metrics.trace_summary(run_id)``).
    """
    if isinstance(cache, (str, Path)):
        cache = ResultCache(cache)
    notify = on_result or (lambda exp, res: None)
    results: dict[int, ExperimentResult] = {}
    pending: list[tuple[int, StreamExperiment]] = []
    for i, exp in enumerate(experiments):
        hit = cache.get(exp) if cache is not None else None
        if hit is not None:
            results[i] = hit
            notify(exp, hit)
        else:
            pending.append((i, exp))
    if _use_pool(parallel, pending):
        workers = max_workers or min(len(pending), os.cpu_count() or 1)
        # chunked submission: several cells per task bounds IPC round-trips
        # while leaving enough tasks (~4 per worker) for load balancing
        chunk = max(1, len(pending) // (workers * 4))
        chunks = [pending[k:k + chunk] for k in range(0, len(pending), chunk)]
        for attempt in (1, 2):
            pool = _get_pool(workers)
            futures = {pool.submit(_run_cell_chunk, [exp for _i, exp in grp]): grp
                       for grp in chunks}
            try:
                for fut in concurrent.futures.as_completed(futures):
                    grp = futures[fut]
                    for (i, exp), (res, summary) in zip(grp, fut.result()):
                        results[i] = res
                        if metrics is not None:
                            metrics.merge_summary(summary)
                        notify(exp, res)
                break
            except concurrent.futures.process.BrokenProcessPool:
                # a worker died (OOM/kill): restart the pool once and retry
                # only the cells that never landed — completed cells keep
                # their results and are not re-notified; cells are pure so
                # re-running the missing ones is safe
                _reset_pool()
                if attempt == 2:
                    raise
                done = set(results)
                chunks = [[(i, exp) for i, exp in grp if i not in done]
                          for grp in chunks]
                chunks = [grp for grp in chunks if grp]
    else:
        for i, exp in pending:
            results[i] = run_experiment(
                exp, metrics if metrics is not None else MetricRegistry())
            notify(exp, results[i])
    if cache is not None:
        for i, _exp in pending:
            cache.put(_exp, results[i])
    return [results[i] for i in range(len(experiments))]


@dataclass
class ScenarioModel:
    """USL model for one (machine, MS, WC, memory, policy, batch) scenario."""

    key: tuple
    fit: USLFit
    n: np.ndarray
    t: np.ndarray

    def __str__(self) -> str:
        m, p, c, mem, pol, bm = self.key
        return (f"{m:>10} pts={p:<6} c={c:<5} mem={mem:<5} "
                f"policy={str(pol):<16} b={bm:<3} -> {self.fit.summary()}")


class StreamInsight:
    """Run a design, fit USL per scenario, evaluate prediction quality.

    ``parallel`` is forwarded to ``run_cells`` (default ``"auto"``: heavy
    grids fan out over the persistent process pool, cheap ones run
    serially); ``cache_dir`` memoizes finished cells on disk (see
    ``ResultCache``).  Pooled sweeps merge compact per-cell trace
    summaries into ``self.metrics``.
    """

    def __init__(self, metrics: MetricRegistry | None = None,
                 cache_dir: str | Path | None = None,
                 max_workers: int | None = None) -> None:
        self.metrics = metrics or MetricRegistry()
        self.cache = ResultCache(cache_dir) if cache_dir is not None else None
        self.max_workers = max_workers
        self.results: list[ExperimentResult] = []

    # -- execution -----------------------------------------------------------
    def run(self, design: ExperimentDesign, verbose: bool = False,
            parallel: bool | str = "auto") -> list[ExperimentResult]:
        exps = design.experiments()

        def progress(exp, res):
            print(f"  ran {exp.machine} N={exp.partitions} pts={exp.points} "
                  f"c={exp.centroids} mem={exp.memory_mb} "
                  f"policy={exp.effective_policy} b={exp.batch_max} "
                  f"-> T={res.throughput:.3f}", flush=True)

        batch = run_cells(exps, metrics=self.metrics, parallel=parallel,
                          max_workers=self.max_workers, cache=self.cache,
                          on_result=progress if verbose else None)
        self.results.extend(batch)
        return self.results

    def records(self) -> list[dict]:
        return [r.record() for r in self.results]

    # -- modeling --------------------------------------------------------------
    @staticmethod
    def scenario_key(rec: dict) -> tuple:
        return (rec["machine"], rec["points"], rec["centroids"],
                rec["memory_mb"], rec.get("policy"), rec.get("batch_max", 1))

    def fit_models(self, records: list[dict] | None = None) -> list[ScenarioModel]:
        records = records if records is not None else self.records()
        groups: dict[tuple, list[dict]] = {}
        for rec in records:
            groups.setdefault(self.scenario_key(rec), []).append(rec)
        models = []
        for key, recs in sorted(groups.items()):
            n = np.array([r["partitions"] for r in recs], dtype=np.float64)
            t = np.array([r["throughput"] for r in recs], dtype=np.float64)
            if len(np.unique(n)) < 2:
                continue
            models.append(ScenarioModel(key=key, fit=fit_usl(n, t), n=n, t=t))
        return models

    # -- model evaluation (paper Fig 7) ----------------------------------------
    def evaluate(self, n_train_configs: int, records: list[dict] | None = None,
                 seed: int = 0) -> dict:
        """Train on ``n_train_configs`` partition levels per scenario, report
        RMSE of throughput predictions on the held-out levels."""
        records = records if records is not None else self.records()
        rng = np.random.default_rng(seed)
        groups: dict[tuple, list[dict]] = {}
        for rec in records:
            groups.setdefault(self.scenario_key(rec), []).append(rec)
        per_scenario = {}
        for key, recs in sorted(groups.items()):
            n = np.array([r["partitions"] for r in recs], dtype=np.float64)
            t = np.array([r["throughput"] for r in recs], dtype=np.float64)
            levels = np.unique(n)
            if len(levels) <= n_train_configs or n_train_configs < 2:
                continue
            # anchor the design range (min AND max level), sample the middle
            middle = levels[(levels > levels.min()) & (levels < levels.max())]
            n_mid = max(n_train_configs - 2, 0)
            chosen = (rng.choice(middle, size=n_mid, replace=False)
                      if n_mid else np.array([]))
            train_levels = np.concatenate([[levels.min(), levels.max()], chosen])
            tr = np.isin(n, train_levels)
            fit = fit_usl(n[tr], t[tr])
            pred = fit.predict(n[~tr])
            per_scenario[key] = dict(
                rmse=rmse(t[~tr], pred),
                rel_rmse=rmse(t[~tr], pred) / max(float(np.mean(t[~tr])), 1e-12),
                n_train=int(tr.sum()), n_test=int((~tr).sum()),
                sigma=fit.sigma, kappa=fit.kappa)
        agg = {
            "n_train_configs": n_train_configs,
            "mean_rmse": float(np.mean([v["rmse"] for v in per_scenario.values()]))
            if per_scenario else float("nan"),
            "mean_rel_rmse": float(np.mean([v["rel_rmse"] for v in per_scenario.values()]))
            if per_scenario else float("nan"),
            "scenarios": per_scenario,
        }
        return agg

    def report(self) -> str:
        lines = ["StreamInsight scenario models (USL):"]
        for m in self.fit_models():
            lines.append("  " + str(m))
        return "\n".join(lines)
