"""Streaming Mini-App: producer → broker → processing, end to end (paper §IV).

Composes the pilot backends, the broker, the backoff producer and the
streaming engine into the paper's benchmark harness.  A single
``StreamExperiment`` describes one cell of the paper's parameter space
(machine M, partitions N, message size MS, workload complexity WC, container
memory); ``run_experiment`` executes it on the virtual clock and returns the
measured throughput T^px and latencies L^px / L^br, traced per run-id.

K-Means cost model (paper §IV-B): messages carry ``points`` d=9 float32
points (≈37 B/point, matching the paper's 296 KB / 8,000 points); workload
complexity is the centroid count c ∈ [128, 8192].  The distance phase is
O(n·c·d); ``IMPL_OVERHEAD`` calibrates raw FLOPs to an effective
sklearn-MiniBatchKMeans rate (Python/numpy overhead ≈ 8×).

Model-sharing consistency policy (see DESIGN.md §2): the paper's measured
Dask sigma ∈ [0.6, 1.0] — "the peak scalability of the system is already
reached with a single partition" — is mechanically consistent only with the
partial_fit executing inside the shared-model critical section; that is the
``full_fit_locked`` default on HPC.  ``update_locked`` (distances computed
against a stale model outside the lock) is the beyond-paper optimization
StreamInsight recommends, and ``lock_free`` is the serverless behaviour
(S3 last-writer-wins).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.metrics import MetricRegistry, new_run_id, percentile_summary
from repro.pilot.api import (PilotComputeService, PilotDescription, State,
                             TaskProfile)
from repro.streaming.broker import Broker
from repro.streaming.engine import SimStreamingEngine, Workload
from repro.streaming.producer import (AIMD, PartitionIngest, SharedFsIngest,
                                      SyntheticProducer)

__all__ = ["StreamExperiment", "ExperimentResult", "KMeansStreamWorkload",
           "run_experiment", "POINT_BYTES", "KMEANS_DIM"]

KMEANS_DIM = 9          # 9 float32 dims + header ≈ 37 B/point (paper: 296 KB / 8,000 pts)
POINT_BYTES = 37
IMPL_OVERHEAD = 8.0     # sklearn/python effective-FLOPs calibration
SERIALIZE_FLOPS_PER_BYTE = 12.0   # pickle/unpickle cost of the model file


@dataclass
class KMeansStreamWorkload:
    """Maps (points, centroids, policy) to a mechanism-level TaskProfile."""

    points: int = 8000
    centroids: int = 1024
    dim: int = KMEANS_DIM
    policy: str = "full_fit_locked"   # | "update_locked" | "lock_free"
    n_partitions: int = 1

    @property
    def msg_bytes(self) -> int:
        return self.points * POINT_BYTES

    @property
    def model_bytes(self) -> float:
        return self.centroids * self.dim * 4.0

    def profile(self) -> TaskProfile:
        n, c, d = self.points, self.centroids, self.dim
        distance = 3.0 * n * c * d * IMPL_OVERHEAD
        update = (2.0 * n * c + 2.0 * n * d + 6.0 * c * d) * IMPL_OVERHEAD
        serialize = 2.0 * self.model_bytes * SERIALIZE_FLOPS_PER_BYTE
        decode = 2.0 * self.msg_bytes
        if self.policy == "full_fit_locked":
            parallel, serial = decode, distance + update + serialize
        elif self.policy == "update_locked":
            parallel, serial = decode + distance, update + serialize
        elif self.policy == "lock_free":
            parallel, serial = decode + distance + update + serialize, 0.0
        else:
            raise ValueError(f"unknown policy {self.policy!r}")
        return TaskProfile(
            flops=parallel,
            serial_flops=serial,
            read_bytes=self.model_bytes,
            write_bytes=self.model_bytes,
            msg_bytes=self.msg_bytes,
            coherence_peers=max(0, self.n_partitions - 1),
            memory_mb=max(64.0, (self.msg_bytes + 2 * self.model_bytes) / 1e6 * 3 + 40),
        )


@dataclass
class StreamExperiment:
    """One cell of the paper's parameter space."""

    machine: str = "serverless"         # serverless | wrangler | stampede2
    partitions: int = 4                 # N^px(p) == N^br(p) (paper constraint)
    points: int = 8000                  # message size knob (MS)
    centroids: int = 1024               # workload complexity knob (WC)
    memory_mb: int = 3008               # Lambda container memory
    n_messages: int = 200
    policy: str | None = None           # None → platform default
    seed: int = 0
    batch_max: int = 1                  # paper: one Lambda invocation per message
    backend_attrs: dict = field(default_factory=dict)

    @property
    def resource_url(self) -> str:
        return ("serverless://aws-sim" if self.machine == "serverless"
                else f"hpc://{self.machine}-sim")

    @property
    def effective_policy(self) -> str:
        if self.policy is not None:
            return self.policy
        return "lock_free" if self.machine == "serverless" else "full_fit_locked"


@dataclass
class ExperimentResult:
    experiment: StreamExperiment
    run_id: str
    throughput: float                  # msgs/s, steady-state window
    latency_px: dict                   # percentile summary of L^px
    latency_br: dict                   # percentile summary of L^br
    runtime_summary: dict              # per-task service times
    processed: int = 0
    failed: int = 0
    retried: int = 0
    wall_virtual_s: float = 0.0
    des_events: int = 0                # Simulator events consumed by this cell

    def record(self) -> dict:
        e = self.experiment
        return dict(machine=e.machine, partitions=e.partitions, points=e.points,
                    centroids=e.centroids, memory_mb=e.memory_mb,
                    policy=e.effective_policy, batch_max=e.batch_max,
                    throughput=self.throughput,
                    latency_px_p50=self.latency_px.get("p50", float("nan")),
                    latency_px_mean=self.latency_px.get("mean", float("nan")),
                    latency_px_std=self.latency_px.get("std", float("nan")),
                    latency_br_p50=self.latency_br.get("p50", float("nan")),
                    task_p50=self.runtime_summary.get("p50", float("nan")),
                    processed=self.processed, failed=self.failed)


def steady_state_throughput(metrics: MetricRegistry, run_id: str,
                            warmup_frac: float = 0.25) -> float:
    """Completions/sec over the post-warmup window (max sustained throughput).

    Thin wrapper over the registry's vectorized implementation, kept for
    API compatibility."""
    return metrics.steady_state_throughput(run_id, "complete",
                                           warmup_frac=warmup_frac)


def run_experiment(exp: StreamExperiment, metrics: MetricRegistry | None = None,
                   ) -> ExperimentResult:
    metrics = metrics if metrics is not None else MetricRegistry()
    run_id = new_run_id(f"{exp.machine}-N{exp.partitions}")

    pcs = PilotComputeService(seed=exp.seed)
    pilot_desc = PilotDescription(
        resource=exp.resource_url,
        memory_mb=exp.memory_mb,
        partitions=exp.partitions,
        concurrency=exp.partitions,
        attrs=dict(exp.backend_attrs),
    )
    pilot = pcs.submit_pilot(pilot_desc)
    backend = pilot.backend
    sim = backend.sim

    broker = Broker()
    topic = "points"
    broker.create_topic(topic, exp.partitions)

    wl = KMeansStreamWorkload(points=exp.points, centroids=exp.centroids,
                              policy=exp.effective_policy,
                              n_partitions=exp.partitions)
    # the cell's cost profile is message-independent — compute it once
    # instead of rebuilding a TaskProfile per dispatched micro-batch
    profile = wl.profile()
    workload = Workload(profile_for=lambda msgs: profile, name="kmeans")

    # broker ingest path: Kinesis shard limits vs Kafka-on-Lustre
    if exp.machine == "serverless":
        ingest = PartitionIngest(sim, exp.partitions, bw_per_partition=1e6)
    else:
        ingest = SharedFsIngest(sim, backend.shared_resource(pilot, "fs"))

    def msg_factory(i: int):
        return (None, {"n_points": exp.points, "seed": exp.seed * 100003 + i},
                wl.msg_bytes)

    producer = SyntheticProducer(
        sim, broker, topic, msg_factory=msg_factory, n_messages=exp.n_messages,
        run_id=run_id, metrics=metrics,
        aimd=AIMD(rate_hz=2.0 * exp.partitions, hi_watermark=4 * exp.partitions,
                  lo_watermark=exp.partitions),
        ingest=ingest,
    )
    engine = SimStreamingEngine(
        sim, broker, topic, pilot, workload, metrics, run_id,
        batch_max=exp.batch_max,
        is_input_complete=lambda: producer.done,
    )

    producer.start()
    engine.start()
    engine.run_to_completion()

    lat_px = metrics.latencies(run_id, "append", "complete")
    lat_br = metrics.latencies(run_id, "produce", "append")
    runtimes = np.asarray([cu.runtime for cu in pilot.compute_units
                           if cu.state is State.DONE])
    result = ExperimentResult(
        experiment=exp,
        run_id=run_id,
        throughput=steady_state_throughput(metrics, run_id),
        latency_px=percentile_summary(lat_px),
        latency_br=percentile_summary(lat_br),
        runtime_summary=percentile_summary(runtimes),
        processed=engine.core.processed,
        failed=engine.core.failed_batches,
        retried=engine.core.retried,
        wall_virtual_s=sim.now,
        des_events=sim.events_processed,
    )
    pcs.close()
    return result
