"""Streaming Mini-App: producer → broker → processing, end to end (paper §IV).

Composes the pilot backends, the broker, the backoff producer and the
streaming engine into the paper's benchmark harness.  A single
``StreamExperiment`` describes one cell of the paper's parameter space
(machine M, partitions N, message size MS, workload complexity WC, container
memory); ``run_experiment`` executes it on the virtual clock and returns the
measured throughput T^px and latencies L^px / L^br, traced per run-id.

K-Means cost model (paper §IV-B): messages carry ``points`` d=9 float32
points (≈37 B/point, matching the paper's 296 KB / 8,000 points); workload
complexity is the centroid count c ∈ [128, 8192].  The distance phase is
O(n·c·d); ``IMPL_OVERHEAD`` calibrates raw FLOPs to an effective
sklearn-MiniBatchKMeans rate (Python/numpy overhead ≈ 8×).

Adaptation mode (paper §V): ``AdaptationExperiment`` / ``run_adaptation``
run the same pipeline under an *open-loop* time-varying rate program with a
live ``ControlLoop`` (see ``core.autoscale``) elastically resizing the
backend, resharding the broker and repartitioning the engine mid-run —
returning allocation/lag traces, SLO violations and the ∫N dt cost
integral instead of a steady-state throughput point.  Two engines run the
same cell: ``engine="sim"`` (default, virtual clock on the simulated
platforms) and ``engine="threaded"`` (wall clock: the threaded streaming
engine on the elastic local backend, a real-time ticker thread driving the
identical ``ControlLoop``).  ``drift_t_s``/``drift_factor`` shift the
per-message compute cost mid-run — the drifting-cost workload the online
re-fitting policy (``scaling_policy="usl_online"``) is built to track.

Model-sharing consistency policy (see DESIGN.md §2): the paper's measured
Dask sigma ∈ [0.6, 1.0] — "the peak scalability of the system is already
reached with a single partition" — is mechanically consistent only with the
partial_fit executing inside the shared-model critical section; that is the
``full_fit_locked`` default on HPC.  ``update_locked`` (distances computed
against a stale model outside the lock) is the beyond-paper optimization
StreamInsight recommends, and ``lock_free`` is the serverless behaviour
(S3 last-writer-wins).
"""

from __future__ import annotations

import threading
import time

from dataclasses import dataclass, field, replace

import numpy as np

from repro.core.autoscale import ControlLoop, policy_from_spec
from repro.core.metrics import MetricRegistry, new_run_id, percentile_summary
from repro.pilot.api import (PilotComputeService, PilotDescription, State,
                             TaskProfile)
from repro.streaming.broker import Broker
from repro.streaming.engine import (SimStreamingEngine,
                                    ThreadedStreamingEngine, Workload)
from repro.streaming.faults import FaultInjector, FaultPlan
from repro.streaming.producer import (AIMD, PartitionIngest, RateProgram,
                                      SharedFsIngest, SyntheticProducer,
                                      rate_program_from_spec)

__all__ = ["StreamExperiment", "ExperimentResult", "KMeansStreamWorkload",
           "run_experiment", "AdaptationExperiment", "AdaptationResult",
           "run_adaptation", "default_consistency", "POINT_BYTES",
           "KMEANS_DIM", "AdaptationPlan", "AdaptationSummary",
           "scaling_policy_spec", "summarize_adaptation", "run_plan",
           "adaptation_profile_factory"]


def default_consistency(machine: str) -> str:
    """Platform-default model-sharing consistency policy: S3 is
    last-writer-wins (lock-free), the shared filesystem serializes the
    full partial_fit (the paper's measured Dask behaviour)."""
    return "lock_free" if machine == "serverless" else "full_fit_locked"

KMEANS_DIM = 9          # 9 float32 dims + header ≈ 37 B/point (paper: 296 KB / 8,000 pts)
POINT_BYTES = 37
IMPL_OVERHEAD = 8.0     # sklearn/python effective-FLOPs calibration
SERIALIZE_FLOPS_PER_BYTE = 12.0   # pickle/unpickle cost of the model file


@dataclass
class KMeansStreamWorkload:
    """Maps (points, centroids, policy) to a mechanism-level TaskProfile."""

    points: int = 8000
    centroids: int = 1024
    dim: int = KMEANS_DIM
    policy: str = "full_fit_locked"   # | "update_locked" | "lock_free"
    n_partitions: int = 1

    @property
    def msg_bytes(self) -> int:
        return self.points * POINT_BYTES

    @property
    def model_bytes(self) -> float:
        return self.centroids * self.dim * 4.0

    def profile(self) -> TaskProfile:
        n, c, d = self.points, self.centroids, self.dim
        distance = 3.0 * n * c * d * IMPL_OVERHEAD
        update = (2.0 * n * c + 2.0 * n * d + 6.0 * c * d) * IMPL_OVERHEAD
        serialize = 2.0 * self.model_bytes * SERIALIZE_FLOPS_PER_BYTE
        decode = 2.0 * self.msg_bytes
        if self.policy == "full_fit_locked":
            parallel, serial = decode, distance + update + serialize
        elif self.policy == "update_locked":
            parallel, serial = decode + distance, update + serialize
        elif self.policy == "lock_free":
            parallel, serial = decode + distance + update + serialize, 0.0
        else:
            raise ValueError(f"unknown policy {self.policy!r}")
        return TaskProfile(
            flops=parallel,
            serial_flops=serial,
            read_bytes=self.model_bytes,
            write_bytes=self.model_bytes,
            msg_bytes=self.msg_bytes,
            coherence_peers=max(0, self.n_partitions - 1),
            memory_mb=max(64.0, (self.msg_bytes + 2 * self.model_bytes) / 1e6 * 3 + 40),
        )


@dataclass
class _PlatformCell:
    """Shared platform axis of every experiment cell: the machine plus its
    derived resource URL and consistency-policy default (subclasses declare
    the ``policy`` field this reads)."""

    machine: str = "serverless"         # serverless | wrangler | stampede2
                                        # | federated (members via the
                                        # experiment's federation spec)

    @property
    def resource_url(self) -> str:
        if self.machine == "serverless":
            return "serverless://aws-sim"
        if self.machine == "federated":
            return "federated://mix"
        return f"hpc://{self.machine}-sim"

    @property
    def effective_policy(self) -> str:
        if self.policy is not None:
            return self.policy
        return default_consistency(self.machine)


@dataclass
class StreamExperiment(_PlatformCell):
    """One cell of the paper's parameter space."""

    partitions: int = 4                 # N^px(p) == N^br(p) (paper constraint)
    points: int = 8000                  # message size knob (MS)
    centroids: int = 1024               # workload complexity knob (WC)
    memory_mb: int = 3008               # Lambda container memory
    n_messages: int = 200
    policy: str | None = None           # None → platform default
    seed: int = 0
    batch_max: int = 1                  # paper: one Lambda invocation per message
    backend_attrs: dict = field(default_factory=dict)


@dataclass
class ExperimentResult:
    experiment: StreamExperiment
    run_id: str
    throughput: float                  # msgs/s, steady-state window
    latency_px: dict                   # percentile summary of L^px
    latency_br: dict                   # percentile summary of L^br
    runtime_summary: dict              # per-task service times
    processed: int = 0
    failed: int = 0
    retried: int = 0
    wall_virtual_s: float = 0.0
    des_events: int = 0                # Simulator events consumed by this cell

    def record(self) -> dict:
        e = self.experiment
        return dict(machine=e.machine, partitions=e.partitions, points=e.points,
                    centroids=e.centroids, memory_mb=e.memory_mb,
                    policy=e.effective_policy, batch_max=e.batch_max,
                    throughput=self.throughput,
                    latency_px_p50=self.latency_px.get("p50", float("nan")),
                    latency_px_mean=self.latency_px.get("mean", float("nan")),
                    latency_px_std=self.latency_px.get("std", float("nan")),
                    latency_br_p50=self.latency_br.get("p50", float("nan")),
                    task_p50=self.runtime_summary.get("p50", float("nan")),
                    processed=self.processed, failed=self.failed)


def steady_state_throughput(metrics: MetricRegistry, run_id: str,
                            warmup_frac: float = 0.25) -> float:
    """Completions/sec over the post-warmup window (max sustained throughput).

    Thin wrapper over the registry's vectorized implementation, kept for
    API compatibility."""
    return metrics.steady_state_throughput(run_id, "complete",
                                           warmup_frac=warmup_frac)


# ---------------------------------------------------------------------------
# adaptation experiments (EILC): characterize -> model -> *adapt*
# ---------------------------------------------------------------------------

@dataclass
class AdaptationExperiment(_PlatformCell):
    """One closed-loop elastic-scaling cell: a rate trace in, allocation and
    lag traces + SLO violations + cost integral out.

    ``rate`` is a JSON-able rate-program spec (see
    ``streaming.producer.rate_program_from_spec``) — rate traces are a
    first-class design axis, like partitions or message size in
    ``StreamExperiment``.  ``scaling_policy`` picks the controller:
    ``"usl"`` (predictive, needs the fitted ``usl_sigma/kappa/gamma`` from
    a characterization sweep), ``"usl_online"`` (predictive + online
    re-fitting: an ``OnlineUSLEstimator`` re-fits the model from the
    loop's own observations every ``refit_interval_s``, over a sliding
    ``refit_window`` of capacity-limited samples recency-weighted with
    half-life ``refit_half_life_s``), ``"reactive"`` (lag-threshold
    baseline) or ``"static"`` (no loop; ``static_partitions``, default the
    ceiling — static-peak provisioning).  ``policy`` remains the
    model-sharing consistency knob, as in ``StreamExperiment``.

    ``engine`` selects the clock: ``"sim"`` (virtual, simulated platforms)
    or ``"threaded"`` (wall clock: the threaded engine on the elastic
    local backend, per-message service time ``threaded_service_s`` —
    default ``1/usl_gamma``).  ``drift_t_s``/``drift_factor`` multiply the
    per-message compute cost by ``drift_factor`` from virtual/wall time
    ``drift_t_s`` on: the mid-run workload shift that makes a frozen
    characterization fit mispredict and the online re-fit earn its keep.
    """

    scaling_policy: str = "usl"        # usl | usl_online | reactive | static
    rate: dict = field(default_factory=lambda: dict(
        kind="step", base_hz=2.0, high_hz=12.0, t_step=40.0))
    horizon_s: float = 120.0
    initial_partitions: int = 2
    max_partitions: int = 16
    static_partitions: int | None = None
    usl_sigma: float | None = None     # fitted USL model for the predictive
    usl_kappa: float | None = None     # policy (from StreamInsight.fit_models)
    usl_gamma: float | None = None
    control_interval_s: float = 2.0
    slo_lag: int = 32
    catchup_horizon_s: float = 20.0
    stabilization_s: float = 60.0      # scale-down stabilization window
    headroom: float = 0.15
    scale_down_hysteresis: float = 0.25   # Autoscaler downscale band
    max_step_up: int | None = None     # per-tick scale-up slew limit
    migration_s_per_delta: float = 0.05
    points: int = 8000                 # message size knob (MS)
    centroids: int = 1024              # workload complexity knob (WC)
    memory_mb: int = 3008
    policy: str | None = None          # model-sharing consistency
    batch_max: int = 1
    seed: int = 0
    backend_attrs: dict = field(default_factory=dict)
    faults: dict | None = None         # FaultPlan spec (streaming.faults) —
                                       # failure semantics as a scenario axis
    max_retries: int = 2               # per-batch retry budget before poison
    retry_backoff_s: float = 0.0       # exponential-backoff base (0 = immediate)
    engine: str = "sim"                # sim | threaded (wall clock)
    drift_t_s: float | None = None     # per-message cost shifts at this time
    drift_factor: float = 1.0          # ... by this multiplier
    refit_interval_s: float = 10.0     # usl_online: seconds between re-fits
    refit_window: int = 128            # usl_online: sliding sample window
    refit_half_life_s: float = 45.0    # usl_online: recency-weight half-life
    threaded_service_s: float | None = None   # wall s/msg (None → 1/gamma)
    federation: dict | None = None     # machine="federated": member specs +
                                       # breaker/placement knobs (see
                                       # pilot.backends.federated)

    def cost_estimate(self) -> float:
        """Work estimate for the serial-vs-pooled auto-switch (same units
        as ``StreamExperiment``'s ``n_messages × points × centroids``)."""
        msgs = rate_program_from_spec(self.rate).mean_messages(0.0, self.horizon_s)
        return msgs * self.points * self.centroids


@dataclass
class AdaptationResult:
    """EILC report card for one adaptation cell."""

    experiment: AdaptationExperiment
    run_id: str
    slo_violations: int                # control ticks with lag > slo_lag
    ticks: int
    cost_integral: float               # ∫ allocation dt (capacity-seconds)
    scale_events: int
    produced: int
    processed: int
    throughput: float                  # completions/s over the whole run
    latency_px: dict                   # percentile summary of L^px
    alloc_trace: list                  # [[t, allocation], ...]
    lag_trace: list                    # [[t, lag], ...]
    final_allocation: int = 1
    drained: bool = True
    drain_s: float = 0.0               # time past the horizon to empty lag
    wall_virtual_s: float = 0.0
    des_events: int = 0
    refits: int = 0                    # online USL re-fits performed
    abandoned: int = 0                 # batches poisoned past the retry budget
    dup_delivered: int = 0             # redelivered messages settled idempotently
    faults_injected: int = 0           # FaultInjector events fired
    preemptions: int = 0               # capacity-revocation events
    fault_windows: int = 0             # control windows dirtied by faults
    lost: int = 0                      # appended - (processed+abandoned+dups)
    tick_error_log: list = field(default_factory=list)
                                       # last ≤16 [t, repr(exc)] tick failures
    member_ledger: list = field(default_factory=list)
                                       # federated runs: per-member report
                                       # cards (placement, breaker, cost)

    def record(self) -> dict:
        e = self.experiment
        return dict(machine=e.machine, scaling_policy=e.scaling_policy,
                    engine=e.engine,
                    rate_kind=e.rate.get("kind", "?"), horizon_s=e.horizon_s,
                    slo_violations=self.slo_violations, ticks=self.ticks,
                    violation_frac=self.slo_violations / max(self.ticks, 1),
                    cost_integral=self.cost_integral,
                    scale_events=self.scale_events, refits=self.refits,
                    produced=self.produced, processed=self.processed,
                    throughput=self.throughput,
                    latency_px_p95=self.latency_px.get("p95", float("nan")),
                    final_allocation=self.final_allocation,
                    drained=self.drained, drain_s=self.drain_s,
                    abandoned=self.abandoned, dup_delivered=self.dup_delivered,
                    faults_injected=self.faults_injected,
                    preemptions=self.preemptions,
                    fault_windows=self.fault_windows, lost=self.lost)


@dataclass
class AdaptationPlan:
    """One closed-loop run as *data*: the experiment plus execution flags.

    A plan is picklable and JSON-able (it rides the ``run_cells`` process
    pool and keys the ``ResultCache``), and ``run_plan`` is a pure function
    of it — a run is a value, not a script.  ``fast=True`` lets the runner
    take the vectorized serverless replay (``sim.batched``) when the cell
    qualifies; the result is bit-identical either way, so ``fast`` is an
    execution hint, not a semantic axis."""

    experiment: AdaptationExperiment
    fast: bool = True

    def __post_init__(self) -> None:
        if isinstance(self.experiment, dict):   # cache/JSON round-trip
            self.experiment = AdaptationExperiment(**self.experiment)

    def cost_estimate(self) -> float:
        """Work estimate for the ``run_cells`` serial-vs-pool auto-switch
        (a plan costs what its cell costs)."""
        return self.experiment.cost_estimate()


@dataclass
class AdaptationSummary:
    """Compact, trace-free report card of one adaptation cell.

    Everything fig8 tables and what-if reductions consume — violations,
    cost integral, fault ledger, refits, latency percentiles — and nothing
    sized O(events): no alloc/lag traces, no tick-error ring, no DES event
    counts.  This is the payload a fleet of pool workers ships back and
    the ``ResultCache`` memoizes for what-if plans."""

    experiment: AdaptationPlan
    slo_violations: int
    ticks: int
    cost_integral: float
    scale_events: int
    produced: int
    processed: int
    throughput: float
    latency_px: dict
    final_allocation: int = 1
    drained: bool = True
    drain_s: float = 0.0
    refits: int = 0
    abandoned: int = 0
    dup_delivered: int = 0
    faults_injected: int = 0
    preemptions: int = 0
    fault_windows: int = 0
    lost: int = 0
    member_ledger: list = field(default_factory=list)
    fast_path: bool = False            # vectorized replay taken?
    fallback_reason: str | None = None  # why it was not, if ``fast`` asked

    def record(self) -> dict:
        """Flat row for tables; excludes the execution-telemetry fields
        (``fast_path``/``fallback_reason``) so fast and scalar runs of the
        same plan produce *identical* rows."""
        e = self.experiment.experiment
        return dict(machine=e.machine, scaling_policy=e.scaling_policy,
                    engine=e.engine,
                    rate_kind=e.rate.get("kind", "?"), horizon_s=e.horizon_s,
                    seed=e.seed,
                    slo_violations=self.slo_violations, ticks=self.ticks,
                    violation_frac=self.slo_violations / max(self.ticks, 1),
                    cost_integral=self.cost_integral,
                    scale_events=self.scale_events, refits=self.refits,
                    produced=self.produced, processed=self.processed,
                    throughput=self.throughput,
                    latency_px_p95=self.latency_px.get("p95", float("nan")),
                    final_allocation=self.final_allocation,
                    drained=self.drained, drain_s=self.drain_s,
                    abandoned=self.abandoned, dup_delivered=self.dup_delivered,
                    faults_injected=self.faults_injected,
                    preemptions=self.preemptions,
                    fault_windows=self.fault_windows, lost=self.lost)


def summarize_adaptation(res: AdaptationResult, *,
                         plan: AdaptationPlan | None = None,
                         fast_path: bool = False,
                         fallback_reason: str | None = None) -> AdaptationSummary:
    """Compress a full ``AdaptationResult`` into an ``AdaptationSummary``
    (drop the traces, keep the report card)."""
    return AdaptationSummary(
        experiment=plan if plan is not None
        else AdaptationPlan(experiment=res.experiment),
        slo_violations=res.slo_violations, ticks=res.ticks,
        cost_integral=res.cost_integral, scale_events=res.scale_events,
        produced=res.produced, processed=res.processed,
        throughput=res.throughput, latency_px=dict(res.latency_px),
        final_allocation=res.final_allocation, drained=res.drained,
        drain_s=res.drain_s, refits=res.refits, abandoned=res.abandoned,
        dup_delivered=res.dup_delivered, faults_injected=res.faults_injected,
        preemptions=res.preemptions, fault_windows=res.fault_windows,
        lost=res.lost, member_ledger=list(res.member_ledger),
        fast_path=fast_path, fallback_reason=fallback_reason)


def run_plan(plan: AdaptationPlan | AdaptationExperiment,
             metrics: MetricRegistry | None = None) -> AdaptationSummary:
    """Execute one what-if plan → summary.  Pure and picklable: same
    signature contract as ``run_adaptation`` (so it slots into the
    ``run_cells`` cell-type registry), but returns the compact summary.

    With ``plan.fast`` set the qualifying serverless cells run on the
    vectorized replay (``sim.batched``) — bit-identical to the scalar DES
    by construction and tested — and every non-qualifying cell falls back
    to ``run_adaptation`` with the reason recorded on the summary (and
    logged by the fast path)."""
    if isinstance(plan, AdaptationExperiment):
        plan = AdaptationPlan(experiment=plan)
    reason = None
    if plan.fast:
        from repro.sim.batched import try_fast_adaptation
        summary, reason = try_fast_adaptation(plan)
        if summary is not None:
            return summary
    res = run_adaptation(plan.experiment, metrics)
    return summarize_adaptation(res, plan=plan, fast_path=False,
                                fallback_reason=reason)


def scaling_policy_spec(exp: AdaptationExperiment) -> dict:
    """The cell's controller as a JSON-able ``policy_from_spec`` spec.

    This is the declarative form a ``WhatIfDesign`` varies over (policy ×
    hyperparameter grids) and the form cache keys / pool workers see — the
    experiment's scattered controller knobs, gathered into one dict."""
    sp = exp.scaling_policy
    if sp in ("usl", "usl_online"):
        if None in (exp.usl_sigma, exp.usl_kappa, exp.usl_gamma):
            raise ValueError(
                "usl scaling policy needs usl_sigma/usl_kappa/usl_gamma "
                "(fit a characterization sweep first — StreamInsight.fit_models)")
        spec = dict(kind=sp, sigma=exp.usl_sigma, kappa=exp.usl_kappa,
                    gamma=exp.usl_gamma, headroom=exp.headroom,
                    max_partitions=exp.max_partitions,
                    scale_down_hysteresis=exp.scale_down_hysteresis,
                    catchup_horizon_s=exp.catchup_horizon_s,
                    downscale_lag=max(4, exp.slo_lag // 2),
                    stabilization_s=exp.stabilization_s,
                    max_step_up=exp.max_step_up)
        if sp == "usl_online":
            spec.update(refit_interval_s=exp.refit_interval_s,
                        refit_window=exp.refit_window,
                        refit_half_life_s=exp.refit_half_life_s)
        return spec
    if sp == "reactive":
        return dict(kind="reactive", hi_lag=exp.slo_lag,
                    lo_lag=max(1, exp.slo_lag // 8),
                    max_partitions=exp.max_partitions)
    if sp == "static":
        return dict(kind="static")
    raise ValueError(f"unknown scaling_policy {sp!r}")


def _make_scaling_policy(exp: AdaptationExperiment, initial: int):
    return policy_from_spec(scaling_policy_spec(exp), initial=initial)


def adaptation_profile_factory(exp: AdaptationExperiment, now_fn, alloc_fn):
    """Per-allocation cost-profile closure shared by ``run_adaptation`` and
    the what-if fast replay (``sim.batched``).

    Coherence peers track the LIVE allocation (``alloc_fn``), so scaling up
    genuinely buys (and pays for) more peers.  Keyed additionally on whether
    the drift has hit (``now_fn() >= drift_t_s``): from then on the
    per-message cost — compute AND model traffic — is multiplied by
    ``drift_factor``, as if the shared model grew mid-run.  On serverless
    (isolated containers) that shifts gamma; on HPC the scaled model bytes
    also ride the shared filesystem and the coherence fan-out, so sigma AND
    kappa drift — the true USL peak moves, and a frozen fit happily scales
    into what is now the retrograde region.

    One definition serves both execution paths so their float arithmetic
    cannot drift apart."""
    profiles: dict[tuple[int, bool], TaskProfile] = {}

    def profile_for(msgs) -> TaskProfile:
        n = alloc_fn()
        drifted = exp.drift_t_s is not None and now_fn() >= exp.drift_t_s
        prof = profiles.get((n, drifted))
        if prof is None:
            prof = KMeansStreamWorkload(
                points=exp.points, centroids=exp.centroids,
                policy=exp.effective_policy, n_partitions=n).profile()
            if drifted and exp.drift_factor != 1.0:
                f = exp.drift_factor
                prof = replace(prof,
                               flops=prof.flops * f,
                               serial_flops=prof.serial_flops * f,
                               read_bytes=prof.read_bytes * f,
                               write_bytes=prof.write_bytes * f)
            profiles[(n, drifted)] = prof
        return prof

    return profile_for


def _build_injector(exp: AdaptationExperiment, engine, broker, topic, pilot,
                    metrics: MetricRegistry, run_id: str):
    """Materialize the cell's fault axis (``exp.faults`` spec → seeded
    ``FaultInjector``), or ``None`` for a fault-free run."""
    if not exp.faults:
        return None
    plan = FaultPlan.from_spec(exp.faults, default_seed=exp.seed,
                               default_horizon_s=exp.horizon_s)
    return FaultInjector(plan, engine, broker, topic, pilot,
                         metrics=metrics, run_id=run_id)


def _fault_fields(engine, broker, topic, injector, loop) -> dict:
    """Failure-semantics columns of the report card.  ``lost`` is the
    at-least-once ledger residue: appends not settled as exactly-once
    processing, poison abandonment or idempotent duplicate absorption.
    Zero means nothing was lost; negative would mean double-counting."""
    core = engine.core
    settled = core.processed + core.abandoned + core.dup_delivered
    return dict(
        abandoned=core.abandoned,
        dup_delivered=core.dup_delivered,
        faults_injected=injector.injected if injector is not None else 0,
        preemptions=injector.preemptions if injector is not None else 0,
        fault_windows=loop.fault_windows,
        lost=broker.appended_total(topic) - settled,
    )


def run_adaptation(exp: AdaptationExperiment,
                   metrics: MetricRegistry | None = None) -> AdaptationResult:
    """Execute one closed-loop adaptation cell.

    ``exp.engine`` picks the clock: ``"sim"`` builds the same producer →
    broker → engine pipeline as ``run_experiment`` on the virtual clock,
    with the producer *open-loop* (the rate program is the externally
    imposed incoming data rate) and a ``ControlLoop`` periodically
    resizing the elastic backend, resharding the broker and repartitioning
    the engine — deterministic given ``exp.seed``, two runs of the same
    cell produce bit-identical traces.  ``"threaded"`` runs the identical
    control loop on the wall clock: threaded engine, elastic local
    backend, a real-time ticker thread (necessarily *not* bit-reproducible
    — it measures the real machine).
    """
    if exp.engine == "threaded":
        return _run_adaptation_threaded(exp, metrics)
    if exp.engine != "sim":
        raise ValueError(f"unknown engine {exp.engine!r}; "
                         "expected 'sim' or 'threaded'")
    metrics = metrics if metrics is not None else MetricRegistry()
    run_id = new_run_id(f"adapt-{exp.machine}-{exp.scaling_policy}")

    static_n = (exp.static_partitions if exp.static_partitions is not None
                else exp.max_partitions)
    initial = static_n if exp.scaling_policy == "static" else exp.initial_partitions
    initial = max(1, min(initial, exp.max_partitions))

    attrs = dict(exp.backend_attrs)
    if exp.machine == "federated":
        if not exp.federation:
            raise ValueError("machine='federated' needs a federation spec "
                             "(AdaptationExperiment.federation)")
        attrs["federation"] = exp.federation
    pcs = PilotComputeService(seed=exp.seed)
    pilot = pcs.submit_pilot(PilotDescription(
        resource=exp.resource_url, memory_mb=exp.memory_mb,
        partitions=initial, concurrency=initial,
        attrs=attrs))
    backend = pilot.backend
    sim = backend.sim

    broker = Broker()
    topic = "points"
    broker.create_topic(topic, initial)

    profile_for = adaptation_profile_factory(
        exp, lambda: sim.now, lambda: loop.allocation)
    workload = Workload(profile_for=profile_for, name="kmeans-adapt")

    if exp.machine in ("serverless", "federated"):
        # shard ceiling pre-provisioned: Kinesis resharding moves routing,
        # idle shards cost nothing in the ingest model.  A federation
        # fronts its members with the same partitioned ingest — member
        # choice is a routing decision behind the broker, not an ingest one
        ingest = PartitionIngest(sim, exp.max_partitions, bw_per_partition=1e6)
    else:
        ingest = SharedFsIngest(sim, backend.shared_resource(pilot, "fs"))

    wl_bytes = exp.points * POINT_BYTES

    def msg_factory(i: int):
        return (None, {"n_points": exp.points, "seed": exp.seed * 100003 + i},
                wl_bytes)

    program = rate_program_from_spec(exp.rate)
    cap = int(program.mean_messages(0.0, exp.horizon_s) * 2 + 1000)
    producer = SyntheticProducer(
        sim, broker, topic, msg_factory=msg_factory, n_messages=cap,
        run_id=run_id, metrics=metrics, rate_program=program,
        horizon_s=exp.horizon_s, ingest=ingest)
    engine = SimStreamingEngine(
        sim, broker, topic, pilot, workload, metrics, run_id,
        batch_max=exp.batch_max, max_retries=exp.max_retries,
        retry_backoff_s=exp.retry_backoff_s,
        is_input_complete=lambda: producer.done)
    injector = _build_injector(exp, engine, broker, topic, pilot,
                               metrics, run_id)
    loop = ControlLoop(
        engine, broker, topic, pilot,
        _make_scaling_policy(exp, initial),
        metrics=metrics, run_id=run_id, interval_s=exp.control_interval_s,
        slo_lag=exp.slo_lag,
        migration_s_per_delta=exp.migration_s_per_delta,
        fault_signal=injector.window_dirty if injector is not None else None)

    producer.start()
    engine.start()
    if injector is not None:
        injector.start()
    loop.start()
    max_virtual = exp.horizon_s * 6.0 + 600.0
    sim.run_until(t=sim.now + max_virtual, predicate=engine.is_finished)
    drained = engine.is_finished()
    loop.stop()

    lat_px = metrics.latencies(run_id, "append", "complete")
    wall = max(sim.now, 1e-9)
    result = AdaptationResult(
        experiment=exp,
        run_id=run_id,
        slo_violations=loop.slo_violations,
        ticks=loop.ticks,
        cost_integral=loop.cost_integral,
        scale_events=loop.scale_events,
        produced=producer.sent,
        processed=engine.core.processed,
        throughput=engine.core.processed / wall,
        latency_px=percentile_summary(lat_px),
        alloc_trace=metrics.series(f"{run_id}/alloc").tolist(),
        lag_trace=metrics.series(f"{run_id}/lag").tolist(),
        final_allocation=loop.allocation,
        drained=drained,
        drain_s=max(0.0, sim.now - exp.horizon_s),
        wall_virtual_s=sim.now,
        des_events=sim.events_processed,
        refits=loop.refit_events,
        tick_error_log=[[t, r] for t, r in loop.tick_error_log],
        member_ledger=(backend.member_ledger(pilot)
                       if hasattr(backend, "member_ledger") else []),
        **_fault_fields(engine, broker, topic, injector, loop),
    )
    pcs.close()
    return result


# ---------------------------------------------------------------------------
# wall-clock adaptation (threaded engine + elastic local backend)
# ---------------------------------------------------------------------------

class _WallClockProducer(threading.Thread):
    """Open-loop rate-program producer on the wall clock.

    The wall twin of ``SyntheticProducer``'s program mode: emits messages
    at r(t) relative to ``t0`` until ``horizon_s``, appending straight to
    the (clock-agnostic) broker — round-robin over the *active* partitions,
    so live resharding redirects new messages exactly as in the sim.
    Emission times are computed against the absolute schedule (sleep until
    ``t_next``), so append/processing jitter does not accumulate drift.
    """

    def __init__(self, broker: Broker, topic: str, program: RateProgram,
                 horizon_s: float, run_id: str, metrics: MetricRegistry,
                 t0: float, msg_bytes: int = 1000,
                 idle_resolution_s: float = 0.25) -> None:
        super().__init__(daemon=True, name="wall-producer")
        self.broker = broker
        self.topic = topic
        self.program = program
        self.horizon_s = horizon_s
        self.run_id = run_id
        self.metrics = metrics
        self.t0 = t0
        self.msg_bytes = msg_bytes
        self.idle_resolution_s = idle_resolution_s
        self.sent = 0
        self.done = False

    def run(self) -> None:
        rec_produce = self.metrics.recorder(self.run_id, "producer", "produce")
        rec_append = self.metrics.recorder(self.run_id, "broker", "append")
        i = 0
        t_next = 0.0                        # relative emission schedule
        while True:
            t_rel = time.perf_counter() - self.t0
            if t_rel >= self.horizon_s:
                break
            rate = self.program.rate(max(t_rel, t_next))
            if rate <= 1e-9:
                time.sleep(self.idle_resolution_s)
                continue
            if t_next >= self.horizon_s:
                break            # next emission falls past the horizon
            if t_next > t_rel:
                time.sleep(t_next - t_rel)
            msg_id = f"{self.run_id}/{i}"
            now_abs = time.perf_counter()
            rec_produce(now_abs, msg_id=msg_id)
            self.broker.append(self.topic, {"i": i}, ts=now_abs,
                               run_id=self.run_id, msg_id=msg_id,
                               size_bytes=self.msg_bytes)
            rec_append(now_abs, msg_id=msg_id)
            i += 1
            self.sent = i
            t_next = max(t_next, t_rel) + 1.0 / rate
        self.done = True


def _run_adaptation_threaded(exp: AdaptationExperiment,
                             metrics: MetricRegistry | None = None
                             ) -> AdaptationResult:
    """Execute one closed-loop adaptation cell on the wall clock.

    Same observe → decide → act loop, same policies, same report card as
    the sim path — but real time: the ``ThreadedStreamingEngine``'s ticker
    thread drives the ``ControlLoop``, the elastic ``local://`` backend
    grants capacity, and the workload *occupies a worker slot* for
    ``threaded_service_s`` wall seconds per message (default
    ``1/usl_gamma`` — the single-worker rate the fitted model implies),
    times ``drift_factor`` once ``drift_t_s`` passes.
    """
    metrics = metrics if metrics is not None else MetricRegistry()
    run_id = new_run_id(f"adapt-threaded-{exp.scaling_policy}")

    static_n = (exp.static_partitions if exp.static_partitions is not None
                else exp.max_partitions)
    initial = static_n if exp.scaling_policy == "static" else exp.initial_partitions
    initial = max(1, min(initial, exp.max_partitions))

    base_s = exp.threaded_service_s
    if base_s is None:
        base_s = 1.0 / exp.usl_gamma if exp.usl_gamma else 0.05

    pcs = PilotComputeService(seed=exp.seed)
    pilot = pcs.submit_pilot(PilotDescription(
        resource="local://", memory_mb=exp.memory_mb,
        partitions=exp.max_partitions, concurrency=exp.max_partitions,
        attrs=dict(exp.backend_attrs)))
    backend = pilot.backend
    backend.scale_to(pilot, initial)

    broker = Broker()
    topic = "points"
    broker.create_topic(topic, initial)

    t0 = time.perf_counter()

    def process(msgs) -> None:
        t_rel = time.perf_counter() - t0
        factor = (exp.drift_factor
                  if exp.drift_t_s is not None and t_rel >= exp.drift_t_s
                  else 1.0)
        time.sleep(base_s * factor * len(msgs))

    workload = Workload(fn=process, name="sleep-adapt")
    engine = ThreadedStreamingEngine(
        broker, topic, pilot, workload, metrics, run_id,
        batch_max=exp.batch_max, max_retries=exp.max_retries,
        retry_backoff_s=exp.retry_backoff_s, seed=exp.seed)
    injector = _build_injector(exp, engine, broker, topic, pilot,
                               metrics, run_id)
    loop = ControlLoop(
        engine, broker, topic, pilot,
        _make_scaling_policy(exp, initial),
        metrics=metrics, run_id=run_id, interval_s=exp.control_interval_s,
        slo_lag=exp.slo_lag,
        migration_s_per_delta=exp.migration_s_per_delta,
        fault_signal=injector.window_dirty if injector is not None else None)
    producer = _WallClockProducer(
        broker, topic, rate_program_from_spec(exp.rate), exp.horizon_s,
        run_id, metrics, t0, msg_bytes=exp.points * POINT_BYTES)

    engine.start()
    producer.start()
    if injector is not None:
        injector.start()
    loop.start()
    producer.join(timeout=exp.horizon_s + 30.0)
    drained = True
    try:
        engine.drain(producer.sent, timeout=exp.horizon_s * 2.0 + 60.0)
    except TimeoutError:
        drained = False
    end_rel = time.perf_counter() - t0
    loop.stop()
    engine.stop()
    if engine.ticker_error is not None:
        # a control tick raised on the ticker thread: the loop silently
        # stopped re-arming itself mid-run, so the traces/report card are
        # NOT a valid experiment — surface the failure instead
        pcs.close()
        raise RuntimeError(
            "control loop crashed mid-run on the ticker thread"
        ) from engine.ticker_error

    def _rel(trace: np.ndarray) -> list:
        out = trace.tolist()
        return [[t - t0, v] for t, v in out]

    lat_px = metrics.latencies(run_id, "append", "complete")
    result = AdaptationResult(
        experiment=exp,
        run_id=run_id,
        slo_violations=loop.slo_violations,
        ticks=loop.ticks,
        cost_integral=loop.cost_integral,
        scale_events=loop.scale_events,
        produced=producer.sent,
        processed=engine.core.processed,
        throughput=engine.core.processed / max(end_rel, 1e-9),
        latency_px=percentile_summary(lat_px),
        alloc_trace=_rel(metrics.series(f"{run_id}/alloc")),
        lag_trace=_rel(metrics.series(f"{run_id}/lag")),
        final_allocation=loop.allocation,
        drained=drained and producer.done,
        drain_s=max(0.0, end_rel - exp.horizon_s),
        wall_virtual_s=end_rel,
        des_events=0,
        refits=loop.refit_events,
        tick_error_log=[[t - t0, r] for t, r in loop.tick_error_log],
        **_fault_fields(engine, broker, topic, injector, loop),
    )
    pcs.close()
    return result


def run_experiment(exp: StreamExperiment, metrics: MetricRegistry | None = None,
                   ) -> ExperimentResult:
    metrics = metrics if metrics is not None else MetricRegistry()
    run_id = new_run_id(f"{exp.machine}-N{exp.partitions}")

    pcs = PilotComputeService(seed=exp.seed)
    pilot_desc = PilotDescription(
        resource=exp.resource_url,
        memory_mb=exp.memory_mb,
        partitions=exp.partitions,
        concurrency=exp.partitions,
        attrs=dict(exp.backend_attrs),
    )
    pilot = pcs.submit_pilot(pilot_desc)
    backend = pilot.backend
    sim = backend.sim

    broker = Broker()
    topic = "points"
    broker.create_topic(topic, exp.partitions)

    wl = KMeansStreamWorkload(points=exp.points, centroids=exp.centroids,
                              policy=exp.effective_policy,
                              n_partitions=exp.partitions)
    # the cell's cost profile is message-independent — compute it once
    # instead of rebuilding a TaskProfile per dispatched micro-batch
    profile = wl.profile()
    workload = Workload(profile_for=lambda msgs: profile, name="kmeans")

    # broker ingest path: Kinesis shard limits vs Kafka-on-Lustre
    if exp.machine == "serverless":
        ingest = PartitionIngest(sim, exp.partitions, bw_per_partition=1e6)
    else:
        ingest = SharedFsIngest(sim, backend.shared_resource(pilot, "fs"))

    def msg_factory(i: int):
        return (None, {"n_points": exp.points, "seed": exp.seed * 100003 + i},
                wl.msg_bytes)

    producer = SyntheticProducer(
        sim, broker, topic, msg_factory=msg_factory, n_messages=exp.n_messages,
        run_id=run_id, metrics=metrics,
        aimd=AIMD(rate_hz=2.0 * exp.partitions, hi_watermark=4 * exp.partitions,
                  lo_watermark=exp.partitions),
        ingest=ingest,
    )
    engine = SimStreamingEngine(
        sim, broker, topic, pilot, workload, metrics, run_id,
        batch_max=exp.batch_max,
        is_input_complete=lambda: producer.done,
    )

    producer.start()
    engine.start()
    engine.run_to_completion()

    lat_px = metrics.latencies(run_id, "append", "complete")
    lat_br = metrics.latencies(run_id, "produce", "append")
    runtimes = np.asarray([cu.runtime for cu in pilot.compute_units
                           if cu.state is State.DONE])
    result = ExperimentResult(
        experiment=exp,
        run_id=run_id,
        throughput=steady_state_throughput(metrics, run_id),
        latency_px=percentile_summary(lat_px),
        latency_br=percentile_summary(lat_br),
        runtime_summary=percentile_summary(runtimes),
        processed=engine.core.processed,
        failed=engine.core.failed_batches,
        retried=engine.core.retried,
        wall_virtual_s=sim.now,
        des_events=sim.events_processed,
    )
    pcs.close()
    return result
