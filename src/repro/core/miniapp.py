"""Streaming Mini-App: producer → broker → processing, end to end (paper §IV).

Composes the pilot backends, the broker, the backoff producer and the
streaming engine into the paper's benchmark harness.  A single
``StreamExperiment`` describes one cell of the paper's parameter space
(machine M, partitions N, message size MS, workload complexity WC, container
memory); ``run_experiment`` executes it on the virtual clock and returns the
measured throughput T^px and latencies L^px / L^br, traced per run-id.

K-Means cost model (paper §IV-B): messages carry ``points`` d=9 float32
points (≈37 B/point, matching the paper's 296 KB / 8,000 points); workload
complexity is the centroid count c ∈ [128, 8192].  The distance phase is
O(n·c·d); ``IMPL_OVERHEAD`` calibrates raw FLOPs to an effective
sklearn-MiniBatchKMeans rate (Python/numpy overhead ≈ 8×).

Adaptation mode (paper §V): ``AdaptationExperiment`` / ``run_adaptation``
run the same pipeline under an *open-loop* time-varying rate program with a
live ``ControlLoop`` (see ``core.autoscale``) elastically resizing the
backend, resharding the broker and repartitioning the engine mid-run —
returning allocation/lag traces, SLO violations and the ∫N dt cost
integral instead of a steady-state throughput point.

Model-sharing consistency policy (see DESIGN.md §2): the paper's measured
Dask sigma ∈ [0.6, 1.0] — "the peak scalability of the system is already
reached with a single partition" — is mechanically consistent only with the
partial_fit executing inside the shared-model critical section; that is the
``full_fit_locked`` default on HPC.  ``update_locked`` (distances computed
against a stale model outside the lock) is the beyond-paper optimization
StreamInsight recommends, and ``lock_free`` is the serverless behaviour
(S3 last-writer-wins).
"""

from __future__ import annotations

import math

from dataclasses import dataclass, field

import numpy as np

from repro.core.autoscale import (AutoscalePolicy, Autoscaler, ControlLoop,
                                  ReactiveLagPolicy, StaticPolicy,
                                  USLPredictivePolicy)
from repro.core.metrics import MetricRegistry, new_run_id, percentile_summary
from repro.core.usl import USLFit
from repro.pilot.api import (PilotComputeService, PilotDescription, State,
                             TaskProfile)
from repro.streaming.broker import Broker
from repro.streaming.engine import SimStreamingEngine, Workload
from repro.streaming.producer import (AIMD, PartitionIngest, SharedFsIngest,
                                      SyntheticProducer, rate_program_from_spec)

__all__ = ["StreamExperiment", "ExperimentResult", "KMeansStreamWorkload",
           "run_experiment", "AdaptationExperiment", "AdaptationResult",
           "run_adaptation", "default_consistency", "POINT_BYTES",
           "KMEANS_DIM"]


def default_consistency(machine: str) -> str:
    """Platform-default model-sharing consistency policy: S3 is
    last-writer-wins (lock-free), the shared filesystem serializes the
    full partial_fit (the paper's measured Dask behaviour)."""
    return "lock_free" if machine == "serverless" else "full_fit_locked"

KMEANS_DIM = 9          # 9 float32 dims + header ≈ 37 B/point (paper: 296 KB / 8,000 pts)
POINT_BYTES = 37
IMPL_OVERHEAD = 8.0     # sklearn/python effective-FLOPs calibration
SERIALIZE_FLOPS_PER_BYTE = 12.0   # pickle/unpickle cost of the model file


@dataclass
class KMeansStreamWorkload:
    """Maps (points, centroids, policy) to a mechanism-level TaskProfile."""

    points: int = 8000
    centroids: int = 1024
    dim: int = KMEANS_DIM
    policy: str = "full_fit_locked"   # | "update_locked" | "lock_free"
    n_partitions: int = 1

    @property
    def msg_bytes(self) -> int:
        return self.points * POINT_BYTES

    @property
    def model_bytes(self) -> float:
        return self.centroids * self.dim * 4.0

    def profile(self) -> TaskProfile:
        n, c, d = self.points, self.centroids, self.dim
        distance = 3.0 * n * c * d * IMPL_OVERHEAD
        update = (2.0 * n * c + 2.0 * n * d + 6.0 * c * d) * IMPL_OVERHEAD
        serialize = 2.0 * self.model_bytes * SERIALIZE_FLOPS_PER_BYTE
        decode = 2.0 * self.msg_bytes
        if self.policy == "full_fit_locked":
            parallel, serial = decode, distance + update + serialize
        elif self.policy == "update_locked":
            parallel, serial = decode + distance, update + serialize
        elif self.policy == "lock_free":
            parallel, serial = decode + distance + update + serialize, 0.0
        else:
            raise ValueError(f"unknown policy {self.policy!r}")
        return TaskProfile(
            flops=parallel,
            serial_flops=serial,
            read_bytes=self.model_bytes,
            write_bytes=self.model_bytes,
            msg_bytes=self.msg_bytes,
            coherence_peers=max(0, self.n_partitions - 1),
            memory_mb=max(64.0, (self.msg_bytes + 2 * self.model_bytes) / 1e6 * 3 + 40),
        )


@dataclass
class _PlatformCell:
    """Shared platform axis of every experiment cell: the machine plus its
    derived resource URL and consistency-policy default (subclasses declare
    the ``policy`` field this reads)."""

    machine: str = "serverless"         # serverless | wrangler | stampede2

    @property
    def resource_url(self) -> str:
        return ("serverless://aws-sim" if self.machine == "serverless"
                else f"hpc://{self.machine}-sim")

    @property
    def effective_policy(self) -> str:
        if self.policy is not None:
            return self.policy
        return default_consistency(self.machine)


@dataclass
class StreamExperiment(_PlatformCell):
    """One cell of the paper's parameter space."""

    partitions: int = 4                 # N^px(p) == N^br(p) (paper constraint)
    points: int = 8000                  # message size knob (MS)
    centroids: int = 1024               # workload complexity knob (WC)
    memory_mb: int = 3008               # Lambda container memory
    n_messages: int = 200
    policy: str | None = None           # None → platform default
    seed: int = 0
    batch_max: int = 1                  # paper: one Lambda invocation per message
    backend_attrs: dict = field(default_factory=dict)


@dataclass
class ExperimentResult:
    experiment: StreamExperiment
    run_id: str
    throughput: float                  # msgs/s, steady-state window
    latency_px: dict                   # percentile summary of L^px
    latency_br: dict                   # percentile summary of L^br
    runtime_summary: dict              # per-task service times
    processed: int = 0
    failed: int = 0
    retried: int = 0
    wall_virtual_s: float = 0.0
    des_events: int = 0                # Simulator events consumed by this cell

    def record(self) -> dict:
        e = self.experiment
        return dict(machine=e.machine, partitions=e.partitions, points=e.points,
                    centroids=e.centroids, memory_mb=e.memory_mb,
                    policy=e.effective_policy, batch_max=e.batch_max,
                    throughput=self.throughput,
                    latency_px_p50=self.latency_px.get("p50", float("nan")),
                    latency_px_mean=self.latency_px.get("mean", float("nan")),
                    latency_px_std=self.latency_px.get("std", float("nan")),
                    latency_br_p50=self.latency_br.get("p50", float("nan")),
                    task_p50=self.runtime_summary.get("p50", float("nan")),
                    processed=self.processed, failed=self.failed)


def steady_state_throughput(metrics: MetricRegistry, run_id: str,
                            warmup_frac: float = 0.25) -> float:
    """Completions/sec over the post-warmup window (max sustained throughput).

    Thin wrapper over the registry's vectorized implementation, kept for
    API compatibility."""
    return metrics.steady_state_throughput(run_id, "complete",
                                           warmup_frac=warmup_frac)


# ---------------------------------------------------------------------------
# adaptation experiments (EILC): characterize -> model -> *adapt*
# ---------------------------------------------------------------------------

@dataclass
class AdaptationExperiment(_PlatformCell):
    """One closed-loop elastic-scaling cell: a rate trace in, allocation and
    lag traces + SLO violations + cost integral out.

    ``rate`` is a JSON-able rate-program spec (see
    ``streaming.producer.rate_program_from_spec``) — rate traces are a
    first-class design axis, like partitions or message size in
    ``StreamExperiment``.  ``scaling_policy`` picks the controller:
    ``"usl"`` (predictive, needs the fitted ``usl_sigma/kappa/gamma`` from
    a characterization sweep), ``"reactive"`` (lag-threshold baseline) or
    ``"static"`` (no loop; ``static_partitions``, default the ceiling —
    static-peak provisioning).  ``policy`` remains the model-sharing
    consistency knob, as in ``StreamExperiment``.
    """

    scaling_policy: str = "usl"        # usl | reactive | static
    rate: dict = field(default_factory=lambda: dict(
        kind="step", base_hz=2.0, high_hz=12.0, t_step=40.0))
    horizon_s: float = 120.0
    initial_partitions: int = 2
    max_partitions: int = 16
    static_partitions: int | None = None
    usl_sigma: float | None = None     # fitted USL model for the predictive
    usl_kappa: float | None = None     # policy (from StreamInsight.fit_models)
    usl_gamma: float | None = None
    control_interval_s: float = 2.0
    slo_lag: int = 32
    catchup_horizon_s: float = 20.0
    stabilization_s: float = 60.0      # scale-down stabilization window
    headroom: float = 0.15
    migration_s_per_delta: float = 0.05
    points: int = 8000                 # message size knob (MS)
    centroids: int = 1024              # workload complexity knob (WC)
    memory_mb: int = 3008
    policy: str | None = None          # model-sharing consistency
    batch_max: int = 1
    seed: int = 0
    backend_attrs: dict = field(default_factory=dict)

    def cost_estimate(self) -> float:
        """Work estimate for the serial-vs-pooled auto-switch (same units
        as ``StreamExperiment``'s ``n_messages × points × centroids``)."""
        msgs = rate_program_from_spec(self.rate).mean_messages(0.0, self.horizon_s)
        return msgs * self.points * self.centroids


@dataclass
class AdaptationResult:
    """EILC report card for one adaptation cell."""

    experiment: AdaptationExperiment
    run_id: str
    slo_violations: int                # control ticks with lag > slo_lag
    ticks: int
    cost_integral: float               # ∫ allocation dt (capacity-seconds)
    scale_events: int
    produced: int
    processed: int
    throughput: float                  # completions/s over the whole run
    latency_px: dict                   # percentile summary of L^px
    alloc_trace: list                  # [[t, allocation], ...]
    lag_trace: list                    # [[t, lag], ...]
    final_allocation: int = 1
    drained: bool = True
    drain_s: float = 0.0               # time past the horizon to empty lag
    wall_virtual_s: float = 0.0
    des_events: int = 0

    def record(self) -> dict:
        e = self.experiment
        return dict(machine=e.machine, scaling_policy=e.scaling_policy,
                    rate_kind=e.rate.get("kind", "?"), horizon_s=e.horizon_s,
                    slo_violations=self.slo_violations, ticks=self.ticks,
                    violation_frac=self.slo_violations / max(self.ticks, 1),
                    cost_integral=self.cost_integral,
                    scale_events=self.scale_events,
                    produced=self.produced, processed=self.processed,
                    throughput=self.throughput,
                    latency_px_p95=self.latency_px.get("p95", float("nan")),
                    final_allocation=self.final_allocation,
                    drained=self.drained, drain_s=self.drain_s)


def _make_scaling_policy(exp: AdaptationExperiment, initial: int):
    if exp.scaling_policy == "usl":
        if None in (exp.usl_sigma, exp.usl_kappa, exp.usl_gamma):
            raise ValueError(
                "usl scaling policy needs usl_sigma/usl_kappa/usl_gamma "
                "(fit a characterization sweep first — StreamInsight.fit_models)")
        fit = USLFit(sigma=exp.usl_sigma, kappa=exp.usl_kappa,
                     gamma=exp.usl_gamma, r2=1.0, rmse=0.0, n_obs=0)
        scaler = Autoscaler(fit, AutoscalePolicy(
            headroom=exp.headroom, max_partitions=exp.max_partitions,
            min_partitions=1), current=initial)
        return USLPredictivePolicy(scaler,
                                   catchup_horizon_s=exp.catchup_horizon_s,
                                   downscale_lag=max(4, exp.slo_lag // 2),
                                   stabilization_s=exp.stabilization_s)
    if exp.scaling_policy == "reactive":
        return ReactiveLagPolicy(hi_lag=exp.slo_lag,
                                 lo_lag=max(1, exp.slo_lag // 8),
                                 min_partitions=1,
                                 max_partitions=exp.max_partitions)
    if exp.scaling_policy == "static":
        return StaticPolicy(initial)
    raise ValueError(f"unknown scaling_policy {exp.scaling_policy!r}")


def run_adaptation(exp: AdaptationExperiment,
                   metrics: MetricRegistry | None = None) -> AdaptationResult:
    """Execute one closed-loop adaptation cell on the virtual clock.

    Builds the same producer → broker → engine pipeline as
    ``run_experiment``, but the producer is *open-loop* (the rate program is
    the externally imposed incoming data rate) and a ``ControlLoop``
    periodically resizes the elastic backend, reshards the broker and
    repartitions the engine.  Deterministic given ``exp.seed`` — two runs
    of the same cell produce bit-identical traces.
    """
    metrics = metrics if metrics is not None else MetricRegistry()
    run_id = new_run_id(f"adapt-{exp.machine}-{exp.scaling_policy}")

    static_n = (exp.static_partitions if exp.static_partitions is not None
                else exp.max_partitions)
    initial = static_n if exp.scaling_policy == "static" else exp.initial_partitions
    initial = max(1, min(initial, exp.max_partitions))

    pcs = PilotComputeService(seed=exp.seed)
    pilot = pcs.submit_pilot(PilotDescription(
        resource=exp.resource_url, memory_mb=exp.memory_mb,
        partitions=initial, concurrency=initial,
        attrs=dict(exp.backend_attrs)))
    backend = pilot.backend
    sim = backend.sim

    broker = Broker()
    topic = "points"
    broker.create_topic(topic, initial)

    # per-allocation cost profiles: coherence peers track the LIVE
    # allocation, so scaling up genuinely buys (and pays for) more peers
    profiles: dict[int, TaskProfile] = {}

    def profile_for(msgs) -> TaskProfile:
        n = loop.allocation
        prof = profiles.get(n)
        if prof is None:
            prof = profiles[n] = KMeansStreamWorkload(
                points=exp.points, centroids=exp.centroids,
                policy=exp.effective_policy, n_partitions=n).profile()
        return prof

    workload = Workload(profile_for=profile_for, name="kmeans-adapt")

    if exp.machine == "serverless":
        # shard ceiling pre-provisioned: Kinesis resharding moves routing,
        # idle shards cost nothing in the ingest model
        ingest = PartitionIngest(sim, exp.max_partitions, bw_per_partition=1e6)
    else:
        ingest = SharedFsIngest(sim, backend.shared_resource(pilot, "fs"))

    wl_bytes = exp.points * POINT_BYTES

    def msg_factory(i: int):
        return (None, {"n_points": exp.points, "seed": exp.seed * 100003 + i},
                wl_bytes)

    program = rate_program_from_spec(exp.rate)
    cap = int(program.mean_messages(0.0, exp.horizon_s) * 2 + 1000)
    producer = SyntheticProducer(
        sim, broker, topic, msg_factory=msg_factory, n_messages=cap,
        run_id=run_id, metrics=metrics, rate_program=program,
        horizon_s=exp.horizon_s, ingest=ingest)
    engine = SimStreamingEngine(
        sim, broker, topic, pilot, workload, metrics, run_id,
        batch_max=exp.batch_max, is_input_complete=lambda: producer.done)
    loop = ControlLoop(
        sim, broker, topic, engine, pilot,
        _make_scaling_policy(exp, initial),
        metrics=metrics, run_id=run_id, interval_s=exp.control_interval_s,
        slo_lag=exp.slo_lag,
        migration_s_per_delta=exp.migration_s_per_delta)

    producer.start()
    engine.start()
    loop.start()
    max_virtual = exp.horizon_s * 6.0 + 600.0
    sim.run_until(t=sim.now + max_virtual, predicate=engine.is_finished)
    drained = engine.is_finished()
    loop.stop()

    lat_px = metrics.latencies(run_id, "append", "complete")
    wall = max(sim.now, 1e-9)
    result = AdaptationResult(
        experiment=exp,
        run_id=run_id,
        slo_violations=loop.slo_violations,
        ticks=loop.ticks,
        cost_integral=loop.cost_integral,
        scale_events=loop.scale_events,
        produced=producer.sent,
        processed=engine.core.processed,
        throughput=engine.core.processed / wall,
        latency_px=percentile_summary(lat_px),
        alloc_trace=metrics.series(f"{run_id}/alloc").tolist(),
        lag_trace=metrics.series(f"{run_id}/lag").tolist(),
        final_allocation=loop.allocation,
        drained=drained,
        drain_s=max(0.0, sim.now - exp.horizon_s),
        wall_virtual_s=sim.now,
        des_events=sim.events_processed,
    )
    pcs.close()
    return result


def run_experiment(exp: StreamExperiment, metrics: MetricRegistry | None = None,
                   ) -> ExperimentResult:
    metrics = metrics if metrics is not None else MetricRegistry()
    run_id = new_run_id(f"{exp.machine}-N{exp.partitions}")

    pcs = PilotComputeService(seed=exp.seed)
    pilot_desc = PilotDescription(
        resource=exp.resource_url,
        memory_mb=exp.memory_mb,
        partitions=exp.partitions,
        concurrency=exp.partitions,
        attrs=dict(exp.backend_attrs),
    )
    pilot = pcs.submit_pilot(pilot_desc)
    backend = pilot.backend
    sim = backend.sim

    broker = Broker()
    topic = "points"
    broker.create_topic(topic, exp.partitions)

    wl = KMeansStreamWorkload(points=exp.points, centroids=exp.centroids,
                              policy=exp.effective_policy,
                              n_partitions=exp.partitions)
    # the cell's cost profile is message-independent — compute it once
    # instead of rebuilding a TaskProfile per dispatched micro-batch
    profile = wl.profile()
    workload = Workload(profile_for=lambda msgs: profile, name="kmeans")

    # broker ingest path: Kinesis shard limits vs Kafka-on-Lustre
    if exp.machine == "serverless":
        ingest = PartitionIngest(sim, exp.partitions, bw_per_partition=1e6)
    else:
        ingest = SharedFsIngest(sim, backend.shared_resource(pilot, "fs"))

    def msg_factory(i: int):
        return (None, {"n_points": exp.points, "seed": exp.seed * 100003 + i},
                wl.msg_bytes)

    producer = SyntheticProducer(
        sim, broker, topic, msg_factory=msg_factory, n_messages=exp.n_messages,
        run_id=run_id, metrics=metrics,
        aimd=AIMD(rate_hz=2.0 * exp.partitions, hi_watermark=4 * exp.partitions,
                  lo_watermark=exp.partitions),
        ingest=ingest,
    )
    engine = SimStreamingEngine(
        sim, broker, topic, pilot, workload, metrics, run_id,
        batch_max=exp.batch_max,
        is_input_complete=lambda: producer.done,
    )

    producer.start()
    engine.start()
    engine.run_to_completion()

    lat_px = metrics.latencies(run_id, "append", "complete")
    lat_br = metrics.latencies(run_id, "produce", "append")
    runtimes = np.asarray([cu.runtime for cu in pilot.compute_units
                           if cu.state is State.DONE])
    result = ExperimentResult(
        experiment=exp,
        run_id=run_id,
        throughput=steady_state_throughput(metrics, run_id),
        latency_px=percentile_summary(lat_px),
        latency_br=percentile_summary(lat_br),
        runtime_summary=percentile_summary(runtimes),
        processed=engine.core.processed,
        failed=engine.core.failed_batches,
        retried=engine.core.retried,
        wall_virtual_s=sim.now,
        des_events=sim.events_processed,
    )
    pcs.close()
    return result
