"""USL-driven predictive autoscaler (the paper's §V future work, implemented).

"We will integrate StreamInsight into the resource management algorithm of
Pilot-Streaming so as to support predictive scaling, viz., the ability to
adapt the resource allocations and configurations to changes in the incoming
data rate(s)."

Given a fitted USL model for a scenario, the autoscaler answers:

* ``partitions_for(target_rate)`` — the smallest N whose predicted
  throughput sustains the incoming rate (with headroom), clamped at the
  USL peak: beyond N* adding partitions *reduces* throughput, so the
  autoscaler never scales into the retrograde region.
* ``max_sustainable_rate()`` — the peak throughput; incoming rates above it
  require throttling the source (the paper's "determination of the amount
  of throttling of data sources to guarantee processing").
* ``plan(rate_series)`` — partition counts tracking a time-varying rate,
  with hysteresis to avoid flapping.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.usl import USLFit

__all__ = ["AutoscalePolicy", "Autoscaler"]


@dataclass
class AutoscalePolicy:
    headroom: float = 0.15         # fraction of spare capacity to keep
    max_partitions: int = 256
    scale_down_hysteresis: float = 0.25   # rate must drop this much to downscale
    min_partitions: int = 1


class Autoscaler:
    def __init__(self, fit: USLFit, policy: AutoscalePolicy | None = None) -> None:
        self.fit = fit
        self.policy = policy or AutoscalePolicy()
        self._current = self.policy.min_partitions

    # -- pure queries ----------------------------------------------------------
    def usable_peak_n(self) -> int:
        peak = self.fit.peak_n
        cap = self.policy.max_partitions
        if math.isinf(peak):
            return cap
        return max(self.policy.min_partitions, min(cap, int(math.floor(peak))))

    def max_sustainable_rate(self) -> float:
        n = self.usable_peak_n()
        return float(self.fit.predict(n))

    def partitions_for(self, target_rate: float) -> int | None:
        """Smallest N sustaining ``target_rate`` (incl. headroom); None if the
        rate exceeds the system's peak → caller must throttle the source."""
        need = target_rate * (1.0 + self.policy.headroom)
        hi = self.usable_peak_n()
        ns = np.arange(self.policy.min_partitions, hi + 1, dtype=np.float64)
        pred = self.fit.predict(ns)
        ok = np.nonzero(pred >= need)[0]
        if ok.size == 0:
            return None
        return int(ns[ok[0]])

    def throttle_rate(self, incoming_rate: float) -> float:
        """Admissible source rate (paper: "amount of throttling of data
        sources to guarantee processing")."""
        return min(incoming_rate, self.max_sustainable_rate() / (1.0 + self.policy.headroom))

    # -- stateful planning -------------------------------------------------------
    def step(self, observed_rate: float) -> int:
        """Hysteresis-stabilized partition recommendation for the next window."""
        want = self.partitions_for(observed_rate)
        if want is None:
            want = self.usable_peak_n()
        if want > self._current:
            self._current = want                     # scale up promptly
        elif want < self._current:
            # only scale down if the needed capacity dropped well below current
            cur_rate = float(self.fit.predict(self._current))
            if observed_rate < cur_rate * (1.0 - self.policy.scale_down_hysteresis):
                self._current = want
        return self._current

    def plan(self, rate_series) -> list[int]:
        return [self.step(float(r)) for r in rate_series]
