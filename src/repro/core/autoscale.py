"""USL-driven autoscaling: offline planner AND live closed control loop.

The paper's §V future work — "we will integrate StreamInsight into the
resource management algorithm of Pilot-Streaming so as to support predictive
scaling, viz., the ability to adapt the resource allocations and
configurations to changes in the incoming data rate(s)" — implemented in two
layers:

**Offline planner** (``Autoscaler``): given a fitted USL model for a
scenario it answers

* ``partitions_for(target_rate)`` — the smallest N whose predicted
  throughput sustains the incoming rate (with headroom), clamped at the
  USL peak: beyond N* adding partitions *reduces* throughput, so the
  autoscaler never scales into the retrograde region.
* ``max_sustainable_rate()`` — the peak throughput; incoming rates above it
  require throttling the source (the paper's "determination of the amount
  of throttling of data sources to guarantee processing").
* ``plan(rate_series)`` — partition counts tracking a time-varying rate,
  with hysteresis to avoid flapping.

**Live closed loop** (``ControlLoop``): a periodic control tick that
*observes* broker lag and windowed arrival/completion rates (O(1) counter
deltas from the columnar ``MetricRegistry`` and the broker), *decides* a
target allocation through a pluggable policy — ``USLPredictivePolicy``
(the paper's predictive scaling: model-inverted partition counts with
hysteresis and peak clamping) or the ``ReactiveLagPolicy`` baseline (scale
on lag watermarks, knowledge-free) — and *acts* by scaling the elastic
pilot backend (``Backend.scale_to``), resharding the broker
(``Broker.repartition``) and repartitioning the engine with a
state-migration cost event.  Per-run it accumulates the EILC report card:
allocation/lag traces, SLO-violation ticks and the allocation cost
integral ∫N dt.

The loop is *clock-agnostic*: it drives itself through the small
``EngineControlSurface`` protocol (``now()`` / ``call_later()`` /
``repartition()``) that both streaming engines implement, so the same
controller code runs as a periodic DES event on the virtual clock
(``SimStreamingEngine``) and as a real-time ticker thread on the wall
clock (``ThreadedStreamingEngine``).

**Online re-fitting** (``OnlineUSLEstimator``): the predictive policy can
*learn while it runs*.  The estimator accumulates (granted allocation N,
observed windowed completion rate) pairs from the control loop's own
observations — only capacity-limited windows (backlog present) count, an
idle system's completion rate is its arrival rate, not its capacity — and
periodically re-fits (sigma, kappa, gamma) through the batched fitter with
recency-decayed observation weights, warm-started from the previous fit
(``fit_usl_batch(seed_params=...)``).  Prior anchor rows synthesized from
the characterization fit regularize the refit while live evidence is thin
and fade automatically as observations accumulate.  The result: the policy
inverts a model that tracks drift (e.g. a workload whose per-message cost
shifts mid-run) instead of a model frozen at characterization time.
"""

from __future__ import annotations

import math
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Protocol, runtime_checkable

import numpy as np

from repro.core.usl import USLFit, fit_usl_batch

__all__ = ["AutoscalePolicy", "Autoscaler", "ControlObservation",
           "USLPredictivePolicy", "ReactiveLagPolicy", "StaticPolicy",
           "ControlLoop", "OnlineUSLEstimator", "EngineControlSurface",
           "policy_from_spec"]


@dataclass
class AutoscalePolicy:
    headroom: float = 0.15         # fraction of spare capacity to keep
    max_partitions: int = 256
    scale_down_hysteresis: float = 0.25   # rate must drop this much to downscale
    min_partitions: int = 1


class Autoscaler:
    def __init__(self, fit: USLFit, policy: AutoscalePolicy | None = None,
                 current: int | None = None) -> None:
        self.fit = fit
        self.policy = policy or AutoscalePolicy()
        self._current = (self.policy.min_partitions if current is None
                         else max(self.policy.min_partitions, int(current)))

    @property
    def current(self) -> int:
        """The planner's current allocation (the hysteresis reference)."""
        return self._current

    @current.setter
    def current(self, n: int) -> None:
        self._current = max(self.policy.min_partitions, int(n))

    # -- pure queries ----------------------------------------------------------
    def usable_peak_n(self) -> int:
        peak = self.fit.peak_n
        cap = self.policy.max_partitions
        if math.isinf(peak):
            return cap
        return max(self.policy.min_partitions, min(cap, int(math.floor(peak))))

    def max_sustainable_rate(self) -> float:
        n = self.usable_peak_n()
        return float(self.fit.predict(n))

    def partitions_for(self, target_rate: float) -> int | None:
        """Smallest N sustaining ``target_rate`` (incl. headroom); None if the
        rate exceeds the system's peak → caller must throttle the source."""
        need = target_rate * (1.0 + self.policy.headroom)
        hi = self.usable_peak_n()
        ns = np.arange(self.policy.min_partitions, hi + 1, dtype=np.float64)
        pred = self.fit.predict(ns)
        ok = np.nonzero(pred >= need)[0]
        if ok.size == 0:
            return None
        return int(ns[ok[0]])

    def throttle_rate(self, incoming_rate: float) -> float:
        """Admissible source rate (paper: "amount of throttling of data
        sources to guarantee processing")."""
        return min(incoming_rate, self.max_sustainable_rate() / (1.0 + self.policy.headroom))

    # -- stateful planning -------------------------------------------------------
    def step(self, observed_rate: float) -> int:
        """Hysteresis-stabilized partition recommendation for the next window."""
        peak = self.usable_peak_n()
        if self._current > peak:
            # beyond the peak every extra partition *subtracts* capacity:
            # retreating to the peak strictly raises predicted throughput,
            # so no hysteresis (or backlog hold) applies.  This matters
            # when the model is re-fitted online — a learned kappa can
            # move the peak below an allocation made under the stale fit.
            self._current = peak
        want = self.partitions_for(observed_rate)
        if want is None:
            want = peak
        if want > self._current:
            self._current = want                     # scale up promptly
        elif want < self._current:
            # only scale down if the needed capacity dropped well below current
            cur_rate = float(self.fit.predict(self._current))
            if observed_rate < cur_rate * (1.0 - self.policy.scale_down_hysteresis):
                self._current = want
        return self._current

    def plan(self, rate_series) -> list[int]:
        return [self.step(float(r)) for r in rate_series]


# ---------------------------------------------------------------------------
# live closed loop (EILC): observe -> decide -> act, as a periodic control tick
# ---------------------------------------------------------------------------

@runtime_checkable
class EngineControlSurface(Protocol):
    """The engine-facing surface the control loop drives itself through.

    Both streaming engines implement it: ``SimStreamingEngine`` maps
    ``now``/``call_later`` onto its ``Simulator`` (the loop is a periodic
    DES event), ``ThreadedStreamingEngine`` onto the wall clock and a
    real-time ticker thread.  ``repartition`` makes the engine adopt the
    broker's current partition count, charging ``migration_s`` of paused
    dispatch as the keyed-state migration cost.
    """

    def now(self) -> float:
        """Current time on the engine's clock (virtual or wall seconds)."""
        ...  # pragma: no cover - protocol

    def call_later(self, delay_s: float, fn: Callable[[], None]) -> None:
        """Run ``fn`` ``delay_s`` seconds from now on the engine's clock."""
        ...  # pragma: no cover - protocol

    def repartition(self, migration_s: float = 0.0) -> None:
        """Adopt the broker's current partition count mid-run."""
        ...  # pragma: no cover - protocol


@dataclass
class ControlObservation:
    """What a control tick sees: the backpressure signal plus windowed
    rates (counter deltas over the last control interval).

    ``lag`` is *end-to-end* outstanding work (produced − completed): it
    includes messages still queued in the ingest path, not only
    appended-but-uncommitted broker lag — per-shard ingest limits mean the
    broker itself can be the bottleneck, and a controller watching only
    consumer lag is blind to that backlog.

    ``effective_allocation`` is the capacity actually *granted* right now,
    as opposed to the target: an HPC worker grown mid-run sits in the batch
    queue for ``grant_delay_s`` before it runs anything.  The online
    estimator attributes observed rates to the granted N — attributing a
    window served by 4 live workers to a target of 8 would poison the fit.
    ``None`` means "same as allocation" (filled in by ``__post_init__``)."""

    t: float
    lag: int                   # produced-but-not-completed messages
    arrival_rate: float        # msgs/s offered (produced) over the last window
    completion_rate: float     # msgs/s completed over the last window
    allocation: int            # current target capacity
    effective_allocation: int | None = None   # granted capacity
    window_stable: bool = True  # granted capacity unchanged across the window

    def __post_init__(self) -> None:
        if self.effective_allocation is None:
            self.effective_allocation = self.allocation


class OnlineUSLEstimator:
    """Re-fit the USL from the control loop's own observations.

    Closes the loop one level higher than PR 4: instead of inverting a
    model frozen at characterization time, the predictive policy hands each
    control observation to this estimator, which

    * records (granted N, windowed completion rate) pairs — but only
      windows that actually measure capacity.  A window is *saturated*
      when the backlog clearly exceeds the in-flight ceiling
      (``lag >= max(busy_lag, saturation_factor * N)``): messages are
      queued behind every worker, so the completion rate IS the capacity
      at N — an equality sample.  An unsaturated window only proves
      capacity ≥ rate (the consumer kept up with the offered load); such
      lower bounds are recorded only when they *beat* the current model's
      prediction — evidence the model underestimates (e.g. per-message
      cost drifted down) — and are discarded otherwise, because treating
      "keeping up" as "at capacity" drags gamma down and ratchets the
      allocation up in a self-confirming spiral;
    * keeps a sliding ``window`` of the most recent samples and weights
      them by recency — weight ``0.5 ** (age / half_life_s)`` — so after a
      drift the stale pre-drift evidence fades on a known time constant;
    * every ``refit_interval_s`` re-fits (sigma, kappa, gamma) through
      ``fit_usl_batch``, warm-started from the previous fit
      (``seed_params``) so a refit pays only the LM polish, plus
      ``anchor_levels`` prior rows predicted by the *characterization* fit
      at weight ``prior_weight * min(1, min_obs / n_obs)`` each — the
      prior regularizes the fit while live evidence is thin, and its mass
      shrinks as observations accumulate so a genuinely drifted system is
      not forever dragged back toward the stale characterization.

    ``fit`` always holds the current best model; ``refit``/``maybe_refit``
    update it in place and return it.
    """

    def __init__(self, prior_fit: USLFit, *,
                 refit_interval_s: float = 10.0,
                 window: int = 128,
                 half_life_s: float = 45.0,
                 min_obs: int = 6,
                 busy_lag: int = 4,
                 saturation_factor: float = 2.0,
                 prior_weight: float = 0.5,
                 anchor_levels: tuple = (1, 2, 4, 8, 16),
                 max_iter: int = 60) -> None:
        if window < 2:
            raise ValueError("window must hold at least 2 observations")
        self.prior_fit = prior_fit
        self.fit = prior_fit
        self.refit_interval_s = float(refit_interval_s)
        self.half_life_s = float(half_life_s)
        self.min_obs = int(min_obs)
        self.busy_lag = int(busy_lag)
        self.saturation_factor = float(saturation_factor)
        self.prior_weight = float(prior_weight)
        self.anchor_levels = tuple(anchor_levels)
        self.max_iter = int(max_iter)
        self._ts: deque[float] = deque(maxlen=window)
        self._ns: deque[float] = deque(maxlen=window)
        self._rates: deque[float] = deque(maxlen=window)
        self._last_refit_t: float | None = None
        self.refits = 0
        self.rejected = 0                  # windows that measure no capacity
        self.last_refit_wall_s = 0.0

    def __len__(self) -> int:
        return len(self._ts)

    @property
    def observations(self) -> list[tuple[float, float, float]]:
        """Recorded (t, N, rate) samples, oldest first."""
        return list(zip(self._ts, self._ns, self._rates))

    def observe(self, t: float, n: float, rate: float, lag: int) -> bool:
        """Record one windowed observation; returns whether it was kept.

        Saturated windows (queue clearly deeper than the in-flight
        ceiling) are equality samples of capacity at N.  Unsaturated
        windows only bound capacity from below and are kept solely when
        they exceed the current model's prediction at N — see the class
        docstring for why admitting them unconditionally poisons the fit.
        """
        if n < 1 or rate <= 0.0:
            self.rejected += 1
            return False
        saturated = lag >= max(self.busy_lag, self.saturation_factor * n)
        if not saturated and rate <= float(self.fit.predict(n)):
            self.rejected += 1
            return False
        self._ts.append(float(t))
        self._ns.append(float(n))
        self._rates.append(float(rate))
        return True

    def observation_weights(self, now: float) -> np.ndarray:
        """Recency weights for the current window: ``0.5 ** (age/half_life)``
        — strictly increasing in observation time, so post-drift samples
        always outweigh pre-drift ones."""
        age = now - np.asarray(self._ts, dtype=np.float64)
        return 0.5 ** (age / max(self.half_life_s, 1e-9))

    def refit(self, now: float) -> USLFit:  # simlint: allow[wall-clock] — self-timing of the refit's wall cost (last_refit_wall_s, reported to operators); no sim decision reads it
        """Unconditionally re-fit from the current window (plus prior
        anchors), warm-started from the current fit."""
        t0 = time.perf_counter()
        w_obs = self.observation_weights(now)
        anchors_n = np.asarray(self.anchor_levels, dtype=np.float64)
        anchors_t = np.asarray(self.prior_fit.predict(anchors_n),
                               dtype=np.float64)
        n = np.concatenate([np.asarray(self._ns, dtype=np.float64), anchors_n])
        t = np.concatenate([np.asarray(self._rates, dtype=np.float64),
                            anchors_t])
        anchor_w = self.prior_weight * min(
            1.0, self.min_obs / max(len(self._ts), 1))
        w = np.concatenate([w_obs, np.full(anchors_n.size, anchor_w)])
        seed = [[self.fit.sigma, self.fit.kappa, self.fit.gamma]]
        self.fit = fit_usl_batch(n[None, :], t[None, :], weights=w[None, :],
                                 max_iter=self.max_iter, seed_params=seed)[0]
        self.refits += 1
        self._last_refit_t = now
        self.last_refit_wall_s = time.perf_counter() - t0
        return self.fit

    def maybe_refit(self, now: float) -> USLFit | None:
        """Re-fit if enough fresh evidence accumulated and the refit
        interval elapsed; returns the new fit, or None if nothing ran."""
        if len(self._ts) < self.min_obs:
            return None
        if self._last_refit_t is not None \
                and now - self._last_refit_t < self.refit_interval_s:
            return None
        return self.refit(now)


class USLPredictivePolicy:
    """Predictive scaling (paper §V): invert the fitted USL model.

    The target allocation is ``partitions_for`` the *demand estimate*,
    clamped at the USL peak (never into the retrograde region).  Demand is
    the observed arrival rate plus a backlog-drain term
    (``lag / catchup_horizon_s`` — capacity to clear the current lag within
    the horizon), floored by an exponentially decaying memory of recent
    peak demand (``stabilization_s``) — the standard scale-down
    stabilization window, which keeps burst-level capacity warm between
    bursts instead of re-paying the platform's scale-up price (cold starts,
    HPC queue/grant delay) every cycle.  Scale-up is prompt; scale-down
    additionally requires the backlog to be cleared (``downscale_lag``) and
    demand to sit well below current capacity (the planner's hysteresis):
    releasing workers while lag is outstanding stalls the drain behind
    fresh grant delays.

    With an ``estimator`` (``OnlineUSLEstimator``) the policy *learns while
    it runs*: every observation is fed to the estimator, and whenever it
    re-fits, the autoscaler's model is swapped for the updated one — the
    inversion then tracks drift instead of staying frozen at
    characterization time.

    ``max_step_up`` bounds how much the allocation may grow per tick
    (doubling-style slew limit: ``max(max_step_up, current)`` extra units).
    Bounded actuation is standard controller hygiene — a reshard from 2 to
    16 partitions in one tick is a traumatic migration — and it makes the
    scale-up trajectory pass *through* the intermediate N levels, which is
    precisely where an online estimator samples the capacity curve's shape
    (a single level cannot distinguish gamma from kappa).
    """

    name = "usl"

    def __init__(self, autoscaler: Autoscaler, catchup_horizon_s: float = 20.0,
                 downscale_lag: int = 16, stabilization_s: float = 60.0,
                 estimator: OnlineUSLEstimator | None = None,
                 max_step_up: int | None = None) -> None:
        self.autoscaler = autoscaler
        self.catchup_horizon_s = catchup_horizon_s
        self.downscale_lag = downscale_lag
        self.stabilization_s = stabilization_s
        self.estimator = estimator
        self.max_step_up = max_step_up
        self._demand_floor = 0.0
        self._last_t: float | None = None

    def decide(self, obs: ControlObservation) -> int:
        if self.estimator is not None:
            # only windows served by a stable granted capacity are clean
            # capacity measurements: a grant/retirement mid-window mixes
            # two capacity levels into one rate.  (The control loop marks
            # stability against the *post-action* grant, so a window that
            # ran entirely at the newly scaled capacity still counts — the
            # climb through intermediate N levels is exactly where the
            # retrograde curvature gets sampled.)
            if obs.window_stable:
                self.estimator.observe(obs.t, obs.effective_allocation,
                                       obs.completion_rate, obs.lag)
            refit = self.estimator.maybe_refit(obs.t)
            if refit is not None:
                self.autoscaler.fit = refit
        inst = obs.arrival_rate + obs.lag / self.catchup_horizon_s
        dt = 0.0 if self._last_t is None else max(obs.t - self._last_t, 0.0)
        self._last_t = obs.t
        if self.stabilization_s > 0.0:
            self._demand_floor *= math.exp(-dt / self.stabilization_s)
            demand = self._demand_floor = max(inst, self._demand_floor)
        else:
            demand = inst       # stabilization disabled: track instantly
        cur = obs.allocation
        # the live allocation is the planner's state; step() then applies
        # the prompt-up / hysteresis-down rule (one copy of that logic)
        self.autoscaler.current = cur
        want = self.autoscaler.step(demand)
        if self.max_step_up is not None and want > cur:
            # slew limit: grow by at most max(max_step_up, cur) per tick
            # (doubling-style), never jump the whole gap in one reshard
            want = min(want, cur + max(self.max_step_up, cur))
            self.autoscaler.current = want
        if want < cur and obs.lag > self.downscale_lag \
                and cur <= self.autoscaler.usable_peak_n():
            # demand says shrink, backlog says hold — but only below the
            # peak: past it, holding N keeps the system in the retrograde
            # region and the backlog drains *slower*
            return cur
        return want


class ReactiveLagPolicy:
    """Model-free baseline: scale on lag watermarks alone.

    Up by ``step_up`` when lag crosses ``hi_lag``, down by one when it
    falls under ``lo_lag`` — the standard threshold autoscaler every
    streaming platform ships.  It cannot anticipate: capacity only moves
    *after* lag has already built (or after over-provisioning is already
    being paid for), which is exactly the gap the USL-predictive policy
    closes in fig 8.
    """

    name = "reactive"

    def __init__(self, hi_lag: int = 32, lo_lag: int = 4, step_up: int = 1,
                 min_partitions: int = 1, max_partitions: int = 256) -> None:
        self.hi_lag = hi_lag
        self.lo_lag = lo_lag
        self.step_up = step_up
        self.min_partitions = min_partitions
        self.max_partitions = max_partitions

    def decide(self, obs: ControlObservation) -> int:
        if obs.lag >= self.hi_lag:
            return min(obs.allocation + self.step_up, self.max_partitions)
        if obs.lag <= self.lo_lag:
            return max(obs.allocation - 1, self.min_partitions)
        return obs.allocation


class StaticPolicy:
    """No adaptation: hold a fixed allocation (e.g. static-peak
    provisioning, the serverful strawman fig 8 compares against)."""

    name = "static"

    def __init__(self, partitions: int) -> None:
        self.partitions = int(partitions)

    def decide(self, obs: ControlObservation) -> int:
        return self.partitions


def policy_from_spec(spec: dict, *, initial: int):
    """Construct a scaling policy from a JSON-able spec dict.

    The spec is data, not code — the same dict a ``WhatIfDesign`` carries
    through pickling into pool workers and into cache keys.  ``kind``
    selects the controller; the remaining keys are its hyperparameters:

    * ``usl`` / ``usl_online``: ``sigma``/``kappa``/``gamma`` (the fitted
      model, required), ``headroom``, ``max_partitions``,
      ``scale_down_hysteresis``, ``catchup_horizon_s``, ``downscale_lag``,
      ``stabilization_s``, ``max_step_up``; online adds
      ``refit_interval_s``, ``refit_window``, ``refit_half_life_s``.
    * ``reactive``: ``hi_lag``, ``lo_lag``, ``step_up``, ``max_partitions``.
    * ``static``: ``partitions`` (defaults to ``initial``).

    ``initial`` seeds the planner's current allocation (the hysteresis
    reference) — it is runtime wiring, not a hyperparameter, which is why
    it is a keyword argument and not a spec field.
    """
    kind = spec.get("kind")
    if kind in ("usl", "usl_online"):
        try:
            fit = USLFit(sigma=float(spec["sigma"]), kappa=float(spec["kappa"]),
                         gamma=float(spec["gamma"]), r2=1.0, rmse=0.0, n_obs=0)
        except KeyError as exc:
            raise ValueError(
                f"{kind} policy spec needs sigma/kappa/gamma "
                "(fit a characterization sweep first)") from exc
        scaler = Autoscaler(fit, AutoscalePolicy(
            headroom=float(spec.get("headroom", 0.15)),
            max_partitions=int(spec.get("max_partitions", 256)),
            scale_down_hysteresis=float(spec.get("scale_down_hysteresis", 0.25)),
            min_partitions=1), current=initial)
        estimator = None
        if kind == "usl_online":
            estimator = OnlineUSLEstimator(
                fit,
                refit_interval_s=float(spec.get("refit_interval_s", 10.0)),
                window=int(spec.get("refit_window", 128)),
                half_life_s=float(spec.get("refit_half_life_s", 45.0)))
        max_step_up = spec.get("max_step_up")
        return USLPredictivePolicy(
            scaler,
            catchup_horizon_s=float(spec.get("catchup_horizon_s", 20.0)),
            downscale_lag=int(spec.get("downscale_lag", 16)),
            stabilization_s=float(spec.get("stabilization_s", 60.0)),
            estimator=estimator,
            max_step_up=None if max_step_up is None else int(max_step_up))
    if kind == "reactive":
        return ReactiveLagPolicy(
            hi_lag=int(spec.get("hi_lag", 32)),
            lo_lag=int(spec.get("lo_lag", 4)),
            step_up=int(spec.get("step_up", 1)),
            min_partitions=1,
            max_partitions=int(spec.get("max_partitions", 256)))
    if kind == "static":
        return StaticPolicy(int(spec.get("partitions", initial)))
    raise ValueError(f"unknown policy kind {kind!r} in spec {spec!r}")


class ControlLoop:
    """Closed-loop elastic scaling as a periodic control tick.

    Each tick: observe (end-to-end lag and windowed arrival/completion
    rates as O(1) ``MetricRegistry.kind_count`` deltas of the run's
    ``produce``/``complete`` event columns — see ``ControlObservation`` for
    why produced−completed, not broker consumer lag, is the backpressure
    signal), decide (``policy.decide``), act (``Backend.scale_to`` →
    ``Broker.repartition`` → ``engine.repartition`` with the
    state-migration cost ``migration_s_per_delta × |ΔN|``), and account
    (allocation/lag traces as registry series, SLO-violation ticks where
    lag exceeds ``slo_lag``, and the cost integral ∫ allocation dt — the
    container-seconds / core-seconds bill).

    The loop schedules itself through the engine's ``EngineControlSurface``
    (``now``/``call_later``/``repartition``), so the identical controller
    runs on the virtual clock (``SimStreamingEngine``) and on the wall
    clock (``ThreadedStreamingEngine``'s ticker thread).  If the policy
    carries an ``OnlineUSLEstimator``, every re-fit is traced as an
    ``autoscale/refit`` event and counted in ``refit_events``.
    """

    def __init__(self, engine, broker, topic: str, pilot, policy, *,
                 metrics, run_id: str,
                 interval_s: float = 2.0, slo_lag: int = 32,
                 migration_s_per_delta: float = 0.0,
                 fault_signal: Callable[[], bool] | None = None) -> None:
        self.engine = engine          # EngineControlSurface
        self.broker = broker
        self.topic = topic
        self.pilot = pilot
        self.policy = policy
        self.metrics = metrics
        self.run_id = run_id
        self.interval_s = interval_s
        self.slo_lag = slo_lag
        self.migration_s_per_delta = migration_s_per_delta
        # latched "a fault fired / is in force since the last probe" read
        # (FaultInjector.window_dirty): such windows are excluded from the
        # online estimator the same way in-flight grants are — a crash or
        # stall mid-window makes the observed rate measure the fault, not
        # the capacity at N.  (Preemption is additionally covered by the
        # granted==target gate, because effective_allocation dips.)
        self.fault_signal = fault_signal
        self.allocation = pilot.backend.allocation(pilot)
        self.ticks = 0
        self.slo_violations = 0
        self.scale_events = 0
        self.refit_events = 0
        self.fault_windows = 0            # ticks whose window saw a fault
        self.tick_errors = 0              # surfaced ticker-callback failures
        # bounded diagnosis ring: the last 16 (sim_ts, repr(exc)) entries —
        # a flapping policy is diagnosable from the report card, not just
        # countable (tick_errors keeps the total)
        self.tick_error_log: deque = deque(maxlen=16)
        self._ticker_error_seen = False
        self.cost_integral = 0.0          # ∫ allocation dt
        self._stopped = False
        self._last_t = engine.now()
        self._last_produced = metrics.kind_count(run_id, "produce")
        self._last_completed = metrics.kind_count(run_id, "complete")
        self._eff_after_act = pilot.backend.effective_allocation(pilot)
        # on the wall-clock path ticks run on the engine's ticker thread
        # while stop() (and the result snapshot after it) runs on the
        # caller's; the lock makes stop() wait out an in-flight tick so the
        # report card is read from quiescent state (on the single-threaded
        # sim path it is uncontended)
        self._tick_lock = threading.Lock()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        self.engine.call_later(self.interval_s, self._tick)

    def stop(self) -> None:
        """Stop ticking and settle the final cost-integral interval.
        Blocks until any in-flight tick completes; no tick mutates the
        loop's accounting after this returns."""
        with self._tick_lock:
            if not self._stopped:
                self._account(self.engine.now())
                self._stopped = True

    # -- the loop ------------------------------------------------------------
    def _account(self, now: float) -> None:
        dt = now - self._last_t
        if dt > 0:
            self.cost_integral += self.allocation * dt
        self._last_t = now

    def observe(self) -> ControlObservation:
        now = self.engine.now()
        backend = self.pilot.backend
        produced = self.metrics.kind_count(self.run_id, "produce")
        completed = self.metrics.kind_count(self.run_id, "complete")
        dt = max(now - self._last_t, 1e-9)
        effective = backend.effective_allocation(self.pilot)
        faulty = bool(self.fault_signal()) if self.fault_signal is not None \
            else False
        if faulty:
            self.fault_windows += 1
        obs = ControlObservation(
            t=now,
            lag=max(0, produced - completed),
            arrival_rate=(produced - self._last_produced) / dt,
            completion_rate=(completed - self._last_completed) / dt,
            allocation=self.allocation,
            effective_allocation=effective,
            # stable = the grant in force since last tick's *action* never
            # moved AND nothing is in flight (granted == target): a window
            # that ran wholly at a freshly scaled capacity is a clean
            # capacity sample; a mid-window grant is not, and neither is a
            # wait on the batch queue — resharded partitions pinned to
            # still-queued workers stall, so the window's rate reflects a
            # crippled topology, not the capacity of the live worker count
            window_stable=(not faulty
                           and effective == self._eff_after_act
                           and effective == self.allocation),
        )
        self._last_produced = produced
        self._last_completed = completed
        return obs

    def _trace_refits(self, obs: ControlObservation) -> None:
        est = getattr(self.policy, "estimator", None)
        if est is None or est.refits == self.refit_events:
            return
        self.refit_events = est.refits
        fit = est.fit
        self.metrics.record(self.run_id, "autoscale", "refit", obs.t,
                            sigma=fit.sigma, kappa=fit.kappa, gamma=fit.gamma,
                            n_obs=len(est), wall_s=est.last_refit_wall_s)

    def _tick(self) -> None:
        try:
            with self._tick_lock:
                self._tick_locked()
        finally:
            # Re-arm OUTSIDE the tick body.  The seed re-armed as the last
            # line of _tick_locked, so a single raising policy/backend call
            # silently killed the loop: the wall ticker stored the error
            # and kept ticking, but nothing ever re-scheduled this tick —
            # in-flight call_later entries drained and the controller went
            # quiet mid-run.  Re-arming in a finally keeps the loop alive
            # through one-off failures; the error itself is still surfaced
            # (ticker_error → tick_errors on the next tick, and
            # run_adaptation raises on it after the run).
            if not self._stopped:
                self.engine.call_later(self.interval_s, self._tick)

    def _tick_locked(self) -> None:
        if self._stopped:
            return
        drain = getattr(self.engine, "drain_ticker_errors", None)
        if drain is not None:
            errs = drain()
        else:
            # engines without a drainable history surface only the root
            # cause once (the pre-ring behaviour)
            err = getattr(self.engine, "ticker_error", None)
            errs = [] if err is None or self._ticker_error_seen else [err]
        for err in errs:
            # a ticker callback (this tick or any other call_later client)
            # failed since the last probe: count it, ring-buffer it and
            # trace it so a crashed-then-recovered controller is visible
            self._ticker_error_seen = True
            self.tick_errors += 1
            self.tick_error_log.append((self.engine.now(), repr(err)))
            self.metrics.record(self.run_id, "autoscale", "tick_error",
                                self.engine.now(), error=repr(err))
        obs = self.observe()
        self._account(obs.t)
        self.ticks += 1
        if obs.lag > self.slo_lag:
            self.slo_violations += 1
        self.metrics.observe(f"{self.run_id}/alloc", obs.t, float(obs.allocation))
        self.metrics.observe(f"{self.run_id}/lag", obs.t, float(obs.lag))
        target = int(self.policy.decide(obs))
        self._trace_refits(obs)
        if target != self.allocation:
            granted = self.pilot.backend.scale_to(self.pilot, target)
            delta = abs(granted - self.allocation)
            if granted != self.allocation:
                self.scale_events += 1
                self.metrics.record(self.run_id, "autoscale", "scale", obs.t,
                                    frm=self.allocation, to=granted,
                                    lag=obs.lag, rate=obs.arrival_rate)
                self.allocation = granted
                self.broker.repartition(self.topic, granted)
                self.engine.repartition(self.migration_s_per_delta * delta)
        self._eff_after_act = self.pilot.backend.effective_allocation(self.pilot)
