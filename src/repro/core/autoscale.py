"""USL-driven autoscaling: offline planner AND live closed control loop.

The paper's §V future work — "we will integrate StreamInsight into the
resource management algorithm of Pilot-Streaming so as to support predictive
scaling, viz., the ability to adapt the resource allocations and
configurations to changes in the incoming data rate(s)" — implemented in two
layers:

**Offline planner** (``Autoscaler``): given a fitted USL model for a
scenario it answers

* ``partitions_for(target_rate)`` — the smallest N whose predicted
  throughput sustains the incoming rate (with headroom), clamped at the
  USL peak: beyond N* adding partitions *reduces* throughput, so the
  autoscaler never scales into the retrograde region.
* ``max_sustainable_rate()`` — the peak throughput; incoming rates above it
  require throttling the source (the paper's "determination of the amount
  of throttling of data sources to guarantee processing").
* ``plan(rate_series)`` — partition counts tracking a time-varying rate,
  with hysteresis to avoid flapping.

**Live closed loop** (``ControlLoop``): a periodic discrete event on the
simulation clock that *observes* broker lag and windowed arrival/completion
rates (O(1) counter deltas from the columnar ``MetricRegistry`` and the
broker), *decides* a target allocation through a pluggable policy —
``USLPredictivePolicy`` (the paper's predictive scaling: model-inverted
partition counts with hysteresis and peak clamping) or the
``ReactiveLagPolicy`` baseline (scale on lag watermarks, knowledge-free) —
and *acts* by scaling the elastic pilot backend (``Backend.scale_to``),
resharding the broker (``Broker.repartition``) and repartitioning the
engine with a state-migration cost event.  Per-run it accumulates the EILC
report card: allocation/lag traces, SLO-violation ticks and the allocation
cost integral ∫N dt.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.usl import USLFit

__all__ = ["AutoscalePolicy", "Autoscaler", "ControlObservation",
           "USLPredictivePolicy", "ReactiveLagPolicy", "StaticPolicy",
           "ControlLoop"]


@dataclass
class AutoscalePolicy:
    headroom: float = 0.15         # fraction of spare capacity to keep
    max_partitions: int = 256
    scale_down_hysteresis: float = 0.25   # rate must drop this much to downscale
    min_partitions: int = 1


class Autoscaler:
    def __init__(self, fit: USLFit, policy: AutoscalePolicy | None = None,
                 current: int | None = None) -> None:
        self.fit = fit
        self.policy = policy or AutoscalePolicy()
        self._current = (self.policy.min_partitions if current is None
                         else max(self.policy.min_partitions, int(current)))

    @property
    def current(self) -> int:
        """The planner's current allocation (the hysteresis reference)."""
        return self._current

    @current.setter
    def current(self, n: int) -> None:
        self._current = max(self.policy.min_partitions, int(n))

    # -- pure queries ----------------------------------------------------------
    def usable_peak_n(self) -> int:
        peak = self.fit.peak_n
        cap = self.policy.max_partitions
        if math.isinf(peak):
            return cap
        return max(self.policy.min_partitions, min(cap, int(math.floor(peak))))

    def max_sustainable_rate(self) -> float:
        n = self.usable_peak_n()
        return float(self.fit.predict(n))

    def partitions_for(self, target_rate: float) -> int | None:
        """Smallest N sustaining ``target_rate`` (incl. headroom); None if the
        rate exceeds the system's peak → caller must throttle the source."""
        need = target_rate * (1.0 + self.policy.headroom)
        hi = self.usable_peak_n()
        ns = np.arange(self.policy.min_partitions, hi + 1, dtype=np.float64)
        pred = self.fit.predict(ns)
        ok = np.nonzero(pred >= need)[0]
        if ok.size == 0:
            return None
        return int(ns[ok[0]])

    def throttle_rate(self, incoming_rate: float) -> float:
        """Admissible source rate (paper: "amount of throttling of data
        sources to guarantee processing")."""
        return min(incoming_rate, self.max_sustainable_rate() / (1.0 + self.policy.headroom))

    # -- stateful planning -------------------------------------------------------
    def step(self, observed_rate: float) -> int:
        """Hysteresis-stabilized partition recommendation for the next window."""
        want = self.partitions_for(observed_rate)
        if want is None:
            want = self.usable_peak_n()
        if want > self._current:
            self._current = want                     # scale up promptly
        elif want < self._current:
            # only scale down if the needed capacity dropped well below current
            cur_rate = float(self.fit.predict(self._current))
            if observed_rate < cur_rate * (1.0 - self.policy.scale_down_hysteresis):
                self._current = want
        return self._current

    def plan(self, rate_series) -> list[int]:
        return [self.step(float(r)) for r in rate_series]


# ---------------------------------------------------------------------------
# live closed loop (EILC): observe -> decide -> act, as a periodic DES event
# ---------------------------------------------------------------------------

@dataclass
class ControlObservation:
    """What a control tick sees: the backpressure signal plus windowed
    rates (counter deltas over the last control interval).

    ``lag`` is *end-to-end* outstanding work (produced − completed): it
    includes messages still queued in the ingest path, not only
    appended-but-uncommitted broker lag — per-shard ingest limits mean the
    broker itself can be the bottleneck, and a controller watching only
    consumer lag is blind to that backlog."""

    t: float
    lag: int                   # produced-but-not-completed messages
    arrival_rate: float        # msgs/s offered (produced) over the last window
    completion_rate: float     # msgs/s completed over the last window
    allocation: int            # current granted capacity


class USLPredictivePolicy:
    """Predictive scaling (paper §V): invert the fitted USL model.

    The target allocation is ``partitions_for`` the *demand estimate*,
    clamped at the USL peak (never into the retrograde region).  Demand is
    the observed arrival rate plus a backlog-drain term
    (``lag / catchup_horizon_s`` — capacity to clear the current lag within
    the horizon), floored by an exponentially decaying memory of recent
    peak demand (``stabilization_s``) — the standard scale-down
    stabilization window, which keeps burst-level capacity warm between
    bursts instead of re-paying the platform's scale-up price (cold starts,
    HPC queue/grant delay) every cycle.  Scale-up is prompt; scale-down
    additionally requires the backlog to be cleared (``downscale_lag``) and
    demand to sit well below current capacity (the planner's hysteresis):
    releasing workers while lag is outstanding stalls the drain behind
    fresh grant delays.
    """

    name = "usl"

    def __init__(self, autoscaler: Autoscaler, catchup_horizon_s: float = 20.0,
                 downscale_lag: int = 16, stabilization_s: float = 60.0) -> None:
        self.autoscaler = autoscaler
        self.catchup_horizon_s = catchup_horizon_s
        self.downscale_lag = downscale_lag
        self.stabilization_s = stabilization_s
        self._demand_floor = 0.0
        self._last_t: float | None = None

    def decide(self, obs: ControlObservation) -> int:
        inst = obs.arrival_rate + obs.lag / self.catchup_horizon_s
        dt = 0.0 if self._last_t is None else max(obs.t - self._last_t, 0.0)
        self._last_t = obs.t
        if self.stabilization_s > 0.0:
            self._demand_floor *= math.exp(-dt / self.stabilization_s)
            demand = self._demand_floor = max(inst, self._demand_floor)
        else:
            demand = inst       # stabilization disabled: track instantly
        cur = obs.allocation
        # the live allocation is the planner's state; step() then applies
        # the prompt-up / hysteresis-down rule (one copy of that logic)
        self.autoscaler.current = cur
        want = self.autoscaler.step(demand)
        if want < cur and obs.lag > self.downscale_lag:
            return cur        # demand says shrink, backlog says hold
        return want


class ReactiveLagPolicy:
    """Model-free baseline: scale on lag watermarks alone.

    Up by ``step_up`` when lag crosses ``hi_lag``, down by one when it
    falls under ``lo_lag`` — the standard threshold autoscaler every
    streaming platform ships.  It cannot anticipate: capacity only moves
    *after* lag has already built (or after over-provisioning is already
    being paid for), which is exactly the gap the USL-predictive policy
    closes in fig 8.
    """

    name = "reactive"

    def __init__(self, hi_lag: int = 32, lo_lag: int = 4, step_up: int = 1,
                 min_partitions: int = 1, max_partitions: int = 256) -> None:
        self.hi_lag = hi_lag
        self.lo_lag = lo_lag
        self.step_up = step_up
        self.min_partitions = min_partitions
        self.max_partitions = max_partitions

    def decide(self, obs: ControlObservation) -> int:
        if obs.lag >= self.hi_lag:
            return min(obs.allocation + self.step_up, self.max_partitions)
        if obs.lag <= self.lo_lag:
            return max(obs.allocation - 1, self.min_partitions)
        return obs.allocation


class StaticPolicy:
    """No adaptation: hold a fixed allocation (e.g. static-peak
    provisioning, the serverful strawman fig 8 compares against)."""

    name = "static"

    def __init__(self, partitions: int) -> None:
        self.partitions = int(partitions)

    def decide(self, obs: ControlObservation) -> int:
        return self.partitions


class ControlLoop:
    """Closed-loop elastic scaling as a periodic simulation event.

    Each tick: observe (end-to-end lag and windowed arrival/completion
    rates as O(1) ``MetricRegistry.kind_count`` deltas of the run's
    ``produce``/``complete`` event columns — see ``ControlObservation`` for
    why produced−completed, not broker consumer lag, is the backpressure
    signal), decide (``policy.decide``), act (``Backend.scale_to`` →
    ``Broker.repartition`` → ``SimStreamingEngine.repartition`` with the
    state-migration cost ``migration_s_per_delta × |ΔN|``), and account
    (allocation/lag traces as registry series, SLO-violation ticks where
    lag exceeds ``slo_lag``, and the cost integral ∫ allocation dt — the
    container-seconds / core-seconds bill).
    """

    def __init__(self, sim, broker, topic: str, engine, pilot, policy, *,
                 metrics, run_id: str,
                 interval_s: float = 2.0, slo_lag: int = 32,
                 migration_s_per_delta: float = 0.0) -> None:
        self.sim = sim
        self.broker = broker
        self.topic = topic
        self.engine = engine
        self.pilot = pilot
        self.policy = policy
        self.metrics = metrics
        self.run_id = run_id
        self.interval_s = interval_s
        self.slo_lag = slo_lag
        self.migration_s_per_delta = migration_s_per_delta
        self.allocation = pilot.backend.allocation(pilot)
        self.ticks = 0
        self.slo_violations = 0
        self.scale_events = 0
        self.cost_integral = 0.0          # ∫ allocation dt
        self._stopped = False
        self._last_t = sim.now
        self._last_produced = metrics.kind_count(run_id, "produce")
        self._last_completed = metrics.kind_count(run_id, "complete")

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        self.sim.schedule_fast(self.interval_s, self._tick)

    def stop(self) -> None:
        """Stop ticking and settle the final cost-integral interval."""
        if not self._stopped:
            self._account(self.sim.now)
            self._stopped = True

    # -- the loop ------------------------------------------------------------
    def _account(self, now: float) -> None:
        dt = now - self._last_t
        if dt > 0:
            self.cost_integral += self.allocation * dt
        self._last_t = now

    def observe(self) -> ControlObservation:
        now = self.sim.now
        produced = self.metrics.kind_count(self.run_id, "produce")
        completed = self.metrics.kind_count(self.run_id, "complete")
        dt = max(now - self._last_t, 1e-9)
        obs = ControlObservation(
            t=now,
            lag=max(0, produced - completed),
            arrival_rate=(produced - self._last_produced) / dt,
            completion_rate=(completed - self._last_completed) / dt,
            allocation=self.allocation,
        )
        self._last_produced = produced
        self._last_completed = completed
        return obs

    def _tick(self) -> None:
        if self._stopped:
            return
        obs = self.observe()
        self._account(obs.t)
        self.ticks += 1
        if obs.lag > self.slo_lag:
            self.slo_violations += 1
        self.metrics.observe(f"{self.run_id}/alloc", obs.t, float(obs.allocation))
        self.metrics.observe(f"{self.run_id}/lag", obs.t, float(obs.lag))
        target = int(self.policy.decide(obs))
        if target != self.allocation:
            granted = self.pilot.backend.scale_to(self.pilot, target)
            delta = abs(granted - self.allocation)
            if granted != self.allocation:
                self.scale_events += 1
                self.metrics.record(self.run_id, "autoscale", "scale", obs.t,
                                    frm=self.allocation, to=granted,
                                    lag=obs.lag, rate=obs.arrival_rate)
                self.allocation = granted
                self.broker.repartition(self.topic, granted)
                self.engine.repartition(self.migration_s_per_delta * delta)
        self.sim.schedule_fast(self.interval_s, self._tick)
