"""Fleet-scale what-if engine: tournaments over (scenario × policy × seed).

The paper's pitch (StreamInsight §IV-V) is that a fitted USL model plus
cheap simulation lets you *choose* configurations before paying for them;
Pilot-Streaming frames the same question at resource-manager scale.  This
module is that question made executable: a ``WhatIfDesign`` declares the
cross-product of rate scenarios × scaling policies (with hyper-parameter
grids) × fault plans × federation specs × seeds, and a ``Tournament``
answers it in one pass —

1. **expand** the design into ``AdaptationPlan`` cells (a run is a value:
   ``core.miniapp.run_plan`` is a pure plan → summary function);
2. **dedupe** shared cells by ``streaminsight.cache_key`` — a question-at-
   a-time runner re-simulates identical baseline cells once per comparison
   (see ``naive_question_cells``, which enumerates exactly that waste; the
   perf-smoke ``whatif`` gate measures it against this runner);
3. **execute** the unique cells through ``streaminsight.run_cells`` — the
   persistent process pool, the on-disk ``ResultCache`` and the serverless
   fast replay (``sim.batched``) all apply, and only compact summaries
   come back (no event traces across the pool boundary);
4. **reduce** to decision tables: a violations/cost Pareto frontier per
   scenario and per-policy win matrices with seed-level sign tests.

Non-qualifying cells (federation, fault plans, threaded engine, HPC
machines) are not a special case: ``run_plan`` falls back to the scalar
DES per cell, logs the reason, and the tournament records it in
``TournamentResult.fallbacks`` — the what-if surface is uniform even
where the fast path is not.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Any

from repro.core.miniapp import AdaptationExperiment, AdaptationPlan, \
    AdaptationSummary
from repro.core.streaminsight import ResultCache, cache_key, run_cells

__all__ = ["WhatIfDesign", "Tournament", "TournamentResult", "sign_test",
           "pareto_frontier"]

# (scenario name, policy name, seed) — the coordinate a summary is filed
# under; distinct coordinates may share one simulated cell (the dedupe).
Coord = tuple[str, str, int]


@dataclass
class WhatIfDesign:
    """Declarative what-if grid over closed-loop adaptation cells.

    ``base`` holds the shared ``AdaptationExperiment`` fields (machine,
    USL coefficients, horizon, SLO ...).  Each ``scenarios`` entry is a
    named dict of experiment overrides — the rate program, drift knobs,
    a ``faults`` plan or a ``federation`` spec all ride here, which makes
    fault plans and federation member mixes first-class sweep axes.  Each
    ``policies`` entry is a scaling-policy spec: a bare name
    (``"reactive"``) or a dict with ``name``, ``scaling_policy`` and
    controller-knob overrides where any **list-valued** field expands into
    a hyper-parameter grid (one policy variant per combination, named
    ``base[knob=value,...]``).
    """

    base: dict = field(default_factory=dict)
    scenarios: list = field(default_factory=lambda: [dict(name="default")])
    policies: list = field(default_factory=lambda: ["usl", "reactive"])
    seeds: list = field(default_factory=lambda: [0])
    fast: bool = True          # execution hint for run_plan (never semantic)

    # -- expansion -----------------------------------------------------------
    def policy_variants(self) -> list[tuple[str, dict]]:
        """``(name, experiment-overrides)`` per policy, hypergrids expanded."""
        out: list[tuple[str, dict]] = []
        for entry in self.policies:
            if isinstance(entry, str):
                out.append((entry, {"scaling_policy": entry}))
                continue
            spec = dict(entry)
            name = spec.pop("name", spec.get("scaling_policy", "policy"))
            spec.setdefault("scaling_policy", name)
            grid_keys = sorted(k for k, v in spec.items()
                               if isinstance(v, (list, tuple)))
            if not grid_keys:
                out.append((name, spec))
                continue
            levels = [spec[k] for k in grid_keys]
            for combo in itertools.product(*levels):
                variant = dict(spec)
                variant.update(dict(zip(grid_keys, combo)))
                tag = ",".join(f"{k}={v:g}" if isinstance(v, float)
                               else f"{k}={v}"
                               for k, v in zip(grid_keys, combo))
                out.append((f"{name}[{tag}]", variant))
        return out

    def scenario_specs(self) -> list[tuple[str, dict]]:
        out = []
        for i, sc in enumerate(self.scenarios):
            spec = dict(sc)
            out.append((str(spec.pop("name", f"scenario{i}")), spec))
        return out

    def plans(self) -> list[tuple[Coord, AdaptationPlan]]:
        """The full cross-product, one ``AdaptationPlan`` per coordinate.
        Override precedence: base < scenario < policy < seed."""
        out: list[tuple[Coord, AdaptationPlan]] = []
        for (sc_name, sc), (pol_name, pol), seed in itertools.product(
                self.scenario_specs(), self.policy_variants(), self.seeds):
            fields: dict[str, Any] = dict(self.base)
            fields.update(sc)
            fields.update(pol)
            fields["seed"] = seed
            exp = AdaptationExperiment(**fields)
            out.append(((sc_name, pol_name, seed),
                        AdaptationPlan(experiment=exp, fast=self.fast)))
        return out

    def naive_question_cells(self) -> list[tuple[str, list[Coord]]]:
        """The per-question cell lists a question-at-a-time runner
        simulates: one block per claim the tournament answers (violations,
        cost, refit activity, drain, one Pareto per scenario, one win-
        matrix entry per ordered policy pair), each independently
        re-running every cell it reads.  This is the pre-tournament
        execution shape — fig8 answered each comparison with its own
        ``run_adaptation`` loop — and what the perf-smoke ``whatif`` gate
        measures the dedupe against."""
        coords = [c for c, _p in self.plans()]
        pol_names = [n for n, _s in self.policy_variants()]
        online = [c for c in coords
                  if "usl_online" in c[1]]
        blocks: list[tuple[str, list[Coord]]] = [
            ("violations", list(coords)),
            ("cost", list(coords)),
            ("refit-activity", online),
            ("drain", list(coords)),
        ]
        for sc_name, _sc in self.scenario_specs():
            blocks.append((f"pareto:{sc_name}",
                           [c for c in coords if c[0] == sc_name]))
        for a, b in itertools.permutations(pol_names, 2):
            blocks.append((f"win:{a}>{b}",
                           [c for c in coords if c[1] in (a, b)]))
        return blocks


# -- reducers -----------------------------------------------------------------

def sign_test(wins: int, losses: int) -> float:
    """Two-sided exact binomial sign test p-value (ties excluded): the
    probability, under H0 "neither policy is better", of a split at least
    this lopsided.  Pure ``math.comb`` — no scipy in the image."""
    n = wins + losses
    if n == 0:
        return 1.0
    k = min(wins, losses)
    tail = sum(math.comb(n, j) for j in range(k + 1)) / 2.0 ** n
    return min(1.0, 2.0 * tail)


def pareto_frontier(points: list[tuple[float, float]]) -> list[bool]:
    """Non-domination flags for (violations, cost) points — smaller is
    better on both axes; a point is on the frontier iff no other point is
    ≤ on both and < on at least one."""
    flags = []
    for i, (vi, ci) in enumerate(points):
        dominated = any(
            (vj <= vi and cj <= ci) and (vj < vi or cj < ci)
            for j, (vj, cj) in enumerate(points) if j != i)
        flags.append(not dominated)
    return flags


@dataclass
class TournamentResult:
    """Everything a tournament learned, summary-sized.

    ``summaries`` is coordinate → ``AdaptationSummary`` (distinct
    coordinates may share one object — that IS the dedupe).  ``pareto``
    maps scenario → per-policy rows (seed-mean violations/cost +
    ``frontier`` flag); ``wins[(a, b)]`` counts a-beats-b across every
    (scenario, seed) cell pair — fewer SLO violations wins, cost breaks
    ties — with the sign-test p-value."""

    summaries: dict
    total_cells: int
    unique_cells: int
    fast_cells: int
    fallbacks: dict
    pareto: dict
    wins: dict

    def summary_rows(self) -> list[dict]:
        """Flat records (one per coordinate) for tables/JSON."""
        rows = []
        for (sc, pol, seed), s in sorted(self.summaries.items()):
            row = s.record()
            row.update(scenario=sc, policy_name=pol, seed=seed)
            rows.append(row)
        return rows


class Tournament:
    """Expand → dedupe → execute → reduce, one invocation.

    ``parallel``/``max_workers``/``cache`` pass through to
    ``streaminsight.run_cells`` (the persistent pool and on-disk memo);
    ``cache`` additionally makes repeated tournaments incremental across
    processes.  Plans are simulated **once per unique cell** however many
    comparisons read them.
    """

    def __init__(self, design: WhatIfDesign, *,
                 parallel: bool | str = "auto",
                 max_workers: int | None = None,
                 cache: ResultCache | str | None = None) -> None:
        self.design = design
        self.parallel = parallel
        self.max_workers = max_workers
        self.cache = cache

    def run(self) -> TournamentResult:
        coords_plans = self.design.plans()
        order: list[str] = []                 # first-seen unique keys
        unique: dict[str, AdaptationPlan] = {}
        fanout: dict[str, list[Coord]] = {}
        for coord, plan in coords_plans:
            key = cache_key(plan)
            if key not in unique:
                unique[key] = plan
                order.append(key)
            fanout[key] = fanout.get(key, []) + [coord]
        results = run_cells([unique[k] for k in order],
                            parallel=self.parallel,
                            max_workers=self.max_workers, cache=self.cache)
        summaries: dict[Coord, AdaptationSummary] = {}
        fallbacks: dict[Coord, str] = {}
        fast_cells = 0
        for key, summary in zip(order, results):
            if summary.fast_path:
                fast_cells += 1
            for coord in fanout[key]:
                summaries[coord] = summary
                if summary.fallback_reason is not None:
                    fallbacks[coord] = summary.fallback_reason
        return TournamentResult(
            summaries=summaries,
            total_cells=len(coords_plans),
            unique_cells=len(unique),
            fast_cells=fast_cells,
            fallbacks=fallbacks,
            pareto=self._pareto(summaries),
            wins=self._wins(summaries))

    # -- reducers ------------------------------------------------------------
    def _pareto(self, summaries: dict) -> dict:
        """Per-scenario policy rows with non-domination flags.

        Distinct policy names whose plans deduped to the *same* physical
        cells (``summaries`` maps their coordinates to the same summary
        objects) would produce coordinate-identical rows — and
        ``pareto_frontier`` flags exact duplicates as mutually
        non-dominated, so one simulated cell could occupy two frontier
        slots under two names.  Such rows are annotated
        ``duplicate_of: <representative policy>`` and excluded from the
        frontier computation; they inherit the representative's flag."""
        out: dict[str, list[dict]] = {}
        for sc_name, _sc in self.design.scenario_specs():
            rows = []
            seen: dict[tuple, str] = {}   # cell identity -> first policy name
            for pol_name, _spec in self.design.policy_variants():
                cells = [summaries[(sc_name, pol_name, s)]
                         for s in self.design.seeds
                         if (sc_name, pol_name, s) in summaries]
                if not cells:
                    continue
                row = {
                    "policy": pol_name,
                    "mean_violations":
                        sum(c.slo_violations for c in cells) / len(cells),
                    "mean_cost":
                        sum(c.cost_integral for c in cells) / len(cells),
                    "seeds": len(cells),
                }
                ident = tuple(id(c) for c in cells)
                rep = seen.get(ident)
                if rep is not None:
                    row["duplicate_of"] = rep
                else:
                    seen[ident] = pol_name
                rows.append(row)
            originals = [r for r in rows if "duplicate_of" not in r]
            flags = pareto_frontier(
                [(r["mean_violations"], r["mean_cost"]) for r in originals])
            rep_frontier = {}
            for r, on_frontier in zip(originals, flags):
                r["frontier"] = on_frontier
                rep_frontier[r["policy"]] = on_frontier
            for r in rows:
                if "duplicate_of" in r:
                    r["frontier"] = rep_frontier[r["duplicate_of"]]
            out[sc_name] = rows
        return out

    def _wins(self, summaries: dict) -> dict:
        pol_names = [n for n, _s in self.design.policy_variants()]
        sc_names = [n for n, _s in self.design.scenario_specs()]
        out: dict[tuple[str, str], dict] = {}
        for a, b in itertools.permutations(pol_names, 2):
            wins = losses = ties = 0
            for sc in sc_names:
                for seed in self.design.seeds:
                    sa = summaries.get((sc, a, seed))
                    sb = summaries.get((sc, b, seed))
                    if sa is None or sb is None:
                        continue
                    ka = (sa.slo_violations, sa.cost_integral)
                    kb = (sb.slo_violations, sb.cost_integral)
                    if ka < kb:
                        wins += 1
                    elif ka > kb:
                        losses += 1
                    else:
                        ties += 1
            out[(a, b)] = {"wins": wins, "losses": losses, "ties": ties,
                           "p_value": sign_test(wins, losses)}
        return out
