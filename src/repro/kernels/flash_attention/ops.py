"""Jitted wrapper for the flash-attention kernel (padding + dispatch).

Padding correctness: Dh is padded to the 128-lane boundary — the extra key
dims are zero so q·k is unchanged, and q is pre-scaled by
sqrt(Dh_pad / Dh) so the kernel's internal 1/sqrt(Dh_pad) lands on the true
1/sqrt(Dh).  S is padded to the block size — with causal masking real query
rows never see padded key positions, and padded query rows are sliced off.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _k
from repro.kernels.flash_attention.ref import mha_ref

__all__ = ["flash_attention"]


def flash_attention(q, k, v, *, causal: bool = True, use_pallas: bool | None = None,
                    interpret: bool = False, block_q: int | None = None,
                    block_k: int | None = None):
    """q (BH, S, Dh); k, v (BKV, S, Dh) with BH = BKV·G (GQA)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return mha_ref(q, k, v, causal=causal)
    assert causal, "padded flash path supports causal attention only"
    BH, S, Dh = q.shape
    pad_d = (-Dh) % 128
    bq = block_q or min(_k.DEFAULT_BLOCK_Q, max(8, S))
    bk = block_k or min(_k.DEFAULT_BLOCK_K, max(8, S))
    pad_s = (-S) % max(bq, bk)
    qs = q * jnp.sqrt((Dh + pad_d) / Dh).astype(q.dtype)
    if pad_d or pad_s:
        pads = ((0, 0), (0, pad_s), (0, pad_d))
        qs = jnp.pad(qs, pads)
        k = jnp.pad(k, pads)
        v = jnp.pad(v, pads)
    out = _k.flash_attention_pallas(qs, k, v, causal=True,
                                    block_q=min(bq, qs.shape[1]),
                                    block_k=min(bk, qs.shape[1]),
                                    interpret=interpret)
    return out[:, :S, :Dh]
