"""Pure-jnp oracle for the flash-attention kernel (causal GQA)."""

from __future__ import annotations

import jax.numpy as jnp
import jax


def mha_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q (BH, S, Dh); k, v (BKV, S, Dh) with BH = BKV * G.  fp32 math."""
    BH, S, Dh = q.shape
    BKV = k.shape[0]
    G = BH // BKV
    scale = scale if scale is not None else 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k.astype(jnp.float32), G, axis=0)
    vf = jnp.repeat(v.astype(jnp.float32), G, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", qf, kf)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)
