"""Pallas TPU flash-attention (causal, GQA) — forward kernel.

Dataflow (FlashAttention [arXiv:2205.14135] adapted to the TPU grid model):
grid = (B·H, S/block_q, S/block_k); the trailing kv axis is sequential on
TPU, so the online-softmax running state (m, l, acc) lives in VMEM scratch
that persists across kv steps for a fixed (head, q-block).  The output tile
is written once, on the last kv block.  Causal masking skips fully-masked
kv blocks via ``pl.when`` (no FLOPs issued for the upper triangle at
block granularity).

GQA: q rows are (B·H); k/v rows are (B·KV); the BlockSpec index maps divide
by the group size G = H/KV, so no repeated-KV materialization ever happens.

Block sizes default to 128×128 (MXU-aligned); d_head is padded to the
128-lane boundary by ops.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, block_q, block_k, n_kv_blocks, causal):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level causal skip: kv block strictly after q block -> no work
    needed = (j * block_k <= i * block_q + block_q - 1) if causal else True

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale                    # (bq, d)
        k = k_ref[0].astype(jnp.float32)                            # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                          (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                          (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = False):
    """q (BH, S, Dh); k, v (BKV, S, Dh), BH = BKV·G.  S % block == 0,
    Dh % 128 == 0 (ops.py pads).  Returns (BH, S, Dh) in q.dtype."""
    BH, S, Dh = q.shape
    BKV = k.shape[0]
    assert BH % BKV == 0, (BH, BKV)
    G = BH // BKV
    bq, bk = min(block_q, S), min(block_k, S)
    nq, nk = S // bq, S // bk
    scale = 1.0 / math.sqrt(Dh)
    kernel = functools.partial(_flash_kernel, scale=scale, block_q=bq,
                               block_k=bk, n_kv_blocks=nk, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, Dh), lambda b, i, j, G=G: (b // G, j, 0)),
            pl.BlockSpec((1, bk, Dh), lambda b, i, j, G=G: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
