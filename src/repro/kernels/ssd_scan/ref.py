"""Pure-jnp oracle for the SSD kernel: the naive O(S) recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bm, Cm, h0=None):
    """Sequential state-space recurrence (Mamba-2 §3, eq. 1-2).

    x  (B, S, H, P); dt (B, S, H); A (H,) negative; Bm, Cm (B, S, N).
    h_t = exp(dt_t A) h_{t-1} + dt_t x_t ⊗ B_t ;  y_t = C_t · h_t
    Returns y (B, S, H, P) fp32 and final h (B, H, P, N).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    h = jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None else h0

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # (B,H,P) (B,H) (B,N) (B,N)
        a = jnp.exp(dtt * A[None])                  # (B,H)
        h = h * a[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xt * dtt[..., None], bt)
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Cm.astype(jnp.float32), 1, 0))
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), h
