"""Pallas TPU kernel for Mamba-2 SSD (state-space duality).  [arXiv:2405.21060]

TPU adaptation of the chunked SSD algorithm: the sequence is cut into
chunks of Q; within a chunk the recurrence is evaluated in its *dual
quadratic form* (two (Q,N)·(N,Q) / (Q,Q)·(Q,P) matmuls — MXU work), and the
(P, N) inter-chunk state is carried in VMEM scratch across the sequential
trailing grid axis.  grid = (B·H, S/Q); one head-chunk tile per step:

    y_chunk = (C Bᵀ ⊙ L) (dt·x)  +  (C hᵀ-decay)        # intra + carry-in
    h      ← exp(Σa) h + Σ_s exp(Σa − cum_s) dt·x_s ⊗ B_s

(L = exp(segsum(a)) lower-triangular decay matrix.)  The final state is
emitted for decode hand-off.  B/C are shared across heads (ngroups=1), so
their BlockSpecs divide the head index out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr, *,
                n_chunks):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)          # (Q,)
    a = a_ref[0].astype(jnp.float32)            # (Q,)  = dt * A  (≤ 0)
    Bm = b_ref[0].astype(jnp.float32)           # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)           # (Q, N)
    Q = x.shape[0]

    cum = jnp.cumsum(a)                         # (Q,)
    # L[i, j] = exp(cum_i - cum_j) for j <= i (decay from step j+1..i)
    li = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(li), 0.0)

    xdt = x * dt[:, None]                       # (Q, P)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    y_intra = jax.lax.dot_general(scores * L, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (Q,P)

    h = h_scr[...]                              # (P, N)
    carry_decay = jnp.exp(cum)[:, None]         # (Q, 1)
    y_carry = jax.lax.dot_general(Cm, h, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32) * carry_decay
    y_ref[0] = (y_intra + y_carry).astype(y_ref.dtype)

    # state update: h' = exp(cum_Q) h + Σ_s exp(cum_Q - cum_s) xdt_s ⊗ B_s
    w = jnp.exp(cum[-1] - cum)[:, None]         # (Q, 1)
    dh = jax.lax.dot_general(xdt * w, Bm, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)       # (P, N)
    h_scr[...] = jnp.exp(cum[-1]) * h + dh

    @pl.when(c_idx == n_chunks - 1)
    def _emit_state():
        hout_ref[0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, a, Bm, Cm, *, chunk: int = DEFAULT_CHUNK,
                    interpret: bool = False):
    """x (BH, S, P); dt, a (BH, S); Bm, Cm (Bg, S, N) with BH = Bg·H.
    Returns (y (BH, S, P) fp32, h_final (BH, P, N) fp32).  S % chunk == 0."""
    BH, S, P = x.shape
    Bg = Bm.shape[0]
    H = BH // Bg
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    kernel = functools.partial(_ssd_kernel, n_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q), lambda b, c: (b, c)),
            pl.BlockSpec((1, Q), lambda b, c: (b, c)),
            pl.BlockSpec((1, Q, N), lambda b, c, H=H: (b // H, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c, H=H: (b // H, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, P, N), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, Bm, Cm)
