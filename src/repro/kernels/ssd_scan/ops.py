"""Jitted wrapper for the SSD kernel: layout adaptation + dispatch.

Model-side layout is (B, S, H, P) with per-head dt and shared B/C
(``models.ssm``); the kernel wants head-major (B·H, S, P).  Fallback is the
chunked pure-JAX SSD in ``models.ssm`` (same math, XLA-fused), oracle is the
naive recurrence in ``ref.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import kernel as _k
from repro.kernels.ssd_scan.ref import ssd_ref

__all__ = ["ssd_scan"]


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = _k.DEFAULT_CHUNK,
             use_pallas: bool | None = None, interpret: bool = False):
    """x (B, S, H, P); dt (B, S, H); A (H,); Bm, Cm (B, S, N).
    Returns (y (B, S, H, P) fp32, h_final (B, H, P, N) fp32)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        from repro.models.ssm import ssd_chunked
        return ssd_chunked(x, dt, A, Bm, Cm, chunk)
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    xb = jnp.moveaxis(x, 2, 1).reshape(B * H, S, P)
    dtb = jnp.moveaxis(dt, 2, 1).reshape(B * H, S)
    ab = dtb * jnp.tile(A, B)[:, None]                       # (BH, S) = dt*A
    y, h = _k.ssd_scan_pallas(xb, dtb, ab, Bm, Cm, chunk=min(chunk, S),
                              interpret=interpret)
    y = jnp.moveaxis(y.reshape(B, H, S, P), 1, 2)
    return y, h.reshape(B, H, P, N)
