"""Jitted public wrappers for the K-Means distance kernels.

Dispatch policy: on TPU the Pallas kernels run compiled; everywhere else the
pure-jnp reference executes (XLA fuses it fine on CPU, and the dry-run's
CPU-hosted compile must not contain TPU-Pallas custom calls).  Tests force
the Pallas path with ``interpret=True``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.kmeans_distance import kernel as _k
from repro.kernels.kmeans_distance.ref import assign_ref, pairwise_sq_dists_ref

__all__ = ["pairwise_sq_dists", "assign", "pad_to_multiple"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pad_to_multiple(a: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = a.shape[axis]
    rem = size % multiple
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(a, pad)


def pairwise_sq_dists(x: jax.Array, c: jax.Array, *, use_pallas: bool | None = None,
                      interpret: bool = False) -> jax.Array:
    """(n, d), (k, d) -> (n, k) squared Euclidean distances."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return pairwise_sq_dists_ref(x, c)
    n, k = x.shape[0], c.shape[0]
    bn = min(_k.DEFAULT_BLOCK_N, max(8, n))
    bc = min(_k.DEFAULT_BLOCK_C, max(8, k))
    xp = pad_to_multiple(pad_to_multiple(x, 1, 128), 0, bn)
    cp = pad_to_multiple(pad_to_multiple(c, 1, 128), 0, bc)
    out = _k.pairwise_sq_dists_pallas(xp, cp, block_n=bn, block_c=bc,
                                      interpret=interpret)
    # padded centroids have ||c||=0 -> distance ||x||^2; slicing removes them
    return out[:n, :k]


def assign(x: jax.Array, c: jax.Array, *, use_pallas: bool | None = None,
           interpret: bool = False):
    """Fused assignment -> (labels (n,) int32, best_sq_dist (n,) f32)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return assign_ref(x, c)
    n, k = x.shape[0], c.shape[0]
    bn = min(_k.DEFAULT_BLOCK_N, max(8, n))
    bc = min(_k.DEFAULT_BLOCK_C, max(8, k))
    xp = pad_to_multiple(pad_to_multiple(x, 1, 128), 0, bn)
    cp = pad_to_multiple(pad_to_multiple(c, 1, 128), 0, bc)
    if cp.shape[0] != k:
        # padded centroids are at the origin; push them to +inf distance by
        # giving them a huge coordinate so argmin never selects padding
        pad_rows = cp.shape[0] - k
        sentinel = jnp.full((pad_rows, cp.shape[1]), 1e17, cp.dtype)
        cp = jnp.concatenate([cp[:k], sentinel], axis=0)
    labels, best = _k.assign_pallas(xp, cp, block_n=bn, block_c=bc,
                                    interpret=interpret)
    return labels[:n], best[:n]
