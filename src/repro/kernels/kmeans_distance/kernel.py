"""Pallas TPU kernels for the K-Means O(n·c) distance phase.

The paper's compute hot-spot is phase 1 of K-Means: Euclidean distances
between all n points and c centroids.  On TPU we express it as
``||x||^2 + ||c||^2 - 2 x c^T`` so the inner contraction runs on the MXU,
tiled so each (block_n × d) point panel and (block_c × d) centroid panel sit
in VMEM and each grid step emits one (block_n × block_c) output tile.

Two kernels:

* ``pairwise_sq_dists_pallas`` — materializes the (n, c) distance matrix.
* ``assign_pallas`` — fused distances + running argmin over centroid blocks:
  the grid's trailing dimension walks centroid panels while the output
  (labels, best) block stays resident in VMEM, so the (n, c) matrix is never
  written to HBM — an O(c/d)× HBM-write saving over kernel 1 for the
  assignment use-case (the K-Means inner loop only needs argmin).

Feature dim d is zero-padded to the 128-lane boundary by ``ops.py``;
zero padding does not change distances (contributes 0 to every norm/dot).
Grid iteration on TPU is sequential over the trailing axis, which the fused
kernel relies on for its running-min accumulation (standard TPU Pallas
revisiting semantics).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_C = 256


def _dist_tile(x_blk, c_blk):
    """(bn, d), (bc, d) -> (bn, bc) squared distances; fp32 accumulation."""
    x32 = x_blk.astype(jnp.float32)
    c32 = c_blk.astype(jnp.float32)
    xn = jnp.sum(x32 * x32, axis=-1, keepdims=True)          # (bn, 1)
    cn = jnp.sum(c32 * c32, axis=-1, keepdims=True).T        # (1, bc)
    dot = jax.lax.dot_general(x32, c32, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return jnp.maximum(xn + cn - 2.0 * dot, 0.0)


# --------------------------------------------------------------------------
# Kernel 1: full (n, c) distance matrix
# --------------------------------------------------------------------------

def _dists_kernel(x_ref, c_ref, out_ref):
    out_ref[...] = _dist_tile(x_ref[...], c_ref[...]).astype(out_ref.dtype)


@partial(jax.jit, static_argnames=("block_n", "block_c", "interpret"))
def pairwise_sq_dists_pallas(x: jax.Array, c: jax.Array, *,
                             block_n: int = DEFAULT_BLOCK_N,
                             block_c: int = DEFAULT_BLOCK_C,
                             interpret: bool = False) -> jax.Array:
    """x (n, d), c (k, d) -> (n, k) float32.  n % block_n == k % block_c == 0
    and d % 128 == 0 (``ops.py`` pads)."""
    n, d = x.shape
    k, _ = c.shape
    grid = (n // block_n, k // block_c)
    return pl.pallas_call(
        _dists_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_c, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(x, c)


# --------------------------------------------------------------------------
# Kernel 2: fused assignment (distances + running argmin, no HBM matrix)
# --------------------------------------------------------------------------

def _assign_kernel(x_ref, c_ref, labels_ref, best_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, jnp.inf)
        labels_ref[...] = jnp.zeros_like(labels_ref)

    d2 = _dist_tile(x_ref[...], c_ref[...])                  # (bn, bc)
    blk_best = jnp.min(d2, axis=1)                           # (bn,)
    blk_arg = jnp.argmin(d2, axis=1).astype(jnp.int32)       # (bn,)
    bc = d2.shape[1]
    cur_best = best_ref[...]
    take = blk_best < cur_best
    best_ref[...] = jnp.where(take, blk_best, cur_best)
    labels_ref[...] = jnp.where(take, blk_arg + j * bc, labels_ref[...])


@partial(jax.jit, static_argnames=("block_n", "block_c", "interpret"))
def assign_pallas(x: jax.Array, c: jax.Array, *,
                  block_n: int = DEFAULT_BLOCK_N,
                  block_c: int = DEFAULT_BLOCK_C,
                  interpret: bool = False):
    """Fused K-Means assignment: returns (labels (n,) int32, best (n,) f32)."""
    n, d = x.shape
    k, _ = c.shape
    grid = (n // block_n, k // block_c)   # trailing axis: centroid panels
    labels, best = pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_c, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(x, c)
    return labels, best
