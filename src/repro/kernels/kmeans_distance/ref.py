"""Pure-jnp oracle for the K-Means distance/assignment kernels."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["pairwise_sq_dists_ref", "assign_ref"]


def pairwise_sq_dists_ref(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """(n, d), (k, d) -> (n, k) squared Euclidean distances.

    Matmul formulation ||x||^2 + ||c||^2 - 2 x c^T (what the MXU kernel
    tiles), clamped at zero against rounding.
    """
    xn = jnp.sum(x * x, axis=-1, keepdims=True)            # (n, 1)
    cn = jnp.sum(c * c, axis=-1, keepdims=True).T          # (1, k)
    d2 = xn + cn - 2.0 * (x @ c.T)
    return jnp.maximum(d2, 0.0)


def assign_ref(x: jnp.ndarray, c: jnp.ndarray):
    """Fused assignment: returns (labels (n,) int32, min_sq_dist (n,))."""
    d2 = pairwise_sq_dists_ref(x, c)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)
