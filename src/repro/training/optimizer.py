"""AdamW with global-norm clipping; optimizer state is ZeRO-shardable.

Pure-pytree implementation (no optax dependency): moments are fp32
regardless of param dtype (bf16 params + fp32 moments), and the launcher
assigns the moments a *ZeRO sharding* (an extra mesh axis on their largest
unsharded dim) via ``distributed.sharding.zero_specs`` — GSPMD then inserts
the reduce-scatter / all-gather around the update.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["OptimizerConfig", "OptState", "init_opt_state", "adamw_step",
           "global_norm"]


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init_opt_state(params) -> OptState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros32, params),
                    nu=jax.tree.map(zeros32, params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def lr_schedule(cfg: OptimizerConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def _is_matrix(p) -> bool:
    return p.ndim >= 2   # decay only matrices (norms/biases exempt)


def adamw_step(params, grads, state: OptState, cfg: OptimizerConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, n):
        g32 = g.astype(jnp.float32) * clip
        m_new = b1 * m + (1 - b1) * g32
        n_new = b2 * n + (1 - b2) * g32 * g32
        mhat = m_new / bc1
        nhat = n_new / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if _is_matrix(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, n_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_n = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_m, flat_n)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr,
               "param_norm": global_norm(new_params)}
    return new_params, OptState(step=step, mu=new_mu, nu=new_nu), metrics
