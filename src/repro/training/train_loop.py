"""Training step builder: loss + grad + AdamW, with gradient accumulation.

``make_train_step(cfg, opt_cfg, n_microbatches)`` returns a pure
``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``
suitable for ``jax.jit`` with in/out shardings (see launch/).  With
``n_microbatches > 1`` the global batch is split on its leading axis and
gradients are averaged under ``lax.scan`` — activation memory scales with
the microbatch, enabling the large train cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.training.optimizer import OptimizerConfig, adamw_step

__all__ = ["make_train_step", "make_eval_step"]


def _split_batch(batch, n_micro):
    def reshape(x):
        b = x.shape[0]
        assert b % n_micro == 0, f"batch {b} not divisible by {n_micro} microbatches"
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    return jax.tree.map(reshape, batch)


def make_train_step(cfg, opt_cfg: OptimizerConfig, n_microbatches: int = 1):
    def loss(params, batch):
        return M.loss_fn(params, cfg, batch)

    def train_step(params, opt_state, batch):
        if n_microbatches == 1:
            loss_val, grads = jax.value_and_grad(loss)(params, batch)
        else:
            micro = _split_batch(batch, n_microbatches)

            def accum(carry, mb):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(loss)(params, mb)
                return (loss_acc + l,
                        jax.tree.map(jnp.add, grad_acc, g)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grad_sum), _ = jax.lax.scan(accum, (0.0, zeros), micro)
            loss_val = loss_sum / n_microbatches
            grads = jax.tree.map(lambda g: g / n_microbatches, grad_sum)
        new_params, new_opt, metrics = adamw_step(params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss_val
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        return M.loss_fn(params, cfg, batch)

    return eval_step
