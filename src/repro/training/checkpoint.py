"""Checkpoint/restart: sharded-tree save/restore with async writes.

Fault-tolerance substrate for the large-scale story (DESIGN.md §5): the
training loop checkpoints every K steps; on restart, training resumes from
the latest complete checkpoint bit-exactly (tested).  Writes are atomic
(tmp dir + rename) so a node failure mid-write never corrupts the latest
checkpoint; an optional background thread makes saves non-blocking
(compute/IO overlap).

Format: one ``.npz`` holding every leaf (keyed by flattened tree path) +
a JSON manifest (step, leaf names/shapes/dtypes).  On multi-host this layout
extends to per-host shard files keyed by device slice — single-process here,
noted in DESIGN.md.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "CheckpointManager"]


def _leaf_key(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    manifest = {"step": int(step), "leaves": []}
    for path, leaf in leaves_with_paths:
        key = _leaf_key(path)
        arr = np.asarray(leaf)
        true_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or true_dtype == "bfloat16":
            # npz cannot roundtrip ml_dtypes (bfloat16 etc.) — store raw bits
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        arrays[key] = arr
        manifest["leaves"].append({"key": key, "shape": list(arr.shape),
                                   "dtype": true_dtype})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, tree_like, step: int | None = None):
    """Restore into the structure of ``tree_like``; returns (tree, step)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    dtypes = {l["key"]: l["dtype"] for l in manifest["leaves"]}
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    new_leaves = []
    for p, leaf in leaves_with_paths:
        key = _leaf_key(p)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        true_dtype = dtypes.get(key, str(arr.dtype))
        if str(arr.dtype) != true_dtype:
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, true_dtype)))
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != {np.shape(leaf)}")
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


class CheckpointManager:
    """Async, retention-managed checkpointing."""

    def __init__(self, ckpt_dir: str, keep: int = 3, async_write: bool = True):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree) -> None:
        self.wait()                             # one outstanding write max
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot

        def write():
            save_checkpoint(self.ckpt_dir, step, host_tree)
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def _gc(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:09d}"),
                          ignore_errors=True)

    def restore_latest(self, tree_like):
        self.wait()
        return restore_checkpoint(self.ckpt_dir, tree_like)
