"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table."""

from __future__ import annotations

import glob
import json
import os
import sys


def load(dirpath: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    return recs


def table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | status | compute_s | memory_s (HLO) | mem_floor_s | "
           "coll_s | wire_s | wire_adj_s | bottleneck | 6ND/HLO | compile s/p | args GB |")
    sep = "|" + "---|" * 13
    rows = [hdr, sep]
    for r in recs:
        if r["status"] == "SKIP":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP(full-attention) "
                        "| — | — | — | — | — | — | — | — | — | — |")
            continue
        if r["status"] != "OK":
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL | — | — | — | — "
                        f"| — | — | — | — | — | — |")
            continue
        t = r["roofline"]
        mem = r["single_pod"]["memory"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | OK "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['memory_floor_s']:.4f} | {t['collective_s']:.4f} "
            f"| {t['collective_wire_s']:.4f} "
            f"| {t.get('collective_wire_bf16adj_s', t['collective_wire_s']):.4f} "
            f"| {t['bottleneck_calibrated']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['single_pod']['compile_s']:.0f}/{r.get('multi_pod', {}).get('compile_s', 0):.0f} "
            f"| {mem.get('argument_size_in_bytes', 0) / 1e9:.1f} |")
    return "\n".join(rows)


def main() -> None:
    recs = load(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    print(table(recs))
    ok = [r for r in recs if r["status"] == "OK"]
    print(f"\n{len(ok)} OK, {sum(r['status'] == 'SKIP' for r in recs)} SKIP, "
          f"{sum(r['status'] == 'FAIL' for r in recs)} FAIL / {len(recs)}")
    # hillclimb candidates
    def frac(r):
        return r["roofline"]["compute_fraction_calibrated"]
    worst = sorted(ok, key=frac)[:5]
    print("\nworst calibrated compute fraction (hillclimb candidates):")
    for r in worst:
        print(f"  {r['arch']} × {r['shape']}: {frac(r) * 100:.1f}% "
              f"(bottleneck {r['roofline']['bottleneck_calibrated']})")
    coll = sorted(ok, key=lambda r: -r["roofline"]["collective_wire_s"])[:5]
    print("most collective-bound:")
    for r in coll:
        print(f"  {r['arch']} × {r['shape']}: wire {r['roofline']['collective_wire_s']:.3f}s "
              f"vs compute {r['roofline']['compute_s']:.3f}s")


if __name__ == "__main__":
    main()
