"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh) cell, TPU v5e constants:

    compute    = HLO_FLOPs_per_device / 197e12          (bf16 MXU peak)
    memory     = HLO_bytes_per_device / 819e9           (HBM bandwidth)
    collective = collective_bytes_per_device / 50e9     (ICI per-link)

Sources: ``compiled.cost_analysis()`` (flops, bytes accessed) on the
SPMD-partitioned per-device module; collective bytes from parsing the
compiled HLO text (operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute).

METHOD NOTE (verified in-repo): XLA cost analysis counts a while-loop body
ONCE, so scanned-layer compiles undercount by n_groups×.  The dry-run
therefore compiles *unrolled* variants with 1 and 2 layer-groups, takes the
per-group delta, and extrapolates:  total = base + (n_groups − 1) · delta.
This is exact because groups are structurally identical.  Peak-memory and
compile-proof come from the full scanned compile.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

__all__ = ["HW", "parse_collective_bytes", "roofline_terms", "CellCost",
           "extrapolate", "model_flops"]

HW = dict(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(pred|[su]\d+|bf16|f\d+|c\d+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic from the SPMD-partitioned HLO text.

    The compiled module prints typed shapes only on results, so operand
    sizes are derived from result sizes per collective semantics
    (all-gather result = operand × g; reduce-scatter result = operand / g).
    Two aggregates:
      * ``total``      — Σ operand bytes (the assignment's definition);
      * ``wire_total`` — ring-algorithm bytes on the busiest link per device
        (AR 2·x·(g−1)/g, AG/RS x·(g−1)/g with x = full buffer, A2A/CP x).
    """
    out: dict[str, float] = {}
    wire = 0.0
    wire_f32 = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_part, kind = m.group(1), m.group(2)
        shapes = _SHAPE_RE.findall(result_part)
        rbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        f32_frac = (sum(_shape_bytes(dt, dims) for dt, dims in shapes
                        if dt == "f32") / rbytes) if rbytes else 0.0
        g = _group_size(line)
        if kind == "all-gather":
            operand = rbytes / g
            w = rbytes * (g - 1) / g
        elif kind == "reduce-scatter":
            operand = rbytes * g
            w = rbytes * (g - 1)
        elif kind == "all-reduce":
            operand = rbytes
            w = 2.0 * rbytes * (g - 1) / g
        else:  # all-to-all, collective-permute
            operand = rbytes
            w = rbytes
        out[kind] = out.get(kind, 0.0) + operand
        wire += w
        wire_f32 += w * f32_frac
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["wire_total"] = wire
    # CPU-backend legalization upcasts bf16 dots to f32 BEFORE the SPMD
    # collectives (verified in-repo); a TPU-native compile keeps them bf16.
    # Adjusted wire assumes every f32 collective is bf16 on the real target.
    out["wire_bf16adj"] = wire - 0.5 * wire_f32
    return out


@dataclass
class CellCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = None

    @classmethod
    def from_compiled(cls, compiled) -> "CellCost":
        ca = compiled.cost_analysis() or {}
        coll = parse_collective_bytes(compiled.as_text())
        return cls(flops=float(ca.get("flops", 0.0)),
                   bytes_accessed=float(ca.get("bytes accessed", 0.0)),
                   collective_bytes=coll["total"], collectives=coll)


def extrapolate(base: CellCost, plus_one: CellCost, n_groups: int) -> CellCost:
    """base = 1-group unrolled compile; plus_one = 2-group.  Exact per-group
    delta × (n_groups - 1) on top of base."""
    k = n_groups - 1
    coll = {key: base.collectives.get(key, 0.0)
            + k * (plus_one.collectives.get(key, 0.0)
                   - base.collectives.get(key, 0.0))
            for key in set(base.collectives) | set(plus_one.collectives)}
    return CellCost(
        flops=base.flops + k * (plus_one.flops - base.flops),
        bytes_accessed=base.bytes_accessed
        + k * (plus_one.bytes_accessed - base.bytes_accessed),
        collective_bytes=max(coll.get("total", 0.0), 0.0),
        collectives=coll,
    )


def roofline_terms(cost: CellCost, memory_floor_bytes: float = 0.0) -> dict:
    """Spec terms + two calibrations:

    ``memory_s`` uses HLO bytes-accessed, an *unfused upper bound* (the XLA
    cost model counts every op's operands; post-fusion HBM traffic is
    lower).  ``memory_floor_s`` is the sharding-exact per-device resident
    bytes that MUST cross HBM once per step (params + caches + opt state) —
    a tight lower bound, the honest number for decode.  ``collective_s``
    follows the assignment definition (Σ operand bytes / link_bw);
    ``collective_wire_s`` models ring algorithms.
    """
    compute_s = cost.flops / HW["peak_flops"]
    memory_s = cost.bytes_accessed / HW["hbm_bw"]
    memory_floor_s = memory_floor_bytes / HW["hbm_bw"]
    collective_s = cost.collective_bytes / HW["ici_bw"]
    wire_s = (cost.collectives or {}).get("wire_total", 0.0) / HW["ici_bw"]
    wire_adj_s = (cost.collectives or {}).get("wire_bf16adj", wire_s) / HW["ici_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)
    total = max(compute_s, memory_s, collective_s)
    # calibrated bottleneck: memory floor instead of the unfused bound, and
    # the bf16-adjusted wire (TPU-native dtype) instead of CPU-legalized f32
    cal = {"compute_s": compute_s, "memory_floor_s": memory_floor_s,
           "collective_wire_s": wire_adj_s}
    cal_bottleneck = max(cal, key=cal.get)
    cal_total = max(cal.values())
    return {**terms, "memory_floor_s": memory_floor_s,
            "collective_wire_s": wire_s,
            "collective_wire_bf16adj_s": wire_adj_s,
            "bottleneck": bottleneck.replace("_s", ""),
            "bottleneck_calibrated": cal_bottleneck.replace("_s", ""),
            "step_lower_bound_s": total,
            "step_bound_calibrated_s": cal_total,
            "compute_fraction": compute_s / total if total > 0 else 0.0,
            "compute_fraction_calibrated": compute_s / cal_total
            if cal_total > 0 else 0.0}


def tree_local_bytes(sds_tree) -> float:
    """Exact per-device bytes of a ShapeDtypeStruct tree, via shardings."""
    import jax
    import numpy as np

    total = 0.0
    for leaf in jax.tree.leaves(sds_tree):
        sh = getattr(leaf, "sharding", None)
        if sh is not None and leaf.shape:
            local = sh.shard_shape(leaf.shape)
        else:
            local = leaf.shape
        total += float(np.prod(local or (1,))) * leaf.dtype.itemsize
    return total


def model_flops(cfg, shape, n_devices: int) -> float:
    """Analytic useful FLOPs per device: 6·N_active·tokens (train) or
    2·N_active·tokens (inference forward)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / n_devices
