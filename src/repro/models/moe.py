"""Mixture-of-Experts layer: top-k routing + capacity-bounded dispatch.

TPU-native design notes (DESIGN.md §2): GShard's one-hot einsum dispatch
costs 2·T·(Tg·k·cf)·d FLOPs — at E=128/top-8 that is ~30-100× the expert
GEMMs themselves, so we use *index-based* dispatch instead: a tiny int32
slot table (invert token→(expert, position) with a scatter), then gather
token rows into per-expert capacity buffers.  Zero matmul overhead; the
moved bytes are O(T·k·d).

Expert parallelism runs in ``shard_map`` — manual over the ``model`` mesh
axis (experts sharded E_loc = E/|model|), auto over data/pod (the batch dim
stays GSPMD-managed).  Per device:

    all_gather(x, model)               # residual arrives sequence-sharded
    route on the full local batch      # deterministic, replicated compute
    gather rows for MY experts → FFN   # (B, E_loc, C, d)
    scatter-add weighted outputs       # partial (B, S, d)
    psum_scatter(out, model)           # back to sequence-sharded residual

Capacity is per sequence: C = ceil(S·k·cf / E) — routing never crosses the
batch dim, so data sharding needs no token exchange (DP×EP grid).  Dropped
tokens (position ≥ C) pass through the residual untouched.

``apply_moe_local`` is the identical math on one device (E_loc = E); it is
the CPU test path and the oracle for the sharded path.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, current_rules, shard_map
from repro.models.layers import dense_init

__all__ = ["moe_init", "moe_specs", "apply_moe", "apply_moe_local",
           "apply_moe_ref", "moe_capacity"]


def moe_init(key, cfg, dtype):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.experts_p
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (d, E), d, jnp.float32),
        "w_gate": dense_init(k2, (E, d, f), d, dtype),
        "w_up": dense_init(k3, (E, d, f), d, dtype),
        "w_down": dense_init(k4, (E, f, d), f, dtype),
    }


def moe_specs(cfg):
    # expert weights are FSDP-sharded over the data axis ("fsdp") in addition
    # to expert parallelism — 470 GB of qwen3-moe experts fit 256 chips only
    # as E/16 × d/16 shards; the full (E_loc, d, f) panel is all-gathered
    # per layer inside shard_map (ZeRO-3 weight gathering).
    return {"router": (None, None),
            "w_gate": ("experts", "fsdp", None),
            "w_up": ("experts", "fsdp", None),
            "w_down": ("experts", "fsdp", None)}


def moe_capacity(cfg, seq_len: int) -> int:
    c = math.ceil(seq_len * cfg.experts_per_token * cfg.capacity_factor
                  / cfg.n_experts)
    return max(4, min(int(c), seq_len)) if seq_len > 1 else cfg.experts_per_token


# ---------------------------------------------------------------------------
# routing: token -> (expert, position-in-expert) with per-sequence capacity
# ---------------------------------------------------------------------------

def _route(cfg, x, router, capacity):
    """x (B, S, d) -> gates (B,S,k), slot (B,S,k) in [0, E*C] (E*C = dropped),
    slot_token (B, E*C+1) int32 inverse table (token index per slot, S = empty).
    """
    B, S, _ = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    C = capacity
    logits = (x.astype(jnp.float32) @ router)                        # (B,S,Ep)
    if router.shape[1] != E:
        # mesh-padding experts are never routed to
        pad_mask = jnp.arange(router.shape[1]) >= E
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
        logits = logits[..., :]  # keep Ep width; padded cols softmax to ~0
    gates_full = jax.nn.softmax(logits, axis=-1)
    gk, ik = jax.lax.top_k(gates_full, k)                            # (B,S,k)
    gk = gk / jnp.maximum(gk.sum(axis=-1, keepdims=True), 1e-9)
    # position-in-expert: priority by (k, token): all rank-0 choices first
    counts = jnp.zeros((B, E), jnp.int32)
    pos = []
    for j in range(k):
        oh = jax.nn.one_hot(ik[:, :, j], E, dtype=jnp.int32)         # (B,S,E)
        within = jnp.cumsum(oh, axis=1) - oh                         # rank among same-k
        pos_j = jnp.take_along_axis(counts, ik[:, :, j], axis=1) \
            + jnp.take_along_axis(within, ik[:, :, j][..., None], axis=2)[..., 0]
        pos.append(pos_j)
        counts = counts + oh.sum(axis=1)
    pos = jnp.stack(pos, axis=-1)                                    # (B,S,k)
    dropped = pos >= C
    Etab = cfg.experts_p       # slot table spans padded experts (empty rows)
    slot = jnp.where(dropped, Etab * C, ik * C + pos)                # (B,S,k)
    # invert: slot -> token index (scatter; last write wins, slots unique)
    token_ids = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :, None],
                                 (B, S, k)).reshape(B, S * k)
    slot_token = jnp.full((B, Etab * C + 1), S, jnp.int32)
    slot_token = jax.vmap(
        lambda st, sl, ti: st.at[sl].set(ti, mode="drop")
    )(slot_token, slot.reshape(B, S * k), token_ids)
    return gk, slot, slot_token, gates_full


def _expert_ffn(cfg, w_gate, w_up, w_down, xin):
    """xin (B, E_loc, C, d) -> (B, E_loc, C, d); SwiGLU per expert."""
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xin, w_gate)) \
        * jnp.einsum("becd,edf->becf", xin, w_up)
    return jnp.einsum("becf,efd->becd", h, w_down)


def _moe_core(cfg, p, x, capacity, e_lo, e_n):
    """Local MoE math for experts [e_lo, e_lo + e_n); x (B, S, d) full-seq."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    C = capacity
    gk, slot, slot_token, _ = _route(cfg, x, p["router"], C)
    # my slice of the slot table
    my_slots = jax.lax.dynamic_slice_in_dim(slot_token, e_lo * C, e_n * C, axis=1)
    valid = my_slots < S                                             # (B, e_n*C)
    tok = jnp.where(valid, my_slots, 0)
    xin = jax.vmap(lambda xb, tb: xb[tb])(x, tok)                    # (B, e_n*C, d)
    xin = jnp.where(valid[..., None], xin, 0.0).reshape(B, e_n, C, d)
    w_gate = jax.lax.dynamic_slice_in_dim(p["w_gate"], e_lo, e_n, axis=0)
    w_up = jax.lax.dynamic_slice_in_dim(p["w_up"], e_lo, e_n, axis=0)
    w_down = jax.lax.dynamic_slice_in_dim(p["w_down"], e_lo, e_n, axis=0)
    h = _expert_ffn(cfg, w_gate, w_up, w_down, xin).reshape(B, e_n * C, d)
    h = jnp.where(valid[..., None], h, 0.0)
    # combine: scatter weighted expert outputs back to token rows.
    # gate per slot: slot -> (token t, rank j) via gk gathered by my_slots
    flat_gate = jnp.zeros((B, cfg.experts_p * C + 1), gk.dtype)
    flat_gate = jax.vmap(
        lambda fg, sl, g: fg.at[sl].set(g, mode="drop")
    )(flat_gate, slot.reshape(B, S * k), gk.reshape(B, S * k))
    my_gates = jax.lax.dynamic_slice_in_dim(flat_gate, e_lo * C, e_n * C, axis=1)
    weighted = h * my_gates[..., None].astype(h.dtype)
    out = jnp.zeros((B, S, d), h.dtype)
    out = jax.vmap(
        lambda ob, tb, hb: ob.at[tb].add(hb, mode="drop")
    )(out, tok, jnp.where(valid[..., None], weighted, 0.0))
    return out


def apply_moe_local(p, cfg, x, capacity=None):
    """Single-device path (CPU tests; oracle for the sharded path)."""
    C = capacity or moe_capacity(cfg, x.shape[1])
    return _moe_core(cfg, p, x.astype(jnp.float32).astype(x.dtype), C,
                     0, cfg.n_experts).astype(x.dtype)


def apply_moe(p, cfg, x):
    """Dispatch: shard_map expert parallelism when a mesh with a >1 'model'
    axis is active; local math otherwise.

    Full-manual shard_map over every mesh axis (the partial-manual
    ``axis_names`` mode miscompiles on the CPU backend): batch stays sharded
    over the data/pod axes (routing is per-sequence, so data shards never
    exchange tokens), experts shard over 'model', and the sequence-sharded
    residual is all-gathered in / psum-scattered out — the Megatron-SP
    pattern made explicit."""
    rules = current_rules()
    mesh = getattr(rules, "mesh", None) if rules is not None else None
    if mesh is None or mesh.shape.get("model", 1) == 1:
        return apply_moe_local(p, cfg, x)

    B, S, d = x.shape
    n_model = mesh.shape["model"]
    C = moe_capacity(cfg, S)
    E = cfg.n_experts
    e_per = cfg.experts_p // n_model   # padded expert count divides exactly
    seq_sharded = S > 1 and S % n_model == 0
    batch_axes = rules.resolve("batch")   # ("pod","data") | "data" | None

    use_a2a = (seq_sharded
               and getattr(rules, "table", {}).get("moe_dispatch") == "a2a")

    def shard_fn(p_loc, x_loc):
        midx = jax.lax.axis_index("model")
        # FSDP weight gathering: (E_loc, d/|data|, f) -> (E_loc, d, f)
        p_full = dict(p_loc)
        for w in ("w_gate", "w_up", "w_down"):
            p_full[w] = jax.lax.all_gather(p_loc[w], "data", axis=1, tiled=True)
        e_lo = midx * e_per
        if use_a2a:
            return _moe_a2a(cfg, p_full, x_loc, n_model, e_per)
        if seq_sharded:
            xf = jax.lax.all_gather(x_loc, "model", axis=1, tiled=True)
        else:
            xf = x_loc
        out_partial = _moe_core_padded(cfg, p_full, xf, C, e_lo, e_per, E)
        if seq_sharded:
            return jax.lax.psum_scatter(out_partial, "model",
                                        scatter_dimension=1, tiled=True)
        return jax.lax.psum(out_partial, "model")

    x_spec = P(batch_axes, "model" if seq_sharded else None, None)
    p_specs = {"router": P(None, None), "w_gate": P("model", "data", None),
               "w_up": P("model", "data", None), "w_down": P("model", "data", None)}
    fn = shard_map(shard_fn, mesh=mesh, in_specs=(p_specs, x_spec),
                   out_specs=x_spec, check_vma=False)
    return fn(p, x).astype(x.dtype)


def _moe_a2a(cfg, p_full, x_loc, n_model, e_per):
    """All-to-all token dispatch (§Perf iteration 7; GShard/Switch topology).

    Each model shard routes ONLY its own sequence slice (router replicated —
    no x all-gather), packs rows into per-destination capacity buffers,
    exchanges them with one all-to-all, runs its local experts, and reverses
    the exchange.  Wire per layer ≈ 2 × routed-row bytes (k·cf·tokens/16)
    instead of all-gather + psum-scatter of the full residual.  Capacity is
    per (source shard, expert): C_loc = ceil(S_loc·k·cf/E) — a documented
    variant of per-sequence capacity (standard in deployed MoE systems).
    """
    B, S_loc, d = x_loc.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    Etab = cfg.experts_p
    C_loc = max(4, math.ceil(S_loc * k * cfg.capacity_factor / E))
    gk, slot, slot_token, _ = _route(cfg, x_loc, p_full["router"], C_loc)
    valid = slot_token < S_loc                                    # (B, Etab*C+1)
    tok = jnp.where(valid, slot_token, 0)
    rows = jax.vmap(lambda xb, tb: xb[tb])(x_loc, tok)            # (B, Etab*C+1, d)
    rows = jnp.where(valid[..., None], rows, 0.0)
    send = rows[:, :Etab * C_loc].reshape(B, n_model, e_per * C_loc, d)
    send = jnp.moveaxis(send, 1, 0)                               # (n_model, B, eC, d)
    recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                              tiled=True)                         # (n_model, B, eC, d)
    # my experts' rows from every source shard
    xin = jnp.moveaxis(recv, 0, 1).reshape(B, n_model, e_per, C_loc, d)
    xin = jnp.moveaxis(xin, 1, 2).reshape(B, e_per, n_model * C_loc, d)
    h = _expert_ffn(cfg, p_full["w_gate"], p_full["w_up"], p_full["w_down"],
                    xin)                                          # (B, e_per, nC, d)
    # reverse exchange
    h = jnp.moveaxis(h.reshape(B, e_per, n_model, C_loc, d), 2, 1)
    h = jnp.moveaxis(h.reshape(B, n_model, e_per * C_loc, d), 1, 0)
    back = jax.lax.all_to_all(h, "model", split_axis=0, concat_axis=0,
                              tiled=True)
    got = jnp.moveaxis(back, 0, 1).reshape(B, Etab * C_loc, d)
    got = jnp.concatenate([got, jnp.zeros((B, 1, d), got.dtype)], axis=1)
    # combine with gates, scatter back to local token rows
    flat_gate = jnp.zeros((B, Etab * C_loc + 1), gk.dtype)
    flat_gate = jax.vmap(
        lambda fg, sl, g: fg.at[sl].set(g, mode="drop")
    )(flat_gate, slot.reshape(B, S_loc * k), gk.reshape(B, S_loc * k))
    weighted = got * flat_gate[..., None].astype(got.dtype)
    out = jnp.zeros((B, S_loc, d), got.dtype)
    out = jax.vmap(
        lambda ob, tb, hb: ob.at[tb].add(hb, mode="drop")
    )(out, tok, jnp.where(valid[..., None], weighted, 0.0))
    return out


def _moe_core_padded(cfg, p_loc, x, capacity, e_lo, e_n, total_e):
    """_moe_core against locally-sliced expert weights (already E_loc rows),
    masking experts beyond ``total_e`` (padding shards)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    C = capacity
    gk, slot, slot_token, _ = _route(cfg, x, p_loc["router"], C)
    my_slots = jax.lax.dynamic_slice_in_dim(slot_token, e_lo * C, e_n * C, axis=1)
    valid = my_slots < S
    tok = jnp.where(valid, my_slots, 0)
    xin = jax.vmap(lambda xb, tb: xb[tb])(x, tok)
    xin = jnp.where(valid[..., None], xin, 0.0).reshape(B, e_n, C, d)
    h = _expert_ffn(cfg, p_loc["w_gate"], p_loc["w_up"], p_loc["w_down"],
                    xin).reshape(B, e_n * C, d)
    h = jnp.where(valid[..., None], h, 0.0)
    flat_gate = jnp.zeros((B, cfg.experts_p * C + 1), gk.dtype)
    flat_gate = jax.vmap(
        lambda fg, sl, g: fg.at[sl].set(g, mode="drop")
    )(flat_gate, slot.reshape(B, S * k), gk.reshape(B, S * k))
    my_gates = jax.lax.dynamic_slice_in_dim(flat_gate, e_lo * C, e_n * C, axis=1)
    weighted = h * my_gates[..., None].astype(h.dtype)
    out = jnp.zeros((B, S, d), h.dtype)
    out = jax.vmap(
        lambda ob, tb, hb: ob.at[tb].add(hb, mode="drop")
    )(out, tok, jnp.where(valid[..., None], weighted, 0.0))
    return out


def apply_moe_ref(p, cfg, x):
    """Dropless dense reference: every expert on every token, gate-masked.
    O(T·E·d·f) — tiny test sizes only.  Capacity-dropping in the real path
    means outputs match only when capacity is not exceeded."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    logits = x.astype(jnp.float32) @ p["router"]
    gates_full = jax.nn.softmax(logits, axis=-1)
    gk, ik = jax.lax.top_k(gates_full, k)
    gk = gk / jnp.maximum(gk.sum(axis=-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for e in range(E):
        h = jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])
        ye = (h @ p["w_down"][e]).astype(jnp.float32)
        gate_e = jnp.where(ik == e, gk, 0.0).sum(axis=-1)            # (B,S)
        out = out + ye * gate_e[..., None]
    return out.astype(x.dtype)
