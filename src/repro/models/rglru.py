"""RG-LRU recurrent block (Griffin / RecurrentGemma).  [arXiv:2402.19427]

Block: u -> (x = W_x u, gate = gelu(W_y u)) ; causal depthwise conv(4) on x;
RG-LRU gated linear recurrence; out = (lru ⊙ gate) @ W_out.

RG-LRU per channel:
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_i x_t + b_i)            input gate
    a_t = exp(c · r_t · (-softplus(Λ)))     with c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The linear recurrence h_t = a_t h_{t-1} + b_t runs as an associative scan
over the sequence (log-depth on TPU); decode is the single-step recurrence
with a (B, W) hidden state + conv history — O(1) in context length, which is
why the hybrid runs the ``long_500k`` cell.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import dense_init

__all__ = ["rglru_init", "rglru_specs", "apply_rglru", "rglru_cache_init",
           "rglru_cache_specs", "rglru_decode_step"]

_C = 8.0  # Griffin's fixed recurrence sharpness


def _width(cfg):
    return cfg.lru_width or cfg.d_model


def rglru_init(key, cfg, dtype):
    d, w = cfg.d_model, _width(cfg)
    ks = jax.random.split(key, 6)
    # Λ init so a^c spans ~(0.9, 0.999) as in the paper
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))   # softplus^-1(-log u / c)
    return {
        "w_x": dense_init(ks[0], (d, w), d, dtype),
        "w_gate": dense_init(ks[1], (d, w), d, dtype),
        "conv_w": (jax.random.normal(ks[2], (4, w), jnp.float32) / 2.0).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": dense_init(ks[3], (w, w), w, dtype),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": dense_init(ks[5], (w, w), w, dtype),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "w_out": dense_init(jax.random.fold_in(key, 7), (w, d), w, dtype),
    }


def rglru_specs(cfg):
    return {"w_x": (None, "lru"), "w_gate": (None, "lru"),
            "conv_w": (None, "lru"), "conv_b": ("lru",),
            "w_a": (None, "lru"), "b_a": ("lru",),
            "w_i": (None, "lru"), "b_i": ("lru",),
            "lam": ("lru",), "w_out": ("lru", None)}


def _conv(x, conv_w, conv_b, state=None):
    W = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    full = jnp.concatenate([pad, x], axis=1)
    out = sum(full[:, i:i + x.shape[1]] * conv_w[i][None, None] for i in range(W))
    return out + conv_b[None, None], full[:, -(W - 1):]


def _gates(p, x):
    """x (..., w) -> log_a (fp32), gated input b (fp32)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -_C * r * jax.nn.softplus(p["lam"])          # ≤ 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return a, b


def apply_rglru(p, cfg, u, h0=None, conv_state=None, return_state=False):
    """u (B, S, d) -> (B, S, d)."""
    x = u @ p["w_x"]
    gate = jax.nn.gelu((u @ p["w_gate"]).astype(jnp.float32))
    x, new_conv = _conv(x, p["conv_w"], p["conv_b"], conv_state)
    x = constrain(x, ("batch", None, "act_lru"))
    a, b = _gates(p, x)
    if h0 is not None:
        # fold the initial state into the first step: h_1 = a_1 h0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = (h * gate).astype(u.dtype) @ p["w_out"]
    if return_state:
        return out, (h[:, -1], new_conv)
    return out


def rglru_cache_init(cfg, batch, dtype=jnp.float32):
    w = _width(cfg)
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, 3, w), dtype)}


def rglru_cache_specs(cfg):
    return {"h": ("batch", "lru"), "conv": ("batch", None, "lru")}


def rglru_decode_step(p, cfg, u, cache):
    """u (B, 1, d) -> (out (B,1,d), new cache)."""
    x = u @ p["w_x"]
    gate = jax.nn.gelu((u @ p["w_gate"]).astype(jnp.float32))
    x, new_conv = _conv(x, p["conv_w"], p["conv_b"], cache["conv"])
    a, b = _gates(p, x)
    h = a[:, 0] * cache["h"] + b[:, 0]
    out = (h[:, None] * gate).astype(u.dtype) @ p["w_out"]
    return out, {"h": h, "conv": new_conv.astype(cache["conv"].dtype)}
