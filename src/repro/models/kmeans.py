"""MiniBatch K-Means in JAX — the paper's representative streaming workload.

K-Means has complexity O(n·c): phase 1 computes Euclidean distances between
all n points and c centroids (the compute hot-spot, implemented as the
``kmeans_distance`` Pallas kernel on TPU with a jnp fallback elsewhere);
phase 2 updates centroid positions with the MiniBatch rule (Sculley 2010 /
sklearn MiniBatchKMeans): per-centroid counts give a decaying learning rate
``eta = m_batch / count`` so centroids converge as streams arrive.

The model state (centroids, counts) is what the paper shares across tasks
via file storage (S3 / Lustre) — see ``core.miniapp`` for how the sharing
policy maps to backend mechanisms.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["KMeansState", "init_state", "assign", "minibatch_step", "inertia"]


class KMeansState(NamedTuple):
    centroids: jax.Array   # (c, d)
    counts: jax.Array      # (c,) — per-centroid cumulative assignment counts


def init_state(key: jax.Array, n_centroids: int, dim: int, scale: float = 1.0) -> KMeansState:
    centroids = scale * jax.random.normal(key, (n_centroids, dim), dtype=jnp.float32)
    return KMeansState(centroids=centroids, counts=jnp.zeros((n_centroids,), jnp.float32))


def _pairwise_sq_dists(points: jax.Array, centroids: jax.Array) -> jax.Array:
    """(n, c) squared Euclidean distances via the matmul formulation
    ||x||^2 + ||c||^2 - 2 x.c^T — the MXU-friendly form the Pallas kernel tiles."""
    from repro.kernels.kmeans_distance import ops as kd_ops

    return kd_ops.pairwise_sq_dists(points, centroids)


def assign(points: jax.Array, centroids: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (labels (n,), sq_dist_to_assigned (n,))."""
    d2 = _pairwise_sq_dists(points, centroids)
    labels = jnp.argmin(d2, axis=1)
    best = jnp.min(d2, axis=1)
    return labels, best


@partial(jax.jit, donate_argnums=(0,))
def minibatch_step(state: KMeansState, points: jax.Array) -> KMeansState:
    """One MiniBatch K-Means update on a batch of points (n, d)."""
    labels, _ = assign(points, state.centroids)
    c = state.centroids.shape[0]
    onehot = jax.nn.one_hot(labels, c, dtype=points.dtype)          # (n, c)
    batch_counts = onehot.sum(axis=0)                               # (c,)
    batch_sums = onehot.T @ points                                  # (c, d)
    new_counts = state.counts + batch_counts
    # decaying per-centroid rate; centroids with no assignments unchanged
    eta = jnp.where(new_counts > 0, batch_counts / jnp.maximum(new_counts, 1.0), 0.0)
    batch_means = batch_sums / jnp.maximum(batch_counts, 1.0)[:, None]
    new_centroids = (1.0 - eta)[:, None] * state.centroids + eta[:, None] * batch_means
    return KMeansState(centroids=new_centroids, counts=new_counts)


@jax.jit
def inertia(points: jax.Array, centroids: jax.Array) -> jax.Array:
    """Mean squared distance to the assigned centroid (clustering quality)."""
    _, best = assign(points, centroids)
    return jnp.mean(best)


def flops_estimate(n: int, c: int, d: int) -> float:
    """Analytic FLOPs of one minibatch step (distance phase dominates: 3ncd)."""
    distance = 3.0 * n * c * d
    update = 2.0 * n * c + 2.0 * n * d + 6.0 * c * d
    return distance + update
