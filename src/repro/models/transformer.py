"""Block composition + scan-over-layers stack.

A config's layer sequence is ``block_pattern × n_groups + tail_pattern``.
The repeated group is executed under ``lax.scan`` with stacked params
(leading ``n_groups`` axis) and a configurable remat policy — this keeps the
HLO small (compile time O(1) in depth) and bounds activation memory; the
tail layers run unrolled.

Block kinds: ``attn`` (GQA + MLP), ``local_attn`` (windowed), ``moe``
(GQA + expert MLP), ``ssm`` (Mamba-2, single residual), ``rglru``
(Griffin recurrent + MLP).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_mlp, apply_norm, mlp_init, mlp_specs, \
    norm_init, norm_specs

__all__ = ["block_init", "block_specs", "apply_block", "block_cache_init",
           "block_cache_specs", "decode_block", "stack_init", "stack_specs",
           "apply_stack", "stack_cache_init", "stack_cache_specs",
           "decode_stack"]

_ATTN_KINDS = ("attn", "local_attn", "moe")


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def block_init(key, cfg, kind, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"norm1": norm_init(cfg.d_model, cfg.norm_type, dtype)}
    if kind in _ATTN_KINDS:
        p["attn"] = attn_mod.attention_init(k1, cfg, dtype)
        p["norm2"] = norm_init(cfg.d_model, cfg.norm_type, dtype)
        p["ffn"] = (moe_mod.moe_init(k2, cfg, dtype) if kind == "moe"
                    else mlp_init(k2, cfg, dtype))
    elif kind == "ssm":
        p["ssm"] = ssm_mod.ssm_init(k3, cfg, dtype)
    elif kind == "rglru":
        p["rec"] = rglru_mod.rglru_init(k4, cfg, dtype)
        p["norm2"] = norm_init(cfg.d_model, cfg.norm_type, dtype)
        p["ffn"] = mlp_init(k2, cfg, dtype)
    else:
        raise ValueError(kind)
    return p


def block_specs(cfg, kind):
    p = {"norm1": norm_specs(cfg.norm_type)}
    if kind in _ATTN_KINDS:
        p["attn"] = attn_mod.attention_specs(cfg)
        p["norm2"] = norm_specs(cfg.norm_type)
        p["ffn"] = moe_mod.moe_specs(cfg) if kind == "moe" else mlp_specs(cfg)
    elif kind == "ssm":
        p["ssm"] = ssm_mod.ssm_specs(cfg)
    elif kind == "rglru":
        p["rec"] = rglru_mod.rglru_specs(cfg)
        p["norm2"] = norm_specs(cfg.norm_type)
        p["ffn"] = mlp_specs(cfg)
    return p


def _res(cfg, x):
    return constrain(x, ("batch", "act_seq", None))


def apply_block(p, cfg, kind, x, positions, cache=None):
    """Training/prefill forward.  Returns (x, cache_or_None)."""
    window = cfg.local_window if kind == "local_attn" else 0
    new_cache = None
    if kind in _ATTN_KINDS:
        h = apply_norm(p["norm1"], x, cfg.norm_type, cfg.norm_eps)
        if cache is not None:
            a, new_cache = attn_mod.prefill_into_cache(
                p["attn"], cfg, h, positions, cache, window)
        else:
            a, _ = attn_mod.attend(p["attn"], cfg, h, positions, window)
        x = _res(cfg, x + a)
        h = apply_norm(p["norm2"], x, cfg.norm_type, cfg.norm_eps)
        f = (moe_mod.apply_moe(p["ffn"], cfg, h) if kind == "moe"
             else apply_mlp(p["ffn"], cfg, h))
        x = _res(cfg, x + f)
    elif kind == "ssm":
        h = apply_norm(p["norm1"], x, cfg.norm_type, cfg.norm_eps)
        if cache is not None:
            s, (hT, conv) = ssm_mod.apply_ssm(p["ssm"], cfg, h, return_state=True)
            new_cache = {"h": hT, "conv": conv.astype(cache["conv"].dtype)}
        else:
            s = ssm_mod.apply_ssm(p["ssm"], cfg, h)
        x = _res(cfg, x + s)
    elif kind == "rglru":
        h = apply_norm(p["norm1"], x, cfg.norm_type, cfg.norm_eps)
        if cache is not None:
            r, (hT, conv) = rglru_mod.apply_rglru(p["rec"], cfg, h, return_state=True)
            new_cache = {"h": hT, "conv": conv.astype(cache["conv"].dtype)}
        else:
            r = rglru_mod.apply_rglru(p["rec"], cfg, h)
        x = _res(cfg, x + r)
        h = apply_norm(p["norm2"], x, cfg.norm_type, cfg.norm_eps)
        x = _res(cfg, x + apply_mlp(p["ffn"], cfg, h))
    else:
        raise ValueError(kind)
    return x, new_cache


def block_cache_init(cfg, kind, batch, cache_len, dtype=jnp.bfloat16):
    if kind == "attn" or kind == "moe":
        return attn_mod.init_cache(cfg, batch, cache_len, 0, dtype)
    if kind == "local_attn":
        return attn_mod.init_cache(cfg, batch, cache_len, cfg.local_window, dtype)
    if kind == "ssm":
        return ssm_mod.ssm_cache_init(cfg, batch, dtype)
    if kind == "rglru":
        return rglru_mod.rglru_cache_init(cfg, batch, dtype)
    raise ValueError(kind)


def block_cache_specs(cfg, kind):
    if kind in ("attn", "moe"):
        return attn_mod.cache_specs(0)
    if kind == "local_attn":
        return attn_mod.cache_specs(cfg.local_window)
    if kind == "ssm":
        return ssm_mod.ssm_cache_specs(cfg)
    if kind == "rglru":
        return rglru_mod.rglru_cache_specs(cfg)
    raise ValueError(kind)


def decode_block(p, cfg, kind, x, cache, pos):
    """One-token decode.  x (B, 1, d); returns (x, new_cache)."""
    window = cfg.local_window if kind == "local_attn" else 0
    if kind in _ATTN_KINDS:
        h = apply_norm(p["norm1"], x, cfg.norm_type, cfg.norm_eps)
        a, cache = attn_mod.decode_step(p["attn"], cfg, h, cache, pos, window)
        x = x + a
        h = apply_norm(p["norm2"], x, cfg.norm_type, cfg.norm_eps)
        f = (moe_mod.apply_moe(p["ffn"], cfg, h) if kind == "moe"
             else apply_mlp(p["ffn"], cfg, h))
        x = x + f
    elif kind == "ssm":
        h = apply_norm(p["norm1"], x, cfg.norm_type, cfg.norm_eps)
        s, cache = ssm_mod.ssm_decode_step(p["ssm"], cfg, h, cache)
        x = x + s
    elif kind == "rglru":
        h = apply_norm(p["norm1"], x, cfg.norm_type, cfg.norm_eps)
        r, cache = rglru_mod.rglru_decode_step(p["rec"], cfg, h, cache)
        x = x + r
        h = apply_norm(p["norm2"], x, cfg.norm_type, cfg.norm_eps)
        x = x + apply_mlp(p["ffn"], cfg, h)
    return x, cache


# ---------------------------------------------------------------------------
# stacked layers: scanned groups + unrolled tail
# ---------------------------------------------------------------------------

def _group_init(key, cfg, dtype):
    ks = jax.random.split(key, len(cfg.block_pattern))
    return {f"b{i}_{kind}": block_init(ks[i], cfg, kind, dtype)
            for i, kind in enumerate(cfg.block_pattern)}


def stack_init(key, cfg, dtype):
    kg, kt = jax.random.split(key)
    groups = [
        _group_init(jax.random.fold_in(kg, g), cfg, dtype)
        for g in range(cfg.n_groups)
    ]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *groups) \
        if cfg.n_groups > 1 else jax.tree.map(lambda x: x[None], groups[0])
    tail = [block_init(jax.random.fold_in(kt, i), cfg, kind, dtype)
            for i, kind in enumerate(cfg.tail_pattern)]
    return {"groups": stacked, "tail": tail}


def stack_specs(cfg):
    group = {f"b{i}_{kind}": block_specs(cfg, kind)
             for i, kind in enumerate(cfg.block_pattern)}
    # leading layer axis is unsharded
    group = jax.tree.map(lambda spec: (None,) + tuple(spec), group,
                         is_leaf=lambda s: isinstance(s, tuple))
    tail = [block_specs(cfg, kind) for kind in cfg.tail_pattern]
    return {"groups": group, "tail": tail}


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)


def apply_stack(params, cfg, x, positions, caches=None):
    """Forward through all layers.  With ``caches`` (prefill) the per-layer
    caches are threaded and returned updated."""
    with_cache = caches is not None

    def group_fn(x, inp):
        gp, gcache = inp
        new_caches = {}
        for i, kind in enumerate(cfg.block_pattern):
            key = f"b{i}_{kind}"
            c = gcache[key] if with_cache else None
            x, nc = apply_block(gp[key], cfg, kind, x, positions, c)
            new_caches[key] = nc
        return x, (new_caches if with_cache else None)

    body = _remat(cfg, group_fn)
    if not cfg.scan_layers:
        # unrolled path: used by the roofline analysis compiles (XLA cost
        # analysis counts a scan body once — see EXPERIMENTS.md §Method)
        new_group_list = []
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda a: a[g], params["groups"])
            gc = jax.tree.map(lambda a: a[g], caches["groups"]) if with_cache else None
            x, nc = body(x, (gp, gc))
            new_group_list.append(nc)
        new_group_caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_group_list)
                            if with_cache else None)
    elif with_cache:
        x, new_group_caches = jax.lax.scan(
            body, x, (params["groups"], caches["groups"]))
    else:
        x, _ = jax.lax.scan(lambda c, gp: body(c, (gp, None)), x, params["groups"])
        new_group_caches = None
    new_tail = []
    for i, kind in enumerate(cfg.tail_pattern):
        c = caches["tail"][i] if with_cache else None
        x, nc = apply_block(params["tail"][i], cfg, kind, x, positions, c)
        new_tail.append(nc)
    if with_cache:
        return x, {"groups": new_group_caches, "tail": new_tail}
    return x, None


def stack_cache_init(cfg, batch, cache_len, dtype=jnp.bfloat16):
    def group_cache(g):
        return {f"b{i}_{kind}": block_cache_init(cfg, kind, batch, cache_len, dtype)
                for i, kind in enumerate(cfg.block_pattern)}

    groups = [group_cache(g) for g in range(cfg.n_groups)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *groups) \
        if cfg.n_groups > 1 else jax.tree.map(lambda x: x[None], groups[0])
    tail = [block_cache_init(cfg, kind, batch, cache_len, dtype)
            for kind in cfg.tail_pattern]
    return {"groups": stacked, "tail": tail}


def stack_cache_specs(cfg):
    group = {f"b{i}_{kind}": block_cache_specs(cfg, kind)
             for i, kind in enumerate(cfg.block_pattern)}
    group = jax.tree.map(lambda spec: (None,) + tuple(spec), group,
                         is_leaf=lambda s: isinstance(s, tuple))
    tail = [block_cache_specs(cfg, kind) for kind in cfg.tail_pattern]
    return {"groups": group, "tail": tail}


def decode_stack(params, cfg, x, caches, pos):
    def group_fn(x, inp):
        gp, gcache = inp
        new_caches = {}
        for i, kind in enumerate(cfg.block_pattern):
            key = f"b{i}_{kind}"
            x, nc = decode_block(gp[key], cfg, kind, x, gcache[key], pos)
            new_caches[key] = nc
        return x, new_caches

    if not cfg.scan_layers:
        new_group_list = []
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda a: a[g], params["groups"])
            gc = jax.tree.map(lambda a: a[g], caches["groups"])
            x, nc = group_fn(x, (gp, gc))
            new_group_list.append(nc)
        new_group_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_group_list)
        new_tail = []
        for i, kind in enumerate(cfg.tail_pattern):
            x, nc = decode_block(params["tail"][i], cfg, kind, x,
                                 caches["tail"][i], pos)
            new_tail.append(nc)
        return x, {"groups": new_group_caches, "tail": new_tail}
    x, new_group_caches = jax.lax.scan(group_fn, x,
                                       (params["groups"], caches["groups"]))
    new_tail = []
    for i, kind in enumerate(cfg.tail_pattern):
        x, nc = decode_block(params["tail"][i], cfg, kind, x, caches["tail"][i], pos)
        new_tail.append(nc)
    return x, {"groups": new_group_caches, "tail": new_tail}
