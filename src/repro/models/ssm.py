"""Mamba-2 (SSD — state-space duality) block in JAX.  [arXiv:2405.21060]

Chunked SSD algorithm (the paper's Listing 1, ported to JAX): sequence split
into chunks of Q; within a chunk the recurrence is computed in its dual
quadratic "attention" form (MXU-friendly), across chunks a tiny recurrence
on the (H, P, N) states links them.  Decode is the pure recurrence — O(1)
in sequence length, which is what makes the ``long_500k`` cell tractable.

Block layout (Mamba-2 defaults): in-proj → causal depthwise conv(4) on
(x,B,C) → SSD → gated RMSNorm → out-proj.  Scalar A per head; ngroups=1.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import dense_init

__all__ = ["ssm_init", "ssm_specs", "apply_ssm", "ssm_cache_init",
           "ssm_cache_specs", "ssm_decode_step", "ssd_chunked", "ssd_recurrent"]


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state


def ssm_init(key, cfg, dtype):
    d = cfg.d_model
    di, nh, ns = _dims(cfg)
    conv_ch = di + 2 * ns                     # x, B, C all pass the conv
    ks = jax.random.split(key, 8)
    dt = jnp.exp(jax.random.uniform(ks[6], (nh,), jnp.float32)
                 * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    return {
        "in_z": dense_init(ks[0], (d, di), d, dtype),
        "in_x": dense_init(ks[1], (d, di), d, dtype),
        "in_B": dense_init(ks[2], (d, ns), d, dtype),
        "in_C": dense_init(ks[3], (d, ns), d, dtype),
        "in_dt": dense_init(ks[4], (d, nh), d, dtype),
        "conv_w": (jax.random.normal(ks[5], (cfg.ssm_conv, conv_ch), jnp.float32)
                   / math.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt)),    # softplus^-1(dt)
        "norm_scale": jnp.ones((di,), dtype),
        "out": dense_init(ks[7], (di, d), di, dtype),
    }


def ssm_specs(cfg):
    return {"in_z": (None, "ssm_inner"), "in_x": (None, "ssm_inner"),
            "in_B": (None, None), "in_C": (None, None),
            "in_dt": (None, None), "conv_w": (None, None), "conv_b": (None,),
            "A_log": (None,), "D": (None,), "dt_bias": (None,),
            "norm_scale": ("ssm_inner",), "out": ("ssm_inner", None)}


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv over time.  xbc (B, S, CH); conv_w (W, CH).
    With ``conv_state`` (B, W-1, CH) the history is prepended (decode)."""
    W = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)                       # (B, S+W-1, CH)
    out = sum(full[:, i:i + xbc.shape[1]] * conv_w[i][None, None]
              for i in range(W))
    return jax.nn.silu(out + conv_b[None, None]), full[:, -(W - 1):]


def _segsum(a):
    """a (..., L) -> (..., L, L) lower-tri cumulative sums: sum_{i<s<=j} a_s."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]                       # (..., j, i)
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk, h0=None):
    """SSD in chunked dual form.

    x  (B, S, H, P) inputs per head
    dt (B, S, H)    softplus'd step sizes
    A  (H,)         negative scalars
    Bm, Cm (B, S, N) shared across heads (ngroups=1)
    Returns y (B, S, H, P) and final state (B, H, P, N).
    """
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    xb = x.reshape(Bsz, nc, Q, H, Pd)
    dtb = dt.reshape(Bsz, nc, Q, H)
    Bb = Bm.reshape(Bsz, nc, Q, N)
    Cb = Cm.reshape(Bsz, nc, Q, N)
    a = dtb * A[None, None, None]                                    # (B,nc,Q,H) ≤ 0
    a = jnp.moveaxis(a, -1, 1)                                       # (B,H,nc,Q)
    a_cum = jnp.cumsum(a, axis=-1)
    L = jnp.exp(_segsum(a))                                          # (B,H,nc,Q,Q)
    xdt = xb * dtb[..., None]                                        # dt-weighted input
    # intra-chunk (dual quadratic form)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cb, Bb, L, xdt)
    # chunk-final states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)                  # (B,H,nc,Q)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bb, decay_states, xdt)
    if h0 is not None:
        states = jnp.concatenate([h0[:, None], states], axis=1)      # (B,nc+1,H,P,N)
    else:
        states = jnp.concatenate([jnp.zeros_like(states[:, :1]), states], axis=1)
    # inter-chunk recurrence (over nc+1 states)
    chunk_decay = a_cum[..., -1]                                     # (B,H,nc)
    pad = jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))
    dec = jnp.exp(_segsum(pad))                                      # (B,H,nc+1,nc+1)
    dec = jnp.where(jnp.isfinite(dec), dec, 0.0)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", dec, states)        # (B,nc+1,H,P,N)
    prev_states, final_state = new_states[:, :-1], new_states[:, -1]
    # inter-chunk contribution to outputs
    state_decay = jnp.exp(a_cum)                                     # (B,H,nc,Q)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cb, prev_states, state_decay)
    y = (y_diag + y_off).reshape(Bsz, S, H, Pd)
    return y, final_state


def ssd_recurrent(x, dt, A, Bm, Cm, h0):
    """Single-step recurrence (decode).  x (B,1,H,P) ... h0 (B,H,P,N)."""
    a = jnp.exp(dt[:, 0] * A[None])                                  # (B,H)
    xdt = x[:, 0] * dt[:, 0, :, None]                                # (B,H,P)
    h = a[..., None, None] * h0 + jnp.einsum("bhp,bn->bhpn", xdt, Bm[:, 0])
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], h)
    return y[:, None], h


def _gated_norm(y, z, scale, eps):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32))


def _proj_all(p, cfg, x):
    di, nh, ns = _dims(cfg)
    z = x @ p["in_z"]
    xi = x @ p["in_x"]
    Bm = x @ p["in_B"]
    Cm = x @ p["in_C"]
    dt = jax.nn.softplus((x @ p["in_dt"]).astype(jnp.float32)
                         + p["dt_bias"][None, None])
    return z, xi, Bm, Cm, dt


def apply_ssm(p, cfg, x, h0=None, conv_state=None, return_state=False):
    """Full-sequence Mamba-2 block.  x (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    di, nh, ns = _dims(cfg)
    z, xi, Bm, Cm, dt = _proj_all(p, cfg, x)
    xbc = jnp.concatenate([xi, Bm, Cm], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xi, Bm, Cm = jnp.split(xbc, [di, di + ns], axis=-1)
    xi = constrain(xi, ("batch", None, "ssm_inner"))
    A = -jnp.exp(p["A_log"])
    xh = xi.astype(jnp.float32).reshape(B, S, nh, cfg.ssm_head_dim)
    y, hT = ssd_chunked(xh, dt, A, Bm.astype(jnp.float32),
                        Cm.astype(jnp.float32), cfg.ssm_chunk, h0)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, S, di)
    out = _gated_norm(y, z, p["norm_scale"], cfg.norm_eps).astype(x.dtype)
    out = out @ p["out"]
    if return_state:
        return out, (hT, new_conv)
    return out


# -- decode ------------------------------------------------------------------

def ssm_cache_init(cfg, batch, dtype=jnp.float32):
    di, nh, ns = _dims(cfg)
    conv_ch = di + 2 * ns
    return {"h": jnp.zeros((batch, nh, cfg.ssm_head_dim, ns), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype)}


def ssm_cache_specs(cfg):
    return {"h": ("batch", None, None, None), "conv": ("batch", None, None)}


def ssm_decode_step(p, cfg, x, cache):
    """x (B, 1, d); cache {h, conv} -> (out (B,1,d), new cache)."""
    B = x.shape[0]
    di, nh, ns = _dims(cfg)
    z, xi, Bm, Cm, dt = _proj_all(p, cfg, x)
    xbc = jnp.concatenate([xi, Bm, Cm], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], cache["conv"])
    xi, Bm, Cm = jnp.split(xbc, [di, di + ns], axis=-1)
    A = -jnp.exp(p["A_log"])
    xh = xi.astype(jnp.float32).reshape(B, 1, nh, cfg.ssm_head_dim)
    y, h = ssd_recurrent(xh, dt, A, Bm.astype(jnp.float32),
                         Cm.astype(jnp.float32), cache["h"])
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, 1, di)
    out = _gated_norm(y, z, p["norm_scale"], cfg.norm_eps).astype(x.dtype)
    return out @ p["out"], {"h": h, "conv": new_conv.astype(cache["conv"].dtype)}
