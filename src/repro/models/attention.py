"""GQA attention: full, KV-chunked (memory-bounded), local-window, and decode.

Sharding design (DESIGN.md §5): all attention tensors live in *head-major*
layout — weights (d, H, Dh) / (H, Dh, d), activations (B, S, H, Dh) — and
tensor parallelism shards the H dim.  Flat (H·Dh) sharding is never used:
for head counts not divisible by the model axis (14, 24, 40, 10 here) a
flat split cuts mid-head and every reshape to head layout forces a full
GSPMD reshard (measured: ~24 GB/device/step of spurious all-reduce on
qwen2-0.5b).  Head-dim sharding with uneven counts only pads — idle compute,
zero communication.  KV heads (≤ 8 everywhere) are replicated across the
model axis; decode KV caches shard their *sequence* axis instead
(flash-decoding split-KV; GSPMD inserts the small softmax-stat reductions).

Three execution paths share one set of weights:

* ``attend_full``   — materializes (S, S) scores; only for small tests.
* ``attend_chunked``— flash dataflow in pure JAX: outer lax.map over q
  blocks, inner lax.scan over KV blocks with online softmax.  Peak memory
  O(chunk²); what the 32k-prefill dry-run compiles.  The Pallas flash
  kernel (kernels/flash_attention) is the TPU-compiled equivalent.
* ``decode_step``   — one token against a (possibly ring-buffered) cache.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attention_init(key, cfg, dtype):
    d, h, kv, dh = cfg.d_model, cfg.heads_p, cfg.kv_heads_p, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (d, h, dh), d, dtype),
        "wk": dense_init(k2, (d, kv, dh), d, dtype),
        "wv": dense_init(k3, (d, kv, dh), d, dtype),
        "wo": dense_init(k4, (h, dh, d), h * dh, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), dtype)
        p["bk"] = jnp.zeros((kv, dh), dtype)
        p["bv"] = jnp.zeros((kv, dh), dtype)
    return p


def attention_specs(cfg):
    p = {"wq": (None, "heads", None), "wk": (None, "kv_heads", None),
         "wv": (None, "kv_heads", None), "wo": ("heads", None, None)}
    if cfg.qkv_bias:
        p["bq"] = ("heads", None)
        p["bk"] = ("kv_heads", None)
        p["bv"] = ("kv_heads", None)
    return p


def _project_qkv(p, cfg, x, positions):
    """x (B, S, d) -> q (B,S,H,Dh) head-sharded; k/v (B,S,KV,Dh) replicated."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q, ("batch", None, "act_heads", None))
    k = constrain(k, ("batch", None, "act_kv", None))
    v = constrain(v, ("batch", None, "act_kv", None))
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(t, cfg):
    """(B, S, KVp, Dh) -> (B, S, Hp, Dh); local (kv replicated, h sharded)."""
    g = cfg.heads_p // cfg.kv_heads_p
    if g == 1:
        return t
    return jnp.repeat(t, g, axis=2)


def _head_mask(cfg, dtype=jnp.float32):
    """1.0 for real heads, 0.0 for mesh-padding heads (inert slots)."""
    if cfg.heads_p == cfg.n_heads:
        return None
    return (jnp.arange(cfg.heads_p) < cfg.n_heads).astype(dtype)


def _out_proj(p, cfg, ctx, x_dtype):
    """ctx (B, S, Hp, Dh) fp32 -> (B, S, d); contraction over sharded H
    produces partials that GSPMD reduces into the seq-sharded residual.
    Mesh-padding heads are masked out (zero contribution, zero grads)."""
    mask = _head_mask(cfg, ctx.dtype)
    if mask is not None:
        ctx = ctx * mask[None, None, :, None]
    out = jnp.einsum("bshk,hkd->bsd", ctx.astype(x_dtype), p["wo"])
    return constrain(out, ("batch", "act_seq", None))


# ---------------------------------------------------------------------------
# full-materialization path (tests / small configs)
# ---------------------------------------------------------------------------

def attend_full(p, cfg, x, positions, window: int = 0):
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    kh = _repeat_kv(k, cfg).astype(jnp.float32)
    vh = _repeat_kv(v, cfg).astype(jnp.float32)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    scores = jnp.einsum("bqhk,bshk->bhqs", q.astype(jnp.float32) * scale, kh)
    qpos = positions[..., :, None]
    kpos = positions[..., None, :]
    mask = kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[:, None] if mask.ndim == 3 else mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqs,bshk->bqhk", probs, vh)
    return _out_proj(p, cfg, ctx, x.dtype), (k, v)


# ---------------------------------------------------------------------------
# chunked online-softmax path (memory-bounded prefill)
# ---------------------------------------------------------------------------

def attend_chunked(p, cfg, x, positions, window: int = 0):
    B, S, _ = x.shape
    C = min(cfg.attn_chunk, S)
    assert S % C == 0, f"seq {S} not divisible by attn chunk {C}"
    H, Dh = cfg.heads_p, cfg.head_dim
    N = S // C
    q, k, v = _project_qkv(p, cfg, x, positions)
    scale = 1.0 / math.sqrt(Dh)
    pos2d = positions if positions.ndim == 2 else jnp.broadcast_to(
        positions[None], (B, S))
    q_blocks = jnp.moveaxis(q.reshape(B, N, C, H, Dh), 1, 0)        # N B C H Dh
    k_blocks = jnp.moveaxis(k.reshape(B, N, C, cfg.kv_heads_p, Dh), 1, 0)
    v_blocks = jnp.moveaxis(v.reshape(B, N, C, cfg.kv_heads_p, Dh), 1, 0)
    pos_blocks = jnp.moveaxis(pos2d.reshape(B, N, C), 1, 0)         # N B C
    # chunk axis stays UNsharded (it is scanned); heads stay on the model
    # axis — otherwise GSPMD reshards every lax.map slice
    q_blocks = constrain(q_blocks, (None, "batch", None, "act_heads", None))
    k_blocks = constrain(k_blocks, (None, "batch", None, "act_kv", None))
    v_blocks = constrain(v_blocks, (None, "batch", None, "act_kv", None))
    pos_blocks = constrain(pos_blocks, (None, "batch", None))

    def per_q(args):
        q_blk, qp = args                                            # (B,C,H,Dh), (B,C)
        qf = q_blk.astype(jnp.float32) * scale

        def body(carry, kv_blk):
            m, l, acc = carry
            k_blk, v_blk, kp = kv_blk
            kh = _repeat_kv(k_blk, cfg).astype(jnp.float32)         # (B,C,H,Dh)
            vh = _repeat_kv(v_blk, cfg).astype(jnp.float32)
            s = jnp.einsum("bqhk,bchk->bhqc", qf, kh)               # (B,H,C,C)
            mask = kp[:, None, :] <= qp[:, :, None]                 # (B,C,C)
            if window:
                mask &= kp[:, None, :] > qp[:, :, None] - window
            s = jnp.where(mask[:, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + pexp.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqc,bchk->bhqk", pexp, vh)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, C), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, C), jnp.float32)
        a0 = jnp.zeros((B, H, C, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      (k_blocks, v_blocks, pos_blocks))
        out_blk = acc / jnp.maximum(l, 1e-30)[..., None]            # B H C Dh
        # cast INSIDE the map: the stacked output (and its backward
        # cotangents through moveaxis/reshape and the TP collectives they
        # feed) stays bf16 instead of f32 — §Perf iteration 1
        return jnp.moveaxis(out_blk, 1, 2).astype(x.dtype)          # B C H Dh

    outs = jax.lax.map(per_q, (q_blocks, pos_blocks))               # N B C H Dh
    ctx = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, Dh)
    return _out_proj(p, cfg, ctx, x.dtype), (k, v)


def attend(p, cfg, x, positions, window: int = 0):
    """Dispatch: chunked when the sequence is large, full otherwise."""
    S = x.shape[1]
    if S > cfg.attn_chunk and S % min(cfg.attn_chunk, S) == 0:
        return attend_chunked(p, cfg, x, positions, window)
    return attend_full(p, cfg, x, positions, window)


# ---------------------------------------------------------------------------
# decode path (single new token against a cache)
# ---------------------------------------------------------------------------

def init_cache(cfg, batch, max_seq, window: int = 0, dtype=jnp.bfloat16):
    S = min(window, max_seq) if window else max_seq
    kv, dh = cfg.kv_heads_p, cfg.head_dim
    return {
        "k": jnp.zeros((batch, S, kv, dh), dtype),
        "v": jnp.zeros((batch, S, kv, dh), dtype),
    }


def cache_specs(window: int = 0):
    # ring buffers (local attention) are small -> replicate their seq; full
    # caches shard the sequence axis over the model axis (split-KV decode)
    seq_axis = None if window else "kv_seq"
    return {"k": ("batch", seq_axis, "kv_heads", None),
            "v": ("batch", seq_axis, "kv_heads", None)}


def decode_step(p, cfg, x, cache, pos, window: int = 0):
    """x (B, 1, d); pos scalar int32.  Returns (out, new cache)."""
    B = x.shape[0]
    dh = cfg.head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    S = cache["k"].shape[1]
    slot = (pos % S) if window else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    kh = _repeat_kv(k, cfg).astype(jnp.float32)                     # (B,S,H,Dh)
    vh = _repeat_kv(v, cfg).astype(jnp.float32)
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqhk,bshk->bhqs", q.astype(jnp.float32) * scale, kh)
    idx = jnp.arange(S)
    if window:
        age = (slot - idx) % S                     # 0 = newest
        valid = age <= jnp.minimum(pos, S - 1)
    else:
        valid = idx <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bshk->bqhk", probs, vh)
    mask = _head_mask(cfg, ctx.dtype)
    if mask is not None:
        ctx = ctx * mask[None, None, :, None]
    out = jnp.einsum("bqhk,hkd->bqd", ctx.astype(x.dtype), p["wo"])
    return out, {"k": k, "v": v}


def prefill_into_cache(p, cfg, x, positions, cache, window: int = 0):
    """Run chunked/full attention AND write K/V into the decode cache."""
    out, (k, v) = attend(p, cfg, x, positions, window)
    S_new = k.shape[1]
    S_cache = cache["k"].shape[1]
    if window and S_new >= S_cache:
        start = S_new - S_cache
        k_keep = jax.lax.dynamic_slice_in_dim(k, start, S_cache, axis=1)
        v_keep = jax.lax.dynamic_slice_in_dim(v, start, S_cache, axis=1)
        # ring alignment: slot of absolute position p is p % S_cache
        roll = (S_new % S_cache)
        k_keep = jnp.roll(k_keep, roll, axis=1)
        v_keep = jnp.roll(v_keep, roll, axis=1)
        cache = {"k": k_keep.astype(cache["k"].dtype),
                 "v": v_keep.astype(cache["v"].dtype)}
    else:
        cache = {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
        }
    return out, cache
