"""Language-model API: init / loss / prefill / decode across all 10 archs.

Modality frontends ([audio]/[vlm] archs) are stubs per the assignment: the
first ``cfg.n_prefix`` sequence positions take precomputed frame/patch
embeddings (supplied by ``input_specs``) instead of token embeddings; the
loss masks those positions.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import transformer as tf
from repro.models.layers import (embed_tokens, embedding_init, embedding_specs,
                                 logits_head, norm_init, norm_specs,
                                 sinusoidal_pos_emb)

__all__ = ["init_params", "param_specs", "forward", "loss_fn", "prefill",
           "decode_step", "cache_init", "cache_specs"]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init_params(key, cfg):
    dtype = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    return {
        "embedding": embedding_init(k1, cfg, dtype),
        "stack": tf.stack_init(k2, cfg, dtype),
        "final_norm": norm_init(cfg.d_model, cfg.norm_type, dtype),
    }


def param_specs(cfg):
    return {
        "embedding": embedding_specs(cfg),
        "stack": tf.stack_specs(cfg),
        "final_norm": norm_specs(cfg.norm_type),
    }


def _embed_inputs(params, cfg, tokens, embeds, positions):
    x = embed_tokens(params["embedding"], tokens).astype(_dtype(cfg))
    if cfg.frontend is not None and embeds is not None:
        x = jax.lax.dynamic_update_slice(
            x, embeds.astype(x.dtype), (0, 0, 0))
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_pos_emb(positions, cfg.d_model).astype(x.dtype)
    return constrain(x, ("batch", "act_seq", None))


def forward(params, cfg, tokens, embeds=None):
    """tokens (B, S) -> logits (B, S, V) float32."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = _embed_inputs(params, cfg, tokens, embeds, positions)
    x, _ = tf.apply_stack(params["stack"], cfg, x, positions)
    x = tf.apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    return logits_head(params["embedding"], cfg, x)


def _xent(logits, labels, mask):
    """logits (B,S,V) fp32, labels (B,S) int32, mask (B,S) -> mean nll."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, cfg, batch):
    """batch: {"tokens": (B,S) int32, optional "embeds": (B,n_prefix,d)}.
    Next-token prediction; frontend-prefix positions are masked out."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = _embed_inputs(params, cfg, tokens, batch.get("embeds"), positions)
    x, _ = tf.apply_stack(params["stack"], cfg, x, positions)
    x = tf.apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    labels = tokens[:, 1:]
    mask = (positions[:, 1:] >= cfg.n_prefix).astype(jnp.float32)
    h = x[:, :-1]
    if cfg.loss_chunk and (S - 1) % cfg.loss_chunk == 0 and S - 1 > cfg.loss_chunk:
        # chunk the vocab projection over the sequence: peak memory is one
        # (B, chunk, V) logits block instead of (B, S, V)
        C = cfg.loss_chunk
        N = (S - 1) // C
        hc = jnp.moveaxis(h.reshape(B, N, C, -1), 1, 0)
        lc = jnp.moveaxis(labels.reshape(B, N, C), 1, 0)
        mc = jnp.moveaxis(mask.reshape(B, N, C), 1, 0)

        def chunk_loss(carry, inp):
            hb, lb, mb = inp
            logits = logits_head(params["embedding"], cfg, hb)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
            nll, msum = carry
            return (nll + ((logz - gold) * mb).sum(), msum + mb.sum()), None

        (nll, msum), _ = jax.lax.scan(chunk_loss, (0.0, 0.0), (hc, lc, mc))
        return nll / jnp.maximum(msum, 1.0)
    logits = logits_head(params["embedding"], cfg, h)
    return _xent(logits, labels, mask)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def cache_init(cfg, batch, cache_len, dtype=None):
    return tf.stack_cache_init(cfg, batch, cache_len, dtype or _dtype(cfg))


def cache_specs(cfg):
    return tf.stack_cache_specs(cfg)


def prefill(params, cfg, tokens, cache_len=None, embeds=None):
    """Process a prompt, returning (last-position logits, filled caches)."""
    B, S = tokens.shape
    cache_len = cache_len or S
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = _embed_inputs(params, cfg, tokens, embeds, positions)
    caches = cache_init(cfg, B, cache_len)
    x, caches = tf.apply_stack(params["stack"], cfg, x, positions, caches)
    x = tf.apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    logits = logits_head(params["embedding"], cfg, x[:, -1:])
    return logits[:, 0], caches


def decode_step(params, cfg, token, caches, pos):
    """token (B,) int32; pos scalar int32 (position of this token).
    Returns (logits (B, V) fp32, new caches)."""
    B = token.shape[0]
    x = embed_tokens(params["embedding"], token[:, None]).astype(_dtype(cfg))
    if cfg.pos_emb == "sinusoidal":
        posv = jnp.full((B, 1), pos, jnp.int32)
        x = x + sinusoidal_pos_emb(posv, cfg.d_model).astype(x.dtype)
    x, caches = tf.decode_stack(params["stack"], cfg, x, caches, pos)
    x = tf.apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    logits = logits_head(params["embedding"], cfg, x)
    return logits[:, 0], caches


def greedy_generate(params, cfg, prompt, n_new, cache_len=None):
    """Simple serving loop for examples/tests: prompt (B, S) -> (B, n_new)."""
    B, S = prompt.shape
    cache_len = cache_len or (S + n_new)
    logits, caches = prefill(params, cfg, prompt, cache_len)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def body(carry, i):
        tok, caches = carry
        logits, caches = decode_step(params, cfg, tok, caches, S + i)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, caches), tok

    (_, _), toks = jax.lax.scan(body, (tok, caches), jnp.arange(n_new))
    return jnp.moveaxis(toks, 0, 1)
