"""Shared neural-net layers (functional style: param dicts + pure applies).

Params are nested dicts of jax arrays; every init function has a matching
``*_specs`` function returning the same tree of *logical sharding axes*
(tuples), consumed by ``distributed.sharding``.  A structure-equality test
guards the pair.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(in_axis_size)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(d, norm_type, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if norm_type == "layer":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_specs(norm_type):
    p = {"scale": ("embed",)}
    if norm_type == "layer":
        p["bias"] = ("embed",)
    return p


def apply_norm(p, x, norm_type, eps):
    xf = x.astype(jnp.float32)
    if norm_type == "rms":
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    elif norm_type == "layer":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32) \
            + p["bias"].astype(jnp.float32)
    else:
        raise ValueError(norm_type)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# positional embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    freqs = rope_frequencies(x.shape[-1], theta)                     # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs        # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                              # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_emb(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """positions (...,) -> (..., d_model) fixed sinusoidal embedding."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {"w_gate": dense_init(k1, (d, f), d, dtype),
                "w_up": dense_init(k2, (d, f), d, dtype),
                "w_down": dense_init(k3, (f, d), f, dtype)}
    return {"w_up": dense_init(k1, (d, f), d, dtype),
            "w_down": dense_init(k2, (f, d), f, dtype)}


def mlp_specs(cfg):
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {"w_gate": (None, "ff"), "w_up": (None, "ff"), "w_down": ("ff", None)}
    return {"w_up": (None, "ff"), "w_down": ("ff", None)}


def apply_mlp(p, cfg, x):
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    h = constrain(h, ("batch", None, "act_ff"))
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# token embedding + output head
# ---------------------------------------------------------------------------

def embedding_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    p = {"tokens": embed_init(k1, (cfg.vocab_p, cfg.d_model), dtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k2, (cfg.d_model, cfg.vocab_p), cfg.d_model, dtype)
    return p


def embedding_specs(cfg):
    # tied tables must stay vocab-sharded (the logits matmul dominates);
    # untied INPUT tables shard d_model instead: the forward gather is then
    # local per shard (no 2.5 GB table all-gather — §Perf iteration 5) and
    # the bwd scatter-add produces a d-sharded grad.
    if cfg.tie_embeddings:
        return {"tokens": ("vocab", "embed")}
    return {"tokens": (None, "embed_tbl"), "head": ("embed", "vocab")}


def embed_tokens(p, tokens):
    return jnp.take(p["tokens"], tokens, axis=0)


def logits_head(p, cfg, x):
    if cfg.tie_embeddings:
        logits = x @ p["tokens"].T
    else:
        logits = x @ p["head"]
    if cfg.logits_soft_cap > 0:
        cap = cfg.logits_soft_cap
        logits = cap * jnp.tanh(logits.astype(jnp.float32) / cap)
    logits = logits.astype(jnp.float32)
    if cfg.vocab_p != cfg.vocab_size:
        # mesh-padding vocab rows are masked out of the softmax
        pad_mask = jnp.arange(cfg.vocab_p) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    return constrain(logits, ("batch", None, "act_vocab"))
