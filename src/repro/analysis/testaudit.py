"""Pass 4 — test audit (PR 5's manual audit, automated).

The suite's wall-clock hygiene rules, as machine checks:

* **test-wall** — test modules NOT listed in the manifest's
  ``wall_test_files`` are sim-classified: they must be entirely
  wall-clock-free (no ``time.*`` reads/sleeps, no ``datetime.now``).
  This is the ROADMAP caveat — "wall-clock adaptation tests assert only
  clock-independent facts ... keep it that way" — enforced;
* **test-sleep** — even in wall-classified test modules, a bare
  ``time.sleep`` is a flake seed: every wait must be a *condition with a
  deadline* through ``conftest.wait_until``.  (A sleep that is genuinely
  a workload, not a wait, takes a justified pragma.)
* **test-slow-wait** — inside a ``@pytest.mark.slow`` test body, ANY
  direct wall-clock access is flagged: slow tests reach wall time only
  through ``conftest.wait_until``.

``conftest.py`` itself (the wait primitive) is exempt via the manifest.
"""

from __future__ import annotations

import ast

from repro.analysis._astutil import FileContext, ScopedVisitor, decorator_name
from repro.analysis.purity import WALL_CLOCK_NAMES

__all__ = ["run_test_audit"]


def _is_slow_marker(dec: ast.AST) -> bool:
    name = decorator_name(dec)
    return name.endswith("mark.slow") or name == "slow"


def _module_slow(tree: ast.Module) -> bool:
    """True when a module-level ``pytestmark`` carries the slow marker."""
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) \
                and any(isinstance(t, ast.Name) and t.id == "pytestmark"
                        for t in stmt.targets):
            values = (stmt.value.elts
                      if isinstance(stmt.value, (ast.List, ast.Tuple))
                      else [stmt.value])
            if any(_is_slow_marker(v) for v in values):
                return True
    return False


class _TestAuditVisitor(ScopedVisitor):
    def __init__(self, ctx: FileContext) -> None:
        super().__init__(ctx)
        self._wall_file = ctx.manifest.is_wall_test(ctx.path)
        self._slow_depth = 1 if _module_slow(ctx.tree) else 0
        self._seen: set[tuple[str, int]] = set()

    def enter_scope(self, node) -> None:
        if not isinstance(node, ast.ClassDef) \
                and any(_is_slow_marker(d) for d in node.decorator_list):
            self._slow_depth += 1
            node._simlint_slow = True

    def exit_scope(self, node) -> None:
        if getattr(node, "_simlint_slow", False):
            self._slow_depth -= 1

    def _flag(self, rule: str, node: ast.AST, message: str) -> None:
        key = (rule, node.lineno)
        if key in self._seen:
            return
        self._seen.add(key)
        self.ctx.report(rule, node.lineno, message, self.scope_lines)

    def _check(self, node: ast.AST) -> None:
        dotted = self.imports.resolve(node)
        if dotted not in WALL_CLOCK_NAMES:
            return
        if not self._wall_file:
            self._flag("test-wall", node,
                       f"sim-classified test module uses {dotted} — sim "
                       f"tests assert clock-independent facts only (or "
                       f"move the file to the manifest's wall_test_files)")
        elif self._slow_depth > 0:
            self._flag("test-slow-wait", node,
                       f"slow-marked test reaches wall time via {dotted} — "
                       f"slow tests wait only through conftest.wait_until")
        elif dotted == "time.sleep":
            self._flag("test-sleep", node,
                       "bare time.sleep in a test — wait on a condition "
                       "with a deadline via conftest.wait_until")

    def visit_Attribute(self, node: ast.Attribute) -> None:
        self._check(node)
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        if isinstance(node.ctx, ast.Load):
            self._check(node)


def run_test_audit(ctx: FileContext) -> None:
    _TestAuditVisitor(ctx).visit(ctx.tree)
