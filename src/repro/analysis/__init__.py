"""simlint — determinism & concurrency analysis for the streaming repro.

The repo's invariants (sim-path purity, lock ordering, DES discipline,
test wall-clock hygiene) as machine-checked rules.  Run with
``python -m repro.analysis`` or the ``repro-lint`` console script; the
tier-1 gate is ``tests/test_static_analysis.py``.
"""

from repro.analysis.cli import analyze_file, iter_source_files, run_analysis
from repro.analysis.lockwatch import LockWatch, install_from_env
from repro.analysis.manifest import DEFAULT_MANIFEST, LockSite, Manifest
from repro.analysis.report import RULES, AnalysisReport, Finding

__all__ = [
    "AnalysisReport",
    "DEFAULT_MANIFEST",
    "Finding",
    "LockSite",
    "LockWatch",
    "Manifest",
    "RULES",
    "analyze_file",
    "install_from_env",
    "iter_source_files",
    "run_analysis",
]
