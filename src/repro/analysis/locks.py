"""Pass 2a — static lock-site extraction.

Finds every ``threading.Lock`` / ``RLock`` / ``Condition`` constructor in
the scanned sources and requires it to be registered in the manifest's
``known_locks`` — with a note stating the lock's role and its place in the
acquisition order.  New concurrency therefore cannot land silently: the
builder of (say) the multiprocess engine must extend the manifest, and the
registry doubles as the human-readable lock-order documentation that the
runtime shim (``lockwatch``) verifies is acyclic in practice.
"""

from __future__ import annotations

import ast

from repro.analysis._astutil import FileContext, ScopedVisitor

__all__ = ["run_lock_pass", "extract_lock_sites"]

_LOCK_CTORS = {
    "threading.Lock": "Lock",
    "threading.RLock": "RLock",
    "threading.Condition": "Condition",
}


class _LockVisitor(ScopedVisitor):
    def __init__(self, ctx: FileContext) -> None:
        super().__init__(ctx)
        self.sites: list[tuple[str, str, int]] = []   # (kind, qualname, line)

    def visit_Call(self, node: ast.Call) -> None:
        dotted = self.imports.resolve(node.func)
        kind = _LOCK_CTORS.get(dotted or "")
        if kind is not None:
            self.sites.append((kind, self.qualname, node.lineno))
            if not self.ctx.manifest.lock_registered(self.ctx.path,
                                                     self.qualname):
                self.ctx.report(
                    "lock-site", node.lineno,
                    f"unregistered threading.{kind} constructed in "
                    f"'{self.qualname or '<module>'}' — add a LockSite "
                    f"entry (with an acquisition-order note) to the "
                    f"manifest's known_locks", self.scope_lines)
        self.generic_visit(node)


def run_lock_pass(ctx: FileContext) -> None:
    _LockVisitor(ctx).visit(ctx.tree)


def extract_lock_sites(ctx: FileContext) -> list[tuple[str, str, int]]:
    """(kind, qualname, line) for every lock constructor in the file —
    the informational inventory the CLI's ``--locks`` mode prints."""
    quiet = FileContext(ctx.path, ctx.tree, ctx.manifest, ctx.pragmas)
    v = _LockVisitor(quiet)
    v.visit(ctx.tree)
    return v.sites
