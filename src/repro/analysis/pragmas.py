"""``simlint: allow[rule] — reason`` comment-pragma parsing/validation.

A pragma suppresses named rules for its own line; placed on a ``def`` or
``class`` header line it suppresses them for the whole scope.  The reason
string is mandatory — an allowance without a justification is itself a
finding — and the repo-wide pragma count is budgeted (``max_pragmas`` in
the manifest) so suppressions stay an audited exception, not an exit.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.analysis.report import RULES, Finding

__all__ = ["Pragma", "scan_pragmas"]

# hash sign, then "simlint: allow[rule-a,rule-b] — reason" ("--"/":" ok too)
_PRAGMA_RE = re.compile(
    r"#\s*simlint:\s*allow\[([^\]]*)\]\s*(?:(?:—|--|:)\s*)?(.*)$")
_MARKER_RE = re.compile(r"#\s*simlint\b")


@dataclass
class Pragma:
    line: int
    rules: tuple[str, ...]
    reason: str
    used: bool = field(default=False, compare=False)

    def covers(self, rule: str) -> bool:
        return rule in self.rules


def scan_pragmas(path: str, source: str) -> tuple[dict[int, Pragma],
                                                  list[Finding]]:
    """Extract pragmas per line; malformed ones become findings.

    Returns ``({lineno: Pragma}, findings)``.  Anything that *looks* like a
    simlint marker but does not parse — or names an unknown rule, or lacks
    a reason — is reported under the ``pragma`` rule rather than silently
    ignored: a typo'd suppression must never masquerade as a clean file.
    """
    pragmas: dict[int, Pragma] = {}
    findings: list[Finding] = []
    for lineno, text in enumerate(source.splitlines(), start=1):
        if not _MARKER_RE.search(text):
            continue
        m = _PRAGMA_RE.search(text)
        if m is None:
            findings.append(Finding(
                path, lineno, "pragma",
                "malformed simlint pragma; expected a comment of the form "
                "'simlint: allow[rule] — reason'"))
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        reason = m.group(2).strip()
        bad = [r for r in rules if r not in RULES]
        if not rules or bad:
            findings.append(Finding(
                path, lineno, "pragma",
                f"pragma names unknown rule(s) {bad or '[]'}; known: "
                f"{', '.join(sorted(RULES))}"))
            continue
        if not reason:
            findings.append(Finding(
                path, lineno, "pragma",
                "pragma reason is empty — every allowance must carry a "
                "justification string"))
            continue
        pragmas[lineno] = Pragma(lineno, rules, reason)
    return pragmas, findings
