"""simlint driver: walk the repo, run every pass, render the verdict.

``python -m repro.analysis`` (or the ``repro-lint`` console script) scans
``src/`` and ``tests/`` under the repo root and exits non-zero on any
finding.  ``tests/test_static_analysis.py`` runs the same
:func:`run_analysis` in-process as the tier-1 gate.
"""

from __future__ import annotations

import argparse
import ast
import os

from repro.analysis._astutil import FileContext
from repro.analysis.des_rules import run_des_pass
from repro.analysis.locks import extract_lock_sites, run_lock_pass
from repro.analysis.manifest import DEFAULT_MANIFEST, Manifest
from repro.analysis.pragmas import scan_pragmas
from repro.analysis.purity import run_purity_pass
from repro.analysis.report import AnalysisReport, Finding
from repro.analysis.testaudit import run_test_audit

__all__ = ["run_analysis", "analyze_file", "iter_source_files", "main"]

_SCAN_DIRS = ("src", "tests")


def _relpath(path: str, root: str) -> str:
    rel = os.path.relpath(path, root)
    return rel.replace(os.sep, "/")


def iter_source_files(root: str,
                      manifest: Manifest = DEFAULT_MANIFEST) -> list[str]:
    """Absolute paths of every ``.py`` file under root's scan dirs,
    manifest exclusions applied, sorted for stable output."""
    out: list[str] = []
    for sub in _SCAN_DIRS:
        base = os.path.join(root, sub)
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                full = os.path.join(dirpath, fn)
                if manifest.is_excluded(_relpath(full, root)):
                    continue
                out.append(full)
    return out


def analyze_file(path: str, rel: str, manifest: Manifest,
                 source: str | None = None) -> FileContext:
    """Run every applicable pass over one file; returns its FileContext
    (findings, pragmas) — test files get the test audit, everything else
    gets purity + DES + lock passes."""
    if source is None:
        with open(path, encoding="utf-8") as f:
            source = f.read()
    pragmas, pragma_findings = scan_pragmas(rel, source)
    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError as exc:
        ctx = FileContext(rel, ast.Module(body=[], type_ignores=[]),
                          manifest, pragmas)
        ctx.findings.extend(pragma_findings)
        ctx.findings.append(Finding(rel, exc.lineno or 1, "parse",
                                    f"syntax error: {exc.msg}"))
        return ctx
    ctx = FileContext(rel, tree, manifest, pragmas)
    ctx.findings.extend(pragma_findings)
    if manifest.is_test_file(rel):
        run_test_audit(ctx)
    elif not manifest.is_test_exempt(rel):
        # conftest/_hypothesis_compat are exempt from EVERY pass, not
        # just the test audit: they are harness plumbing
        run_purity_pass(ctx)
        run_des_pass(ctx)
        run_lock_pass(ctx)
    return ctx


def run_analysis(root: str,
                 manifest: Manifest = DEFAULT_MANIFEST) -> AnalysisReport:
    report = AnalysisReport()
    pragma_sites: list[tuple[str, int]] = []
    for path in iter_source_files(root, manifest):
        rel = _relpath(path, root)
        ctx = analyze_file(path, rel, manifest)
        report.findings.extend(ctx.findings)
        report.files_scanned += 1
        pragma_sites.extend((rel, p.line) for p in ctx.pragmas.values())
    report.pragma_count = len(pragma_sites)
    if report.pragma_count > manifest.max_pragmas:
        listing = ", ".join(f"{p}:{ln}" for p, ln in sorted(pragma_sites))
        report.findings.append(Finding(
            "<repo>", 0, "pragma",
            f"pragma budget exceeded: {report.pragma_count} > "
            f"{manifest.max_pragmas} ({listing}) — fix violations instead "
            f"of suppressing them, or raise max_pragmas deliberately"))
    return report


def _print_lock_inventory(root: str, manifest: Manifest) -> None:
    print("lock constructor sites (static):")
    for path in iter_source_files(root, manifest):
        rel = _relpath(path, root)
        ctx = analyze_file(path, rel, manifest)
        for kind, qualname, line in extract_lock_sites(ctx):
            reg = "registered" if manifest.lock_registered(rel, qualname) \
                else "UNREGISTERED"
            print(f"  {rel}:{line}: {kind} in "
                  f"'{qualname or '<module>'}' [{reg}]")
    print()
    print("manifest known_locks (the documented acquisition order):")
    for site in manifest.known_locks:
        print(f"  {site.kind:9s} {site.path}::{site.qualname or '<module>'}"
              f" — {site.note}")


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="repro-lint",
        description="simlint: determinism & concurrency rules for the "
                    "streaming-USL repro, machine-checked")
    parser.add_argument("--root", default=None,
                        help="repo root (default: auto-detect from this "
                             "package's location)")
    parser.add_argument("--locks", action="store_true",
                        help="print the static lock inventory and the "
                             "manifest's documented order, then exit")
    args = parser.parse_args(argv)
    root = args.root
    if root is None:
        # src/repro/analysis/cli.py -> repo root holds src/
        here = os.path.dirname(os.path.abspath(__file__))
        root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    if args.locks:
        _print_lock_inventory(root, DEFAULT_MANIFEST)
        return 0
    report = run_analysis(root)
    print(report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
