"""Pass 1 — sim-path purity.

Code the manifest classifies ``sim`` must be deterministic given a seed:

* **wall-clock** — no ``time.time``/``perf_counter``/``monotonic``/
  ``sleep``/``datetime.now`` (reads *or* references: storing
  ``time.perf_counter`` as a default clock leaks the wall clock just as
  surely as calling it);
* **global-random** — no module-level ``random.*`` and no legacy global
  ``np.random.*`` (``np.random.seed``/``rand``/...); randomness flows
  through seeded ``np.random.default_rng`` / ``Generator`` instances
  (``Simulator.rng`` is the canonical stream);
* **salted-hash** — no builtin ``hash()``: string hashing is salted per
  process (PYTHONHASHSEED), which made key→partition routing
  nondeterministic across the experiment pool's workers before PR 1
  replaced it with ``broker.stable_hash`` (crc32).

The classification is scope-granular: ``streaming/engine.py`` is sim by
default while its ``ThreadedStreamingEngine``/``_WallTicker`` classes are
wall-classified in the manifest and skipped here.
"""

from __future__ import annotations

import ast

from repro.analysis._astutil import FileContext, ScopedVisitor

__all__ = ["run_purity_pass", "WALL_CLOCK_NAMES"]

WALL_CLOCK_NAMES = frozenset({
    "time.time", "time.time_ns", "time.perf_counter", "time.perf_counter_ns",
    "time.monotonic", "time.monotonic_ns", "time.process_time",
    "time.process_time_ns", "time.sleep",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
})

# np.random members that are seeded-generator constructors, not global state
_NP_RANDOM_ALLOWED = frozenset({
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "PCG64DXSM", "Philox", "MT19937", "SFC64",
})
_RANDOM_ALLOWED = frozenset({"Random"})     # explicit seeded instance


class _PurityVisitor(ScopedVisitor):
    def __init__(self, ctx: FileContext) -> None:
        super().__init__(ctx)
        self._cls_stack = [ctx.manifest.classify(ctx.path, "")]
        self._seen: set[tuple[str, int]] = set()

    def enter_scope(self, node) -> None:
        self._cls_stack.append(
            self.ctx.manifest.classify(self.ctx.path, self.qualname))

    def exit_scope(self, node) -> None:
        self._cls_stack.pop()

    @property
    def _sim(self) -> bool:
        return self._cls_stack[-1] == "sim"

    def _flag(self, rule: str, node: ast.AST, message: str) -> None:
        key = (rule, node.lineno)
        if key in self._seen:
            return
        self._seen.add(key)
        self.ctx.report(rule, node.lineno, message, self.scope_lines)

    def _check_dotted(self, node: ast.AST) -> None:
        dotted = self.imports.resolve(node)
        if dotted is None:
            return
        if dotted in WALL_CLOCK_NAMES:
            self._flag("wall-clock", node,
                       f"sim-path scope '{self.qualname or '<module>'}' "
                       f"references {dotted} — sim code runs on the "
                       f"virtual clock only")
            return
        if dotted.startswith("random."):
            member = dotted.split(".", 1)[1].split(".")[0]
            if member not in _RANDOM_ALLOWED:
                self._flag("global-random", node,
                           f"sim-path use of global {dotted} — draw from a "
                           f"seeded np.random.default_rng stream instead")
        elif dotted.startswith("numpy.random."):
            member = dotted.split(".")[2]
            if member not in _NP_RANDOM_ALLOWED:
                self._flag("global-random", node,
                           f"sim-path use of legacy global {dotted} — use "
                           f"a seeded np.random.default_rng stream")

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if self._sim:
            self._check_dotted(node)
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        # catches from-imports: ``from time import sleep; sleep(...)``
        if self._sim and isinstance(node.ctx, ast.Load):
            self._check_dotted(node)

    def visit_Call(self, node: ast.Call) -> None:
        if self._sim and isinstance(node.func, ast.Name) \
                and node.func.id == "hash":
            self._flag("salted-hash", node,
                       "builtin hash() is PYTHONHASHSEED-salted per "
                       "process — use broker.stable_hash (crc32) for "
                       "any routing/bucketing decision")
        self.generic_visit(node)


def run_purity_pass(ctx: FileContext) -> None:
    _PurityVisitor(ctx).visit(ctx.tree)
