"""Pass 3 — DES discipline.

* **negative-delay** — ``schedule``/``schedule_fast``/``call_later`` must
  never receive a (statically evident) negative delay: the DES core raises
  at runtime, but a negative constant in source is a bug that deserves to
  fail before any simulation runs.  Only constant/unary-minus-constant
  first arguments are decidable statically; runtime values stay guarded by
  ``Simulator.schedule``'s check.
* **slots** — per-event record classes in the manifest's hot modules
  (heap entries, broker messages, metric columns...) must declare
  ``__slots__``: the DES mints one per event, and a ``__dict__`` per
  record measurably moves the reference-cell benchmarks.  Satisfied by a
  literal ``__slots__``, ``@dataclass(slots=True)``, or a ``NamedTuple``
  base (tuple subclasses carry no ``__dict__`` for their fields).

Event handlers reading the wall clock are covered by the purity pass —
every sim-path scope is wall-clock-free, handlers included.
"""

from __future__ import annotations

import ast
import re

from repro.analysis._astutil import FileContext, ScopedVisitor, decorator_name

__all__ = ["run_des_pass"]

_SCHEDULE_METHODS = frozenset({"schedule", "schedule_fast", "call_later"})


def _static_negative(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, float)):
        return node.value < 0
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub) \
            and isinstance(node.operand, ast.Constant) \
            and isinstance(node.operand.value, (int, float)):
        return node.operand.value > 0
    return False


def _declares_slots(node: ast.ClassDef) -> bool:
    for stmt in node.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, ast.AnnAssign):
            targets = [stmt.target]
        for t in targets:
            if isinstance(t, ast.Name) and t.id == "__slots__":
                return True
    return False


def _dataclass_slots(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        if decorator_name(dec).split(".")[-1] != "dataclass":
            continue
        if isinstance(dec, ast.Call):
            for kw in dec.keywords:
                if kw.arg == "slots" and isinstance(kw.value, ast.Constant) \
                        and kw.value.value is True:
                    return True
    return False


def _namedtuple_base(node: ast.ClassDef) -> bool:
    return any("NamedTuple" in ast.dump(base) for base in node.bases)


class _DesVisitor(ScopedVisitor):
    def __init__(self, ctx: FileContext) -> None:
        super().__init__(ctx)
        self._record_re = re.compile(ctx.manifest.record_class_re)
        self._hot = ctx.manifest.is_hot(ctx.path)

    def visit_Call(self, node: ast.Call) -> None:
        name = None
        if isinstance(node.func, ast.Attribute):
            name = node.func.attr
        elif isinstance(node.func, ast.Name):
            name = node.func.id
        if name in _SCHEDULE_METHODS and node.args \
                and _static_negative(node.args[0]):
            self.ctx.report(
                "negative-delay", node.lineno,
                f"{name}() called with a negative delay — DES events may "
                f"only be scheduled at or after the current virtual time",
                self.scope_lines)
        self.generic_visit(node)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        if self._hot and self._record_re.search(node.name) \
                and not (_declares_slots(node) or _dataclass_slots(node)
                         or _namedtuple_base(node)):
            self.ctx.report(
                "slots", node.lineno,
                f"hot-path record class '{node.name}' must declare "
                f"__slots__ (directly, dataclass(slots=True), or as a "
                f"NamedTuple) — one __dict__ per event is measurable at "
                f"DES event rates", self.scope_lines)
        self._enter(node)


def run_des_pass(ctx: FileContext) -> None:
    _DesVisitor(ctx).visit(ctx.tree)
