"""The determinism & concurrency manifest: the repo's contract as data.

This file IS the contract the analyzer enforces.  Every module (and, where
one file hosts both worlds, every class/function) is classified:

* ``sim`` — code on the simulation path: the DES core, the broker and sim
  engine, the autoscale tick, USL fitting.  Sim-path code must be
  deterministic given a seed: no wall clock, no unseeded global random
  state, no salted builtin ``hash()`` routing.  The paper's USL claims are
  measured on this substrate, so nondeterminism here silently corrupts the
  science.
* ``wall`` — code that legitimately lives on the wall clock: the threaded
  engine, the real (local/jaxmesh) backends, the wall-clock producers, the
  launch tooling.  The purity rules do not apply.
* ``neutral`` — everything else (models, kernels, configs...): unchecked.

Classification is first-match-wins over ``overrides`` (path glob +
qualname glob), then ``sim_modules`` / ``wall_modules`` path globs, then
``neutral``.  Globs are ``fnmatch`` patterns against repo-relative posix
paths and dotted qualnames ("" is module level).

**Extending the manifest** (e.g. for the future multiprocess engine): add
the new engine's sim-twin modules to ``sim_modules``, its wall/process
classes to ``overrides`` (or ``wall_modules``), and register every new
``threading``/``multiprocessing`` lock in ``known_locks`` with a note
stating its place in the acquisition order.  The tier-1 gate
(``tests/test_static_analysis.py``) fails until the manifest and the code
agree — which is the point.

Worked example — the what-if engine (``core/whatif.py``): the tournament
sits squarely on the sim path (its summaries feed the paper's adaptation
claims), so the module went into ``sim_modules``.  It takes no locks —
expansion/dedupe/reduction are pure, and execution delegates to
``streaminsight.run_cells``, whose module-level pool-creation ``Lock``
was already registered — so ``known_locks`` gained no entry; a wrapper
that only *calls* locked machinery is not a new lock site.  Had it added
one (say a results-accumulator lock fed from pool callbacks), the entry's
note would state it is leaf: acquired after, never while holding, the
pool lock.

Second worked example — widening the fast replay (``sim/batched.py``):
teaching the replay fault-plan splicing, HPC coupling chains and straggler
speculation tripled the module's surface but changed nothing here.  The
new code is pure event-loop machinery over ``sim.des`` (no wall-clock, no
RNG outside the seeded ``Simulator`` streams, no locks), so the existing
``*/repro/sim/*.py`` glob already covers it and neither ``known_locks``
nor a pragma was needed.  Growth that stays inside an existing glob with
zero new findings is the manifest working as designed — the gate only
moves when the *concurrency story* changes, not when code volume does.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from fnmatch import fnmatch

__all__ = ["LockSite", "Manifest", "DEFAULT_MANIFEST"]


def _match_path(path: str, pattern: str) -> bool:
    """fnmatch that treats ``*/x/y.py`` as suffix-anchored: it matches both
    ``repo/x/y.py`` and the repo-relative ``x/y.py`` (where the leading
    ``*`` would otherwise require a component to consume)."""
    return fnmatch(path, pattern) or fnmatch("/" + path, pattern)


@dataclass(frozen=True)
class LockSite:
    """One registered lock constructor site.

    ``note`` documents the lock's role and its place in the acquisition
    order — the runtime shim (``lockwatch``) verifies the order is acyclic,
    this registry is where a human reads what the order *is*.
    """

    path: str        # path glob, e.g. "*/repro/streaming/broker.py"
    qualname: str    # qualname glob of the constructing scope
    kind: str        # "Lock" | "RLock" | "Condition"
    note: str

    def matches(self, path: str, qualname: str) -> bool:
        return _match_path(path, self.path) \
            and fnmatch(qualname, self.qualname)


@dataclass(frozen=True)
class Manifest:
    # -- sim-path purity ----------------------------------------------------
    sim_modules: tuple[str, ...] = ()
    wall_modules: tuple[str, ...] = ()
    # (path glob, qualname glob, classification) — checked before the
    # module lists, first match wins; this is the class/function-level
    # escape for files hosting both worlds (streaming/engine.py).
    overrides: tuple[tuple[str, str, str], ...] = ()
    # -- DES discipline -----------------------------------------------------
    hot_modules: tuple[str, ...] = ()
    # class-name regex: classes matching this in a hot module are per-event
    # records and must declare __slots__ (directly, dataclass(slots=True),
    # or by being a NamedTuple)
    record_class_re: str = r"(Message|Event|Record|State|Scheduled|Column)$"
    # -- concurrency --------------------------------------------------------
    known_locks: tuple[LockSite, ...] = ()
    # -- test audit ---------------------------------------------------------
    test_globs: tuple[str, ...] = ("*/tests/*.py",)
    # test files that may touch the wall clock (threaded-engine suites);
    # every other test file is sim-classified: wall-clock-free by contract
    wall_test_files: tuple[str, ...] = ()
    # files the test audit never applies to (the wait primitive itself)
    test_exempt: tuple[str, ...] = ()
    # -- scanning -----------------------------------------------------------
    exclude: tuple[str, ...] = ()
    max_pragmas: int = 10

    def classify(self, path: str, qualname: str) -> str:
        """'sim' | 'wall' | 'neutral' for a scope at ``path::qualname``."""
        for pg, qg, cls in self.overrides:
            if _match_path(path, pg) and fnmatch(qualname, qg):
                return cls
        for pg in self.sim_modules:
            if _match_path(path, pg):
                return "sim"
        for pg in self.wall_modules:
            if _match_path(path, pg):
                return "wall"
        return "neutral"

    def is_hot(self, path: str) -> bool:
        return any(_match_path(path, pg) for pg in self.hot_modules)

    def is_test_exempt(self, path: str) -> bool:
        return any(_match_path(path, pg) for pg in self.test_exempt)

    def is_test_file(self, path: str) -> bool:
        if self.is_test_exempt(path):
            return False
        return any(_match_path(path, pg) for pg in self.test_globs)

    def is_wall_test(self, path: str) -> bool:
        return any(_match_path(path, pg) for pg in self.wall_test_files)

    def is_excluded(self, path: str) -> bool:
        return any(_match_path(path, pg) for pg in self.exclude)

    def lock_registered(self, path: str, qualname: str) -> bool:
        return any(site.matches(path, qualname) for site in self.known_locks)


DEFAULT_MANIFEST = Manifest(
    sim_modules=(
        "*/repro/sim/*.py",
        "*/repro/streaming/*.py",         # broker/producer/engine (sim side)
        "*/repro/core/usl.py",
        "*/repro/core/autoscale.py",
        "*/repro/core/metrics.py",
        "*/repro/core/miniapp.py",
        "*/repro/core/streaminsight.py",
        # the what-if tournament: pure expand/dedupe/reduce around
        # streaminsight.run_cells — it creates no locks of its own (the
        # module-level pool Lock below covers its execution) and its
        # reducers (sign test, Pareto, win matrices) are seed-deterministic
        "*/repro/core/whatif.py",
        "*/repro/pilot/api.py",
        "*/repro/pilot/backends/hpcsim.py",
        "*/repro/pilot/backends/serverless.py",
        # the federation composes sim backends on one shared Simulator and
        # is lock-free: health/breaker/placement decisions are pure
        # functions of the virtual clock and CU completions
        "*/repro/pilot/backends/federated.py",
    ),
    wall_modules=(
        "*/repro/pilot/backends/local.py",
        "*/repro/pilot/backends/jaxmesh.py",
        "*/repro/launch/*.py",
    ),
    overrides=(
        # streaming/engine.py hosts both engines: the threaded driver and
        # its ticker live on the wall clock by design
        ("*/repro/streaming/engine.py", "ThreadedStreamingEngine*", "wall"),
        ("*/repro/streaming/engine.py", "_WallTicker*", "wall"),
        # Timer is the wall-clock duration context manager
        ("*/repro/core/metrics.py", "Timer*", "wall"),
        # miniapp's wall-clock adaptation path (threaded producer + runner)
        ("*/repro/core/miniapp.py", "_WallClockProducer*", "wall"),
        ("*/repro/core/miniapp.py", "_run_adaptation_threaded*", "wall"),
    ),
    hot_modules=(
        "*/repro/sim/des.py",
        "*/repro/streaming/broker.py",
        "*/repro/streaming/engine.py",
        "*/repro/streaming/producer.py",
        "*/repro/core/metrics.py",
    ),
    known_locks=(
        LockSite("*/repro/streaming/broker.py", "Broker.__init__", "RLock",
                 "broker state (topics/commits/counters); leaf on the "
                 "append path — subscribers run OUTSIDE it"),
        LockSite("*/repro/streaming/engine.py", "_EngineCore.__init__",
                 "Lock", "shared accounting counters; leaf — never held "
                 "across a broker or pilot call"),
        LockSite("*/repro/streaming/engine.py", "_WallTicker.__init__",
                 "Condition", "ticker heap; callbacks run OUTSIDE it"),
        LockSite("*/repro/streaming/engine.py",
                 "ThreadedStreamingEngine.__init__", "Lock",
                 "admin (repartition/start/ticker) serialization; may be "
                 "held while creating wakeup Events, never across broker "
                 "or compute calls"),
        LockSite("*/repro/pilot/backends/local.py", "LocalBackend.__init__",
                 "Condition", "capacity accounting; leaf"),
        LockSite("*/repro/pilot/backends/jaxmesh.py",
                 "JaxMeshBackend.__init__", "Condition",
                 "device accounting; leaf"),
        LockSite("*/repro/core/autoscale.py", "ControlLoop.__init__",
                 "Lock", "control tick vs stop(); outermost on the tick "
                 "path — may be held across metrics/broker/backend calls"),
        LockSite("*/repro/core/metrics.py", "MetricRegistry.__init__",
                 "Lock", "series/summaries (record() is lock-free); leaf"),
        LockSite("*/repro/core/streaminsight.py", "", "Lock",
                 "module-level process-pool creation; leaf"),
    ),
    wall_test_files=(
        # the cross-engine conformance suite drives the threaded engine on
        # the wall clock; test_adaptation deliberately stays SIM-classified
        # — ROADMAP: wall-clock adaptation tests assert only
        # clock-independent facts via conftest.wait_until
        "*/tests/test_engine_conformance.py",
        "*/tests/test_static_analysis.py",   # times subprocess runs of itself
    ),
    test_exempt=(
        "*/tests/conftest.py",              # implements wait_until
        "*/tests/_hypothesis_compat.py",    # vendored shim
    ),
    exclude=(
        "*simlint_fixtures*",               # known-bad corpus, tested apart
    ),
    max_pragmas=10,
)
