"""Finding/report types shared by every simlint pass.

A ``Finding`` is one rule violation anchored to a source line; an
``AnalysisReport`` is the outcome of one analyzer run over a file set.
Findings are plain, orderable data so the CLI, the tier-1 test gate and
the fixtures-corpus tests all consume the same objects.
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["Finding", "AnalysisReport", "RULES"]

# Every rule the analyzer can emit, with the one-line contract it enforces.
# ``pragmas.py`` validates ``simlint: allow[...]`` rule ids against this
# table, so a typo'd pragma is itself a finding instead of a silent no-op.
RULES: dict[str, str] = {
    "wall-clock": "sim-path code must not read or wait on the wall clock "
                  "(time.time/perf_counter/sleep/datetime.now): all timing "
                  "flows through the virtual clock",
    "global-random": "sim-path code must not touch unseeded global random "
                     "state (random.*, legacy np.random.*): draw through a "
                     "seeded Generator (np.random.default_rng)",
    "salted-hash": "sim-path code must not route on builtin hash(): string "
                   "hashing is PYTHONHASHSEED-salted per process — use "
                   "broker.stable_hash (crc32)",
    "negative-delay": "DES discipline: schedule/schedule_fast/call_later "
                      "must never be given a negative delay",
    "slots": "hot-path record classes (per-event/per-message objects in "
             "the hot modules) must declare __slots__",
    "lock-site": "every threading.Lock/RLock/Condition constructor must be "
                 "registered in the manifest's KNOWN_LOCKS with an ordering "
                 "note — the lock-order shim keys its graph on these sites",
    "test-sleep": "tests must not call time.sleep directly: wall waits go "
                  "through conftest.wait_until (condition with a deadline)",
    "test-slow-wait": "slow-marked tests may only reach wall time through "
                      "conftest.wait_until",
    "test-wall": "sim-classified test modules must stay wall-clock-free "
                 "(assert clock-independent facts only)",
    "pragma": "simlint pragmas must name a known rule and carry a "
              "non-empty justification, within the repo-wide budget",
    "parse": "source file failed to parse",
}


@dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at ``path:line``."""

    path: str          # repo-relative posix path
    line: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class AnalysisReport:
    findings: list[Finding] = field(default_factory=list)
    files_scanned: int = 0
    pragma_count: int = 0

    @property
    def ok(self) -> bool:
        return not self.findings

    def render(self) -> str:
        lines = [f.render() for f in sorted(self.findings)]
        lines.append(
            f"simlint: {len(self.findings)} finding(s), "
            f"{self.pragma_count} pragma(s) across "
            f"{self.files_scanned} file(s)")
        return "\n".join(lines)
