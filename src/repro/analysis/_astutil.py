"""Shared AST machinery: import-alias resolution and scoped visiting.

Every static pass works on the same primitives:

* ``ImportMap`` — resolves ``Name``/``Attribute`` nodes to dotted module
  paths through the file's import aliases (``import time as t`` →
  ``t.sleep`` resolves to ``"time.sleep"``), so rules match *semantics*,
  not spelling.
* ``ScopedVisitor`` — an ``ast.NodeVisitor`` that maintains the dotted
  qualname of the enclosing class/function stack plus the header line of
  each enclosing scope (where a scope-level pragma may sit).
* ``FileContext`` — per-file state: source, pragmas, manifest, and the
  ``report()`` sink that applies pragma suppression.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field

from repro.analysis.manifest import Manifest
from repro.analysis.pragmas import Pragma
from repro.analysis.report import Finding

__all__ = ["ImportMap", "ScopedVisitor", "FileContext", "decorator_name"]


class ImportMap:
    """File-scoped import alias table (collected over the whole tree —
    function-local imports count; shadowing is rare enough to ignore)."""

    def __init__(self, tree: ast.AST) -> None:
        self.modules: dict[str, str] = {}   # local name -> module path
        self.members: dict[str, str] = {}   # local name -> "module.attr"
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.asname:
                        self.modules[alias.asname] = alias.name
                    else:
                        root = alias.name.split(".")[0]
                        self.modules[root] = root
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for alias in node.names:
                    self.members[alias.asname or alias.name] = \
                        f"{node.module}.{alias.name}"

    def resolve(self, node: ast.AST) -> str | None:
        """Dotted path for a Name/Attribute chain, or None."""
        if isinstance(node, ast.Name):
            return self.members.get(node.id) or self.modules.get(node.id)
        if isinstance(node, ast.Attribute):
            base = self.resolve(node.value)
            if base is not None:
                return f"{base}.{node.attr}"
        return None


@dataclass
class FileContext:
    path: str                      # repo-relative posix path
    tree: ast.AST
    manifest: Manifest
    pragmas: dict[int, Pragma]
    findings: list[Finding] = field(default_factory=list)

    def report(self, rule: str, line: int, message: str,
               scope_lines: tuple[int, ...] = ()) -> None:
        """Record a finding unless a pragma on the offending line — or on
        an enclosing def/class header — covers the rule."""
        for ln in (line, *scope_lines):
            p = self.pragmas.get(ln)
            if p is not None and p.covers(rule):
                p.used = True
                return
        self.findings.append(Finding(self.path, line, rule, message))


class ScopedVisitor(ast.NodeVisitor):
    """NodeVisitor tracking the enclosing qualname and scope header lines."""

    def __init__(self, ctx: FileContext) -> None:
        self.ctx = ctx
        self.imports = ImportMap(ctx.tree)
        self._names: list[str] = []
        self._scope_lines: list[int] = []

    @property
    def qualname(self) -> str:
        return ".".join(self._names)

    @property
    def scope_lines(self) -> tuple[int, ...]:
        return tuple(self._scope_lines)

    def _enter(self, node) -> None:
        self._names.append(node.name)
        self._scope_lines.append(node.lineno)
        self.enter_scope(node)
        self.generic_visit(node)
        self.exit_scope(node)
        self._names.pop()
        self._scope_lines.pop()

    # subclass hooks
    def enter_scope(self, node) -> None:  # noqa: B027 — optional hook
        pass

    def exit_scope(self, node) -> None:  # noqa: B027 — optional hook
        pass

    visit_FunctionDef = _enter
    visit_AsyncFunctionDef = _enter
    visit_ClassDef = _enter


def decorator_name(dec: ast.AST) -> str:
    """Dotted spelling of a decorator expression ('pytest.mark.slow')."""
    if isinstance(dec, ast.Call):
        return decorator_name(dec.func)
    if isinstance(dec, ast.Attribute):
        return f"{decorator_name(dec.value)}.{dec.attr}"
    if isinstance(dec, ast.Name):
        return dec.id
    return ""
