"""Pass 2b — runtime lock-order instrumentation (opt-in shim).

Static extraction (``locks.py``) knows where locks are *born*; only a run
shows how they *nest*.  ``LockWatch.install()`` wraps the
``threading.Lock``/``RLock``/``Condition`` constructors so every lock
created afterwards is a tracked proxy.  While installed it records:

* the **acquisition graph** — a directed edge ``A → B`` whenever a thread
  acquires lock B while already holding lock A, keyed by the lock's
  *creation site* (file:line:scope), so all instances born at one site
  collapse into one node.  A cycle (the classic ABBA) is a deadlock the
  scheduler merely hasn't lost yet — the conformance-under-shim test
  fails on any;
* **waits-while-holding** — a ``Condition.wait`` (which ``Event.wait``
  reduces to) entered while the thread holds *other* tracked locks.
  Cross-component holds (e.g. waiting on an engine condition while
  holding the broker lock) stall every producer behind a consumer's
  sleep and are reported as ``cross_component_waits``.

The shim is deliberately constructor-time only: locks created before
``install()`` (module-level singletons, interpreter internals) stay
untracked — the target is the lock population a test session creates.

Everything here is wall-path tooling: the shim exists to *verify* the sim
contract, it never runs on the sim path itself.
"""

from __future__ import annotations

import _thread
import json
import sys
import threading

__all__ = ["LockWatch", "install_from_env", "ENV_OUT"]

ENV_OUT = "SIMLINT_LOCKWATCH_OUT"

_COMPONENTS = (
    ("broker.py", "broker"),
    ("engine.py", "engine"),
    ("autoscale.py", "autoscale"),
    ("metrics.py", "metrics"),
    ("streaminsight.py", "streaminsight"),
    ("miniapp.py", "miniapp"),
    ("local.py", "backend.local"),
    ("jaxmesh.py", "backend.jaxmesh"),
)


def _component(site: str) -> str:
    path = site.split(":", 1)[0]
    for suffix, comp in _COMPONENTS:
        if path.endswith(suffix):
            return comp
    if "repro/" in path.replace("\\", "/"):
        return "repro.other"
    return "external"


def _creation_site() -> str:
    """file:line:function of the frame that called the lock constructor,
    skipping shim and threading internals."""
    f = sys._getframe(2)
    while f is not None:
        fn = f.f_code.co_filename
        if not (fn.endswith("lockwatch.py") or fn.endswith("threading.py")):
            short = fn
            for marker in ("/src/", "/tests/"):
                i = fn.rfind(marker)
                if i != -1:
                    short = fn[i + 1:]
                    break
            return f"{short}:{f.f_lineno}:{f.f_code.co_name}"
        f = f.f_back
    return "<unknown>"


class _TrackedLock:
    """Proxy around a raw lock, feeding the watch's per-thread held stack.

    Exposes the RLock protocol (``_is_owned``/``_release_save``/
    ``_acquire_restore``) when the inner lock does, so a tracked lock can
    serve as a ``Condition``'s lock transparently.
    """

    def __init__(self, watch: "LockWatch", inner, site: str) -> None:
        self._watch = watch
        self._inner = inner
        self.site = site

    def acquire(self, blocking: bool = True, timeout: float = -1):
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._watch._note_acquired(self)
        return ok

    __enter__ = acquire

    def release(self) -> None:
        self._inner.release()
        self._watch._note_released(self)

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._inner.locked()

    # -- RLock protocol for Condition ---------------------------------------
    # Condition picks the RLock protocol whenever the lock exposes these
    # attributes; since the proxy always does, each must fall back to the
    # plain-lock behaviour (Condition's own defaults) when the inner lock
    # is a primitive Lock.
    def _is_owned(self):
        inner = self._inner
        if hasattr(inner, "_is_owned"):
            return inner._is_owned()
        if inner.acquire(False):
            inner.release()
            return False
        return True

    def _release_save(self):
        inner = self._inner
        if hasattr(inner, "_release_save"):
            state = inner._release_save()
        else:
            inner.release()
            state = None
        self._watch._note_released(self, all_holds=True)
        return state

    def _acquire_restore(self, state) -> None:
        inner = self._inner
        if hasattr(inner, "_acquire_restore"):
            inner._acquire_restore(state)
        else:
            inner.acquire()
        self._watch._note_acquired(self)

    def __getattr__(self, name):
        # pass through anything else the stdlib pokes at (_at_fork_reinit,
        # acquire_lock aliases, ...)
        return getattr(object.__getattribute__(self, "_inner"), name)

    def __repr__(self) -> str:
        return f"<TrackedLock {self.site}>"


class LockWatch:
    """Install/uninstall the shim; accumulate the acquisition graph."""

    def __init__(self) -> None:
        # raw allocate_lock: the graph lock itself must never be tracked
        # (it is only ever taken *after* a tracked acquire succeeds, so it
        # can introduce no ordering of its own)
        self._graph_lock = _thread.allocate_lock()
        self._tls = threading.local()
        self.edges: dict[str, set[str]] = {}
        self.sites: dict[str, str] = {}            # site -> kind
        self.waits: list[dict] = []                # wait-while-holding events
        self.acquisitions = 0
        self._installed = False
        self._saved: dict[str, object] = {}

    # -- per-thread held stack ----------------------------------------------
    def _held(self) -> list:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    def _note_acquired(self, lock: _TrackedLock) -> None:
        held = self._held()
        if any(h is lock for h in held):     # reentrant re-acquire
            held.append(lock)
            return
        if held:
            with self._graph_lock:
                self.acquisitions += 1
                for h in {id(h): h for h in held}.values():
                    # same-site pairs (two instances born at one line) are
                    # skipped: a site-level self-edge would always read as
                    # a cycle, but the real ordering there is an
                    # instance-level question this graph can't decide
                    if h is not lock and h.site != lock.site:
                        self.edges.setdefault(h.site, set()).add(lock.site)
        else:
            with self._graph_lock:
                self.acquisitions += 1
        held.append(lock)

    def _note_released(self, lock: _TrackedLock, all_holds: bool = False)\
            -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i] is lock:
                del held[i]
                if not all_holds:
                    return

    def _note_wait(self, cond_lock, timeout) -> None:
        held = self._held()
        others = sorted({h.site for h in held if h is not cond_lock})
        if not others:
            return
        cond_site = getattr(cond_lock, "site", "<untracked>")
        with self._graph_lock:
            self.waits.append({
                "cond": cond_site,
                "held": others,
                "cross_component": [
                    s for s in others
                    if _component(s) != _component(cond_site)],
            })

    # -- install / uninstall -------------------------------------------------
    def install(self) -> "LockWatch":
        if self._installed:
            return self
        watch = self
        orig_lock, orig_rlock = threading.Lock, threading.RLock
        orig_cond = threading.Condition

        def make_lock():
            site = _creation_site()
            with watch._graph_lock:
                watch.sites.setdefault(site, "Lock")
            return _TrackedLock(watch, orig_lock(), site)

        def make_rlock():
            site = _creation_site()
            with watch._graph_lock:
                watch.sites.setdefault(site, "RLock")
            return _TrackedLock(watch, orig_rlock(), site)

        class TrackedCondition(orig_cond):
            def wait(self, timeout=None):
                watch._note_wait(self._lock, timeout)
                return super().wait(timeout)

        self._saved = {"Lock": orig_lock, "RLock": orig_rlock,
                       "Condition": orig_cond}
        threading.Lock = make_lock
        threading.RLock = make_rlock
        threading.Condition = TrackedCondition
        self._installed = True
        return self

    def uninstall(self) -> None:
        if not self._installed:
            return
        threading.Lock = self._saved["Lock"]
        threading.RLock = self._saved["RLock"]
        threading.Condition = self._saved["Condition"]
        self._installed = False

    # -- analysis -------------------------------------------------------------
    def cycles(self) -> list[list[str]]:
        """Cycles in the site-level acquisition graph (DFS, each reported
        once from its smallest node)."""
        found: list[list[str]] = []
        seen_cycles: set[tuple[str, ...]] = set()
        edges = {a: sorted(bs) for a, bs in self.edges.items()}

        def dfs(node: str, stack: list[str], on_stack: set[str]) -> None:
            for nxt in edges.get(node, ()):
                if nxt in on_stack:
                    cyc = stack[stack.index(nxt):] + [nxt]
                    lo = min(range(len(cyc) - 1), key=lambda i: cyc[i])
                    key = tuple(cyc[lo:-1] + cyc[:lo])
                    if key not in seen_cycles:
                        seen_cycles.add(key)
                        found.append(cyc)
                elif nxt not in visited:
                    visited.add(nxt)
                    stack.append(nxt)
                    on_stack.add(nxt)
                    dfs(nxt, stack, on_stack)
                    on_stack.discard(nxt)
                    stack.pop()

        visited: set[str] = set()
        for start in sorted(edges):
            if start not in visited:
                visited.add(start)
                dfs(start, [start], {start})
        return found

    def cross_component_waits(self) -> list[dict]:
        return [w for w in self.waits if w["cross_component"]]

    def report(self) -> dict:
        return {
            "sites": dict(sorted(self.sites.items())),
            "edges": {a: sorted(bs)
                      for a, bs in sorted(self.edges.items())},
            "acquisitions": self.acquisitions,
            "cycles": self.cycles(),
            "waits_while_holding": self.waits,
            "cross_component_waits": self.cross_component_waits(),
        }

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.report(), f, indent=1, sort_keys=True)


def install_from_env() -> LockWatch | None:
    """Install the shim when ``SIMLINT_LOCKWATCH_OUT`` names an output
    path (the conformance-under-shim subprocess run); the caller is
    responsible for dumping at session end."""
    import os

    if not os.environ.get(ENV_OUT):
        return None
    return LockWatch().install()
