"""Data pipeline: deterministic synthetic token streams + file-backed corpora.

The synthetic stream is a seeded Markov-ish token process with learnable
structure (repetition + local n-gram biases) so a small model's loss
demonstrably falls during the example training runs — pure-noise tokens
would plateau at log(V) immediately and prove nothing.

Determinism contract: ``batch_at(step)`` is a pure function of
(seed, step, shard), so checkpoint-restart reproduces the exact data order
without persisting iterator state, and each data shard reads a disjoint
slice (multi-host ready).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["SyntheticLM", "FileCorpus"]


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_prefix: int = 0
    d_model: int = 0          # for frontend-embed stubs
    shard: int = 0
    n_shards: int = 1

    n_templates: int = 16

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 1_000_003 + step) * 97 + self.shard)

    def _bank(self) -> np.ndarray:
        """Fixed per-seed template bank — the stable structure to learn."""
        period = max(4, min(16, self.seq_len // 4))
        return np.random.default_rng(self.seed).integers(
            0, self.vocab_size, size=(self.n_templates, period))

    def batch_at(self, step: int) -> dict:
        rng = self._rng(step)
        b = self.global_batch // self.n_shards
        V = self.vocab_size
        bank = self._bank()
        period = bank.shape[1]
        # each sequence tiles one template from the fixed bank, + 5% noise
        which = rng.integers(0, self.n_templates, size=b)
        reps = -(-self.seq_len // period)
        tokens = np.tile(bank[which], (1, reps))[:, :self.seq_len]
        noise = rng.random((b, self.seq_len)) < 0.05
        tokens = np.where(noise, rng.integers(0, V, size=tokens.shape), tokens)
        out = {"tokens": tokens.astype(np.int32)}
        if self.n_prefix and self.d_model:
            out["embeds"] = (0.02 * rng.standard_normal(
                (b, self.n_prefix, self.d_model))).astype(np.float32)
        return out


class FileCorpus:
    """Token file (np.int32 flat array) -> fixed-length training batches."""

    def __init__(self, path: str, seq_len: int, global_batch: int,
                 shard: int = 0, n_shards: int = 1):
        self.tokens = np.load(path, mmap_mode="r")
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.shard = shard
        self.n_shards = n_shards
        self.n_windows = (len(self.tokens) - 1) // seq_len

    def batch_at(self, step: int) -> dict:
        b = self.global_batch // self.n_shards
        idx0 = (step * self.global_batch + self.shard * b) % self.n_windows
        rows = []
        for i in range(b):
            w = (idx0 + i) % self.n_windows
            rows.append(self.tokens[w * self.seq_len:(w + 1) * self.seq_len])
        return {"tokens": np.stack(rows).astype(np.int32)}
