"""Partitioned message broker — the Kafka/Kinesis abstraction.

A broker is a set of topics; a topic is a fixed number of *partitions*
(Kinesis: shards); a partition is an append-only offset-addressed log.
Consumer groups track per-partition committed offsets; ``lag`` (appended but
uncommitted messages) is the backpressure signal the producer's intelligent
backoff consumes.

The broker is a passive, clock-agnostic data structure so the same code
backs the virtual-clock simulations and the real threaded engine; timing
semantics (ingest bandwidth, append latency) are modeled by the caller
(see ``streaming.producer``), matching the paper's normative
Pilot-Description: "the number of topic shards for Kinesis and Kafka can be
specified using the same attribute".

Consumers register *append subscribers* (``subscribe``): a callback invoked
synchronously — outside the broker lock — after every append to a topic.
This is the push path the streaming engines use to dispatch immediately
instead of polling; it stays clock-agnostic because the broker only hands
over the ``Message`` and the subscriber decides how to schedule itself
(virtual-clock engines schedule on their ``Simulator``, the threaded engine
sets a wakeup ``threading.Event``).

Keyed routing uses a stable hash (``zlib.crc32``), not builtin ``hash`` —
string hashing is salted per process (PYTHONHASHSEED), which would make
key → partition assignment nondeterministic across runs and across the
parallel experiment runner's pool workers, violating the DES determinism
contract in ``sim.des``.
"""

from __future__ import annotations

import threading
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple

__all__ = ["Message", "Broker", "stable_hash"]


def stable_hash(key: Any) -> int:
    """Process-independent hash for keyed partition routing (crc32)."""
    if isinstance(key, bytes):
        data = key
    elif isinstance(key, str):
        data = key.encode("utf-8")
    else:
        data = repr(key).encode("utf-8")
    return zlib.crc32(data)


class Message(NamedTuple):
    """Immutable broker record.  A NamedTuple, not a frozen dataclass: the
    broker mints one per append on the simulation hot path, and frozen
    dataclasses pay an ``object.__setattr__`` per field at construction."""

    topic: str
    partition: int
    offset: int
    ts: float                  # broker append timestamp
    key: Any
    value: Any
    run_id: str | None = None
    msg_id: str | None = None
    size_bytes: int = 0


@dataclass
class _Partition:
    log: list = field(default_factory=list)


class Broker:
    def __init__(self) -> None:
        self._topics: dict[str, list[_Partition]] = {}
        self._commits: dict[tuple[str, str, int], int] = {}  # (group, topic, part) -> next offset
        self._rr: dict[str, int] = {}
        self._subs: dict[str, list[Callable[[Message], None]]] = {}
        self._lock = threading.RLock()
        # incrementally maintained so lag() is O(1): the producer's AIMD
        # controller reads it once per produced message
        self._appended_total: dict[str, int] = {}
        self._committed_total: dict[tuple[str, str], int] = {}
        self._active: dict[str, int] = {}   # open (routable) partition count

    # -- topic admin -------------------------------------------------------
    def create_topic(self, name: str, partitions: int) -> None:
        with self._lock:
            if name in self._topics:
                raise ValueError(f"topic '{name}' exists")
            if partitions < 1:
                raise ValueError("partitions must be >= 1")
            self._topics[name] = [_Partition() for _ in range(partitions)]
            self._rr[name] = 0
            self._appended_total[name] = 0
            self._active[name] = partitions

    def num_partitions(self, topic: str) -> int:
        """Partitions new messages route to (Kinesis: open shards)."""
        return self._active[topic]

    def total_partitions(self, topic: str) -> int:
        """All partitions ever created, including sealed ones — consumers
        must keep draining sealed partitions' backlogs."""
        return len(self._topics[topic])

    def repartition(self, topic: str, partitions: int) -> int:
        """Live resharding (Kinesis shard split/merge semantics).

        Growing appends fresh partitions; shrinking *seals* the tail
        partitions: their logs stay addressable (offsets never move) and
        consumers drain the remaining backlog, but new messages only route
        to the first ``partitions`` actives.  Returns the new active count.
        Data is never dropped — any state-migration *cost* of moving keyed
        state between partitions is modeled by the caller (the control
        loop charges the engine a migration pause; see
        ``SimStreamingEngine.repartition``).
        """
        with self._lock:
            if partitions < 1:
                raise ValueError("partitions must be >= 1")
            parts = self._topics[topic]
            while len(parts) < partitions:
                parts.append(_Partition())
            self._active[topic] = partitions
            return partitions

    def topics(self) -> list[str]:
        return sorted(self._topics)

    # -- produce ------------------------------------------------------------
    def partition_for(self, topic: str, key: Any) -> int:
        with self._lock:
            n = self._active[topic]
            if key is None:
                p = self._rr[topic] % n
                self._rr[topic] += 1
                return p
            return stable_hash(key) % n

    def subscribe(self, topic: str, fn: Callable[[Message], None]) -> None:
        """Register ``fn(msg)`` to be called after every append to ``topic``.

        Callbacks run synchronously in the appender's context, outside the
        broker lock; they must not block.  This is the engines' push path.
        """
        with self._lock:
            if topic not in self._topics:
                raise KeyError(f"unknown topic '{topic}'")
            self._subs.setdefault(topic, []).append(fn)

    def append(self, topic: str, value: Any, *, ts: float, key: Any = None,
               partition: int | None = None, run_id: str | None = None,
               msg_id: str | None = None, size_bytes: int = 0) -> Message:
        """Append one message; returns the minted ``Message``.

        Every message carries a *stable id*: callers that retry/redeliver
        pass the original ``msg_id`` explicitly (a redelivery lands at a
        NEW offset but keeps its id); first-time appends that pass ``None``
        get the deterministic ``topic/partition/offset`` of their first
        landing.  The engines' idempotent accounting keys on this id, so
        at-least-once delivery still yields processed-exactly-once counts.
        """
        with self._lock:
            if partition is None:
                partition = self.partition_for(topic, key)
            part = self._topics[topic][partition]
            if msg_id is None:
                msg_id = f"{topic}/{partition}/{len(part.log)}"
            msg = Message(topic, partition, len(part.log), ts, key, value,
                          run_id, msg_id, size_bytes)
            part.log.append(msg)
            self._appended_total[topic] += 1
            subs = list(self._subs.get(topic, ()))
        for fn in subs:
            fn(msg)
        return msg

    # -- consume --------------------------------------------------------------
    def fetch(self, topic: str, partition: int, offset: int,
              max_records: int = 64) -> list[Message]:
        with self._lock:
            log = self._topics[topic][partition].log
            return log[offset:offset + max_records]

    def end_offset(self, topic: str, partition: int) -> int:
        with self._lock:
            return len(self._topics[topic][partition].log)

    def end_offsets(self, topic: str) -> list[int]:
        """End offsets of every partition (sealed ones included) under one
        lock acquisition — the engines' drain checks and the conformance
        suite's accounting audits read all partitions at once, and a
        per-partition ``end_offset`` loop re-takes the lock N times."""
        with self._lock:
            return [len(p.log) for p in self._topics[topic]]

    def commit(self, group: str, topic: str, partition: int, offset: int) -> None:
        """Commit ``offset`` = next offset to read (Kafka semantics)."""
        with self._lock:
            key = (group, topic, partition)
            old = self._commits.get(key, 0)
            if offset > old:
                self._commits[key] = offset
                gt = (group, topic)
                self._committed_total[gt] = self._committed_total.get(gt, 0) \
                    + (offset - old)

    def committed(self, group: str, topic: str, partition: int) -> int:
        with self._lock:
            return self._commits.get((group, topic, partition), 0)

    # -- backpressure signal ------------------------------------------------
    def lag(self, group: str, topic: str) -> int:
        """Total appended-but-uncommitted messages across partitions.

        O(1) from incrementally maintained totals — the producer's AIMD
        controller calls this once per produced message, so the seed's
        per-partition scan (re-taking the lock per partition) sat directly
        on the simulation hot path."""
        with self._lock:
            return (self._appended_total[topic]
                    - self._committed_total.get((group, topic), 0))

    def appended_total(self, topic: str) -> int:
        """Messages ever appended to ``topic`` — O(1).  The control loop's
        windowed arrival-rate observation is the delta of this counter."""
        with self._lock:
            return self._appended_total[topic]

    def total_messages(self, topic: str) -> int:
        with self._lock:
            return sum(len(p.log) for p in self._topics[topic])
