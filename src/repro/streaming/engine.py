"""Streaming processing engine: binds compute-units to broker partitions.

The paper's usage mode (ii): "the invoking of compute tasks in response to
incoming data events ... a task is then automatically spawned in response to
an event".  Each partition is consumed in order; up to ``batch_max`` pending
messages are micro-batched into one compute-unit (the Lambda/Kinesis batch
semantics); the CU is submitted to the pilot, and its completion commits the
partition offset.

Dispatch is **push-based**: the engines register an append subscriber on the
broker (``Broker.subscribe``) and dispatch the moment a message lands in an
idle partition — "a task is then automatically spawned in response to an
event", literally.  The virtual-clock engine therefore schedules *no* idle
poll events at all; in the seed implementation each idle partition re-polled
every 5 ms of virtual time, and those O(partitions × idle_time /
poll_interval) events dominated ``Simulator`` event counts in every
benchmark sweep.

Fault tolerance (framework-level, beyond the paper's prose but required for
scale):

* **retry / re-dispatch** — a failed CU is re-submitted up to
  ``max_retries`` times with exponential backoff + jitter
  (``retry_backoff_s``, default 0 = immediate); after a worker-loss
  (``ConnectionError``) the retry drops its partition pinning so any
  surviving worker can take it.
* **straggler mitigation** — if a CU exceeds ``straggler_factor ×`` the
  median observed runtime (with a floor), a duplicate CU is dispatched;
  the first completion wins and commits, the loser is ignored (both
  engines — the threaded engine dispatches the speculative copy from its
  consumer thread and the first finisher acks).
* **at-least-once + idempotent accounting** — offsets only advance on
  completion, so every message is processed at least once; duplicate
  completions are idempotent on the commit path, and *redelivered*
  messages (same stable ``msg_id``, new offset — see ``Broker.append``)
  commit their offset but settle as ``dup_delivered``, keeping
  ``processed`` an exactly-once count.
* **fault injection** — ``streaming.faults`` drives crashes/preemptions
  through the backends, ``stall_partition`` freezes a partition's dispatch
  for a duration, and duplicate redelivery exercises the id-dedup path;
  identical semantics on both engines are pinned by the conformance suite.

Two drivers share this logic:
``SimStreamingEngine`` (virtual clock, push wakeups on the broker's append
hook) powers the benchmarks; ``ThreadedStreamingEngine`` (wall clock, append
hook sets per-partition wakeup events) powers the real-compute examples on
the local / jaxmesh backends.

Both drivers implement the ``EngineControlSurface`` protocol
(``core.autoscale``): ``now()`` / ``call_later()`` expose the engine's
clock — the DES virtual clock or ``time.perf_counter`` plus a real-time
ticker thread — and ``repartition()`` adopts the broker's partition count
mid-run with a migration-cost dispatch pause.  That is the whole surface
the ``ControlLoop`` needs, so the identical controller closes the loop on
virtual and wall time.
"""

from __future__ import annotations

import heapq
import itertools
import statistics
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.core.metrics import MetricRegistry
from repro.pilot.api import ComputeUnitDescription, Pilot, State, TaskProfile
from repro.sim.des import Simulator
from repro.streaming.broker import Broker, Message

__all__ = ["Workload", "SimStreamingEngine", "ThreadedStreamingEngine"]


@dataclass
class Workload:
    """What to run per micro-batch of messages.

    ``profile_for(msgs)`` → TaskProfile consumed by the simulated backends.
    ``fn(msgs)`` optional real computation (executed by real backends, and by
    sim backends at completion time for state effects).
    """

    profile_for: Callable[[list[Message]], TaskProfile] | None = None
    fn: Callable[[list[Message]], Any] | None = None
    name: str = "workload"


@dataclass(slots=True)
class _PartitionState:
    next_offset: int = 0
    inflight: bool = False
    retries: int = 0
    stalled_until: float = 0.0     # fault-injected dispatch freeze

    def is_done(self, key: tuple) -> bool:
        """True if the (offset_lo, offset_hi) batch already committed.

        Batches are fetched contiguously from ``next_offset`` and commits
        only ever advance it, so a batch is settled iff the offset has
        moved past its end.  This guard must hold for *any* historical
        batch — a late straggler duplicate completing after several newer
        batches must never roll ``next_offset`` back (the seed's
        last-key-only guard allowed exactly that)."""
        return key[1] <= self.next_offset


class _EngineCore:
    """Shared bookkeeping between sim and threaded drivers."""

    def __init__(self, broker: Broker, topic: str, pilot: Pilot, workload: Workload,
                 metrics: MetricRegistry, run_id: str, group: str = "engine",
                 batch_max: int = 8, max_retries: int = 2,
                 retry_backoff_s: float = 0.0,
                 retry_backoff_cap_s: float = 30.0, rng=None,
                 seed: int = 0) -> None:
        self.broker = broker
        self.topic = topic
        self.pilot = pilot
        self.workload = workload
        self.metrics = metrics
        self.run_id = run_id
        self.group = group
        self.batch_max = batch_max
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.retry_backoff_cap_s = retry_backoff_cap_s
        # seeded Generator for backoff jitter; with no explicit rng the
        # stream derives from the experiment seed (never unseeded, never
        # jitter-free) so faulted reruns stay bit-identical by default
        self._retry_rng = rng if rng is not None \
            else np.random.default_rng([0x5EED, seed])
        self.n_partitions = broker.num_partitions(topic)
        self.parts = [_PartitionState() for _ in range(self.n_partitions)]
        self.completed_runtimes: list[float] = []
        self._rec_complete = metrics.recorder(run_id, "engine", "complete")
        self._rec_dispatch = metrics.recorder(run_id, "engine", "dispatch")
        # aggregate counters are written by every consumer thread of the
        # threaded driver; drain() relies on their exact sum, so updates
        # must not be lost to interleaved read-modify-writes
        self.counter_lock = threading.Lock()
        self.processed = 0
        self.failed_batches = 0
        self.abandoned = 0          # actual messages skipped by poison batches
        self.duplicates = 0          # batch-level duplicate completions
        self.dup_delivered = 0       # redelivered messages (same stable id)
        self.retried = 0
        self.seen_ids: set = set()   # stable msg_ids settled as processed
        self._straggler_cache = (0, float("inf"))  # (runtimes seen, timeout)
        # Empty fetches: none schedule events (push engines just go quiet).
        # Grows with completions that catch up to the producer, so it is a
        # caught-up-consumer signal, not an idle-poll count.
        self.idle_fetches = 0

    def make_cu_desc(self, msgs: list[Message], partition: int | None) -> ComputeUnitDescription:
        profile = self.workload.profile_for(msgs) if self.workload.profile_for else TaskProfile()
        fn = (lambda: self.workload.fn(msgs)) if self.workload.fn else None
        return ComputeUnitDescription(func=fn, profile=profile,
                                      name=f"{self.workload.name}[p{partition}]",
                                      run_id=self.run_id, partition=partition)

    def on_batch_done(self, partition: int, msgs: list[Message], now: float) -> bool:
        """Commit + metrics; returns False if another copy already won.

        Idempotent accounting: a *redelivered* message (same stable
        ``msg_id``, new offset) commits its offset like any other but
        settles as ``dup_delivered``, not ``processed`` — so ``processed``
        stays an exactly-once count despite at-least-once delivery, and a
        ``complete`` metric event is recorded only for the first copy
        (keeping latency pairing 1:1)."""
        ps = self.parts[partition]
        key = (msgs[0].offset, msgs[-1].offset + 1)
        if ps.is_done(key):
            with self.counter_lock:
                self.duplicates += 1
            return False
        ps.next_offset = msgs[-1].offset + 1
        self.broker.commit(self.group, self.topic, partition, ps.next_offset)
        seen = self.seen_ids
        fresh = []
        dups = 0
        with self.counter_lock:
            for m in msgs:
                mid = m.msg_id
                if mid is not None and mid in seen:
                    dups += 1
                else:
                    if mid is not None:
                        seen.add(mid)
                    fresh.append(m)
            self.processed += len(fresh)
            self.dup_delivered += dups
        rec = self._rec_complete
        for m in fresh:
            rec(now, msg_id=m.msg_id, partition=partition)
        return True

    def retry_delay(self, attempt: int) -> float:
        """Exponential backoff + jitter for retry ``attempt`` (1-based):
        ``backoff · 2^(attempt-1) · U[0.5, 1.5)`` capped at
        ``retry_backoff_cap_s``; 0 when backoff is disabled (the default,
        which keeps the pre-fault-era immediate-retry behaviour)."""
        base = self.retry_backoff_s
        if base <= 0.0:
            return 0.0
        delay = base * (2.0 ** (attempt - 1))
        with self.counter_lock:        # one rng, many consumer threads
            delay *= 0.5 + self._retry_rng.random()
        return min(delay, self.retry_backoff_cap_s)

    @property
    def straggler_timeout(self) -> float:
        """4× the median observed runtime (with a floor).

        The median over all completed runtimes is O(n log n); recomputing
        it on *every* dispatch made dispatch cost grow with run length.
        The estimate only needs to track the runtime distribution, so it
        refreshes exactly while the sample is small (< 32) and then once
        every 32 completions."""
        n = len(self.completed_runtimes)
        if n < 3:
            return float("inf")
        cached_n, cached = self._straggler_cache
        if n != cached_n and (n < 32 or n % 32 == 0 or cached_n < 3):
            cached = max(4.0 * statistics.median(self.completed_runtimes), 1e-3)
            self._straggler_cache = (n, cached)
        return cached


class SimStreamingEngine:
    """Virtual-clock engine (push-dispatched, used by all benchmarks).

    ``start`` subscribes to the broker's append hook and scans each
    partition once for pre-existing backlog; after that the engine is woken
    only by appends and by its own batch completions — no poll events.
    ``poll_interval`` is retained for API compatibility but unused.
    """

    def __init__(self, sim: Simulator, broker: Broker, topic: str, pilot: Pilot,
                 workload: Workload, metrics: MetricRegistry, run_id: str,
                 *, group: str = "engine", batch_max: int = 8,
                 poll_interval: float = 0.005, max_retries: int = 2,
                 retry_backoff_s: float = 0.0,
                 straggler_mitigation: bool = True,
                 is_input_complete: Callable[[], bool] | None = None) -> None:
        self.sim = sim
        self.core = _EngineCore(broker, topic, pilot, workload, metrics, run_id,
                                group=group, batch_max=batch_max,
                                max_retries=max_retries,
                                retry_backoff_s=retry_backoff_s, rng=sim.rng)
        self.poll_interval = poll_interval
        self.straggler_mitigation = straggler_mitigation
        self.is_input_complete = is_input_complete or (lambda: False)
        self._appended_seen = 0
        self._inflight_n = 0
        self._paused_until = 0.0       # state-migration dispatch pause

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        core = self.core

        def on_append(msg) -> None:
            self._appended_seen += 1
            self._drain(msg.partition)

        core.broker.subscribe(core.topic, on_append)
        # pre-subscribe backlog counts toward the settled-message fast path
        # (no appends can interleave here: the subscribe and this scan run
        # synchronously before the simulator advances)
        self._appended_seen = sum(core.broker.end_offset(core.topic, p)
                                  for p in range(core.n_partitions))
        for p in range(core.n_partitions):
            self.sim.schedule(0.0, lambda p=p: self._drain(p))

    def is_finished(self) -> bool:
        """O(1) fast path: every partition advances ``next_offset`` by
        exactly the messages it commits (``processed``) or poison-skips
        (``abandoned``), so the topic is drained iff those counters reach
        the number of appends observed.  ``run_until`` evaluates this
        predicate before *every* event — the seed's per-partition
        ``end_offset`` scan (one broker lock acquisition each) dominated
        reference-cell wall time.  The authoritative per-partition check
        still runs, but only once the fast path says we are done (one
        bulk ``end_offsets`` read, a single lock acquisition)."""
        core = self.core
        if not self.is_input_complete():
            return False
        if self._inflight_n or core.processed + core.abandoned \
                + core.dup_delivered < self._appended_seen:
            return False
        ends = core.broker.end_offsets(core.topic)
        if len(core.parts) < len(ends):
            return False     # broker repartition not yet adopted
        return all(ps.next_offset >= end and not ps.inflight
                   for ps, end in zip(core.parts, ends))

    @property
    def finished(self) -> bool:
        return self.is_finished()

    def run_to_completion(self, max_virtual_s: float = 1e7) -> None:
        self.sim.run_until(t=self.sim.now + max_virtual_s, predicate=self.is_finished)
        if not self.is_finished():
            raise TimeoutError("engine did not drain the topic in time")

    # -- control surface (EngineControlSurface) -------------------------------
    def now(self) -> float:
        return self.sim.now

    def call_later(self, delay_s: float, fn: Callable[[], None]) -> None:
        self.sim.schedule_fast(delay_s, fn)

    # -- live repartitioning (EILC: the control loop resizes N mid-run) -------
    def repartition(self, migration_s: float = 0.0) -> None:
        """Adopt the broker's current partition count mid-run.

        Newly created partitions get consumer state and start draining as
        appends land; sealed partitions keep draining their backlog until
        empty.  ``migration_s`` charges the state-migration cost of moving
        keyed state between partitions as a real DES event: dispatch is
        paused for that long (in-flight batches finish; new dispatches
        wait), then every partition is re-drained.
        """
        core = self.core
        total = core.broker.total_partitions(core.topic)
        while len(core.parts) < total:
            core.parts.append(_PartitionState())
        core.n_partitions = total
        if migration_s > 0.0:
            core.metrics.record(core.run_id, "engine", "migrate", self.sim.now,
                                duration=migration_s, partitions=total)
            resume_at = self.sim.now + migration_s
            if resume_at > self._paused_until:
                self._paused_until = resume_at
                self.sim.schedule_fast(migration_s, self._resume)

    def _resume(self) -> None:
        if self.sim.now < self._paused_until:
            return     # superseded by a longer, later migration pause
        for p in range(len(self.core.parts)):
            self._drain(p)

    # -- fault surface ---------------------------------------------------------
    def stall_partition(self, partition: int, duration_s: float) -> None:
        """Freeze dispatch on ``partition`` for ``duration_s`` virtual
        seconds (fault injection: a stuck shard).  In-flight batches
        finish; new fetches wait out the stall, then a scheduled re-drain
        resumes consumption."""
        core = self.core
        if partition >= len(core.parts):
            self.repartition()
        ps = core.parts[partition]
        until = self.sim.now + duration_s
        if until > ps.stalled_until:
            ps.stalled_until = until
            self.sim.schedule_fast(duration_s, lambda: self._drain(partition))

    # -- push-dispatched partition consumer -----------------------------------
    def _drain(self, partition: int) -> None:
        """Dispatch the next pending batch of ``partition``, if idle.

        Invoked synchronously from the broker's append hook and from batch
        completions — both already run inside a simulator event, so no extra
        event is scheduled on the hot path.
        """
        core = self.core
        if self.sim.now < self._paused_until:
            return     # migrating: the resume sweep re-drains every partition
        if partition >= len(core.parts):
            # append raced ahead of the control loop's repartition call
            self.repartition()
        ps = core.parts[partition]
        if self.sim.now < ps.stalled_until:
            return     # stalled: the stall-expiry event re-drains
        if ps.inflight:
            return
        msgs = core.broker.fetch(core.topic, partition, ps.next_offset, core.batch_max)
        if not msgs:
            core.idle_fetches += 1
            return
        ps.inflight = True
        self._inflight_n += 1
        ps.retries = 0
        self._dispatch(partition, msgs, pinned=True)

    def _dispatch(self, partition: int, msgs: list[Message], pinned: bool,
                  speculate: bool = True) -> None:
        core = self.core
        desc = core.make_cu_desc(msgs, partition if pinned else None)
        core._rec_dispatch(self.sim.now, partition=partition, batch=len(msgs))
        cu = core.pilot.submit_compute_unit(desc)
        straggler_ev = None
        if self.straggler_mitigation and speculate:
            timeout = core.straggler_timeout
            if timeout != float("inf"):
                straggler_ev = self.sim.schedule(
                    timeout, lambda: self._straggler_check(partition, msgs, cu))
        cu.add_done_callback(lambda cu: self._on_final(partition, msgs, cu, straggler_ev))

    def _straggler_check(self, partition: int, msgs: list[Message], cu) -> None:
        core = self.core
        ps = core.parts[partition]
        key = (msgs[0].offset, msgs[-1].offset + 1)
        if cu.state.is_final or ps.is_done(key):
            return
        core.metrics.record(core.run_id, "engine", "straggler_dup", self.sim.now,
                            partition=partition)
        # at most ONE backup copy per attempt (speculate=False), matching
        # the threaded engine's _await_first: a speculative copy that arms
        # its own straggler check breeds copy-of-copy chains whenever the
        # platform is convoyed (e.g. the HPC model-lock under a burst) —
        # every copy adds load to the shared bottleneck that made the
        # primary slow, a positive feedback loop that melts the run
        self._dispatch(partition, msgs, pinned=False, speculate=False)

    def _on_final(self, partition: int, msgs: list[Message], cu,
                  straggler_ev=None) -> None:
        core = self.core
        ps = core.parts[partition]
        if straggler_ev is not None:
            self.sim.cancel(straggler_ev)
        if cu.state == State.DONE:
            if core.on_batch_done(partition, msgs, self.sim.now):
                core.completed_runtimes.append(cu.runtime)
                ps.inflight = False
                self._inflight_n -= 1
                self._drain(partition)
            return
        # FAILED / CANCELED
        key = (msgs[0].offset, msgs[-1].offset + 1)
        if ps.is_done(key):
            return  # a duplicate already completed this batch
        if ps.retries < core.max_retries:
            ps.retries += 1
            core.retried += 1
            pinned = not isinstance(cu.exception, ConnectionError)
            delay = core.retry_delay(ps.retries)
            core.metrics.record(core.run_id, "engine", "retry", self.sim.now,
                                partition=partition, attempt=ps.retries,
                                backoff=delay)
            if delay > 0.0:
                # the batch stays in-flight through the backoff window, so
                # is_finished cannot falsely report a drained topic
                self.sim.schedule_fast(
                    delay, lambda: self._dispatch(partition, msgs, pinned=pinned))
            else:
                self._dispatch(partition, msgs, pinned=pinned)
        else:
            core.failed_batches += 1
            core.abandoned += len(msgs)
            core.metrics.record(core.run_id, "engine", "abandon", self.sim.now,
                                partition=partition, messages=len(msgs))
            ps.next_offset = msgs[-1].offset + 1   # skip poison batch, keep draining
            core.broker.commit(core.group, core.topic, partition, ps.next_offset)
            ps.inflight = False
            self._inflight_n -= 1
            self._drain(partition)


class _WallTicker(threading.Thread):
    """Real-time callback scheduler backing the threaded engine's control
    surface: a single daemon thread draining a (due, seq, fn) heap under a
    condition variable.  ``call_later`` is the wall-clock analogue of
    ``Simulator.schedule_fast`` — the control loop re-arms itself through
    it every tick.  A callback exception is stored on ``last_error`` (and
    the ticker keeps running) rather than silently killing the thread."""

    def __init__(self) -> None:
        super().__init__(daemon=True, name="engine-ticker")
        self._cv = threading.Condition()
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self._stopped = False
        self.last_error: BaseException | None = None
        # bounded history of callback errors, oldest dropped first; the
        # control loop drains this into its tick_error_log ring (deque
        # append/popleft are atomic, so no extra lock is needed)
        self.errors: deque = deque(maxlen=16)

    def call_later(self, delay_s: float, fn: Callable[[], None]) -> None:
        with self._cv:
            heapq.heappush(self._heap,
                           (time.perf_counter() + max(delay_s, 0.0),
                            next(self._seq), fn))
            self._cv.notify()

    def stop(self) -> None:
        with self._cv:
            self._stopped = True
            self._cv.notify()

    def run(self) -> None:
        while True:
            with self._cv:
                while not self._stopped and (
                        not self._heap
                        or self._heap[0][0] > time.perf_counter()):
                    wait = (None if not self._heap
                            else max(0.0, self._heap[0][0] - time.perf_counter()))
                    self._cv.wait(wait)
                if self._stopped:
                    return
                _due, _seq, fn = heapq.heappop(self._heap)
            try:
                fn()
            except Exception as exc:  # noqa: BLE001 — keep ticking
                if self.last_error is None:   # keep the root cause
                    self.last_error = exc
                self.errors.append(exc)


class ThreadedStreamingEngine:
    """Wall-clock engine: one consumer thread per partition, real compute.

    Consumers block on a per-partition wakeup event that the broker's append
    hook sets, so an idle partition dispatches as soon as data lands instead
    of sleeping out a poll interval (``poll_interval`` remains the bounded
    fallback wait, a safety net against missed wakeups).

    Implements ``EngineControlSurface``: ``now()`` is ``perf_counter``,
    ``call_later`` schedules on a lazily started real-time ticker thread,
    and ``repartition`` adopts the broker's partition count mid-run —
    growing consumer state, wakeup events and (once started) consumer
    threads, and pausing dispatch for the migration cost, mirroring the
    virtual-clock engine's semantics on the wall clock.
    """

    def __init__(self, broker: Broker, topic: str, pilot: Pilot, workload: Workload,
                 metrics: MetricRegistry, run_id: str, *, group: str = "engine",
                 batch_max: int = 8, poll_interval: float = 0.01,
                 max_retries: int = 2, retry_backoff_s: float = 0.0,
                 straggler_mitigation: bool = True, seed: int = 0) -> None:
        self.core = _EngineCore(broker, topic, pilot, workload, metrics, run_id,
                                group=group, batch_max=batch_max,
                                max_retries=max_retries,
                                retry_backoff_s=retry_backoff_s,
                                rng=np.random.default_rng(seed))
        self.poll_interval = poll_interval
        self.straggler_mitigation = straggler_mitigation
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._wakeups = [threading.Event() for _ in range(self.core.n_partitions)]
        self._ticker: _WallTicker | None = None
        self._paused_until = 0.0       # state-migration dispatch pause
        self._started = False
        # serializes repartition/start against concurrent append callbacks
        self._admin_lock = threading.Lock()

    def start(self) -> None:
        def on_append(msg) -> None:
            if msg.partition >= len(self._wakeups):
                # append raced ahead of the control loop's repartition call
                self.repartition()
            self._wakeups[msg.partition].set()

        self.core.broker.subscribe(self.core.topic, on_append)
        with self._admin_lock:
            self._started = True
            self._spawn_consumers()

    def _spawn_consumers(self) -> None:
        """Start consumer threads for partitions that lack one (caller
        holds ``_admin_lock``)."""
        while len(self._threads) < self.core.n_partitions:
            p = len(self._threads)
            t = threading.Thread(target=self._consume, args=(p, time),
                                 daemon=True)
            t.start()
            self._threads.append(t)

    # -- control surface (EngineControlSurface) -------------------------------
    def now(self) -> float:
        return time.perf_counter()

    def call_later(self, delay_s: float, fn: Callable[[], None]) -> None:
        with self._admin_lock:
            if self._ticker is None:
                self._ticker = _WallTicker()
                self._ticker.start()
            ticker = self._ticker
        ticker.call_later(delay_s, fn)

    @property
    def ticker_error(self) -> BaseException | None:
        """The first exception a ``call_later`` callback raised, if any.

        A failing callback does not kill the ticker thread.  Historically
        it DID silently end anything that re-arms itself from inside its
        own callback (the control loop's tick never reached its
        re-schedule line); ``ControlLoop._tick`` now re-arms in a
        ``finally`` and surfaces this error on its next tick
        (``tick_errors`` / the ``autoscale.tick_error`` metric).  Drivers
        must still check this after the run —
        ``run_adaptation(engine="threaded")`` raises on it — otherwise a
        crashed controller looks like a quiet, successful experiment."""
        return self._ticker.last_error if self._ticker is not None else None

    def drain_ticker_errors(self) -> list:
        """Pop-and-return every callback error seen since the last drain
        (bounded: the ticker keeps at most 16).  The control loop feeds
        these into its ``tick_error_log`` ring so a *flapping* policy is
        diagnosable, not just countable — ``ticker_error`` keeps only the
        root cause."""
        ticker = self._ticker
        if ticker is None:
            return []
        out = []
        while True:
            try:
                out.append(ticker.errors.popleft())
            except IndexError:
                return out

    def repartition(self, migration_s: float = 0.0) -> None:
        """Adopt the broker's current partition count mid-run.

        Newly created partitions get consumer state, a wakeup event and
        (once the engine is started) a consumer thread; sealed partitions
        keep draining their backlog until empty.  ``migration_s`` charges
        the keyed-state migration cost as a real-time dispatch pause —
        in-flight batches finish, new dispatches wait out the pause.
        """
        core = self.core
        with self._admin_lock:
            total = core.broker.total_partitions(core.topic)
            while len(core.parts) < total:
                core.parts.append(_PartitionState())
            while len(self._wakeups) < total:
                self._wakeups.append(threading.Event())
            core.n_partitions = total
            if migration_s > 0.0:
                core.metrics.record(core.run_id, "engine", "migrate",
                                    self.now(), duration=migration_s,
                                    partitions=total)
                self._paused_until = max(self._paused_until,
                                         self.now() + migration_s)
            if self._started:
                self._spawn_consumers()

    # -- fault surface ---------------------------------------------------------
    def stall_partition(self, partition: int, duration_s: float) -> None:
        """Freeze dispatch on ``partition`` for ``duration_s`` wall seconds
        (fault injection: a stuck shard).  The in-flight batch finishes;
        the consumer thread waits out the stall before its next fetch."""
        if partition >= len(self.core.parts):
            self.repartition()
        ps = self.core.parts[partition]
        until = self.now() + duration_s
        if until > ps.stalled_until:
            ps.stalled_until = until     # atomic float store; consumer polls

    def _await_first(self, cu, partition: int, msgs, time_mod):
        """Block until the primary CU or its speculative duplicate reaches a
        final state; returns ``(winner, loser)`` (loser may still be running
        or ``None``).  The speculative copy is dispatched unpinned once the
        primary exceeds ``straggler_timeout`` — first finisher wins, the
        conformance twin of the sim engine's ``_straggler_check`` event."""
        core = self.core
        spec = None
        t0 = time_mod.perf_counter()
        while not self._stop.is_set():
            if cu.state.is_final:
                return cu, spec
            if spec is not None and spec.state.is_final:
                return spec, cu
            if spec is None and self.straggler_mitigation:
                timeout = core.straggler_timeout
                if timeout != float("inf") \
                        and time_mod.perf_counter() - t0 > timeout:
                    core.metrics.record(core.run_id, "engine", "straggler_dup",
                                        time_mod.perf_counter(),
                                        partition=partition)
                    spec = core.pilot.submit_compute_unit(
                        core.make_cu_desc(msgs, None))
            cu.done_event.wait(self.poll_interval)
        return cu, spec     # stopping: the caller checks _stop

    def _consume(self, partition: int, time_mod) -> None:
        core = self.core
        ps = core.parts[partition]
        wakeup = self._wakeups[partition]
        while not self._stop.is_set():
            pause = max(self._paused_until,
                        ps.stalled_until) - time_mod.perf_counter()
            if pause > 0:
                # migrating or fault-stalled: interruptible sleep, re-check
                self._stop.wait(min(pause, self.poll_interval))
                continue
            wakeup.clear()
            msgs = core.broker.fetch(core.topic, partition, ps.next_offset, core.batch_max)
            if not msgs:
                with core.counter_lock:
                    core.idle_fetches += 1
                # an append between the fetch and this wait sets the event,
                # so the wait returns immediately — no lost wakeups
                wakeup.wait(self.poll_interval)
                continue
            attempts = 0
            while True:
                cu = core.pilot.submit_compute_unit(core.make_cu_desc(msgs, partition))
                winner, loser = self._await_first(cu, partition, msgs, time_mod)
                if self._stop.is_set() and not winner.state.is_final:
                    return
                if winner.state == State.DONE:
                    now = time_mod.perf_counter()
                    if core.on_batch_done(partition, msgs, now):
                        core.completed_runtimes.append(winner.runtime)
                    if loser is not None:
                        # first-finisher-wins: the losing copy must settle
                        # on the idempotent duplicate path when it lands
                        # (commit already happened above, so on_batch_done
                        # sees is_done and counts `duplicates` — identical
                        # to the sim engine's late-straggler accounting).
                        # Bind the batch by value: the consumer loop rebinds
                        # ``msgs`` on its next fetch long before the loser
                        # finishes, so a late-bound closure would hand
                        # on_batch_done a different (possibly empty) batch.
                        loser.add_done_callback(
                            lambda lo, _msgs=msgs: core.on_batch_done(
                                partition, _msgs, time_mod.perf_counter())
                            if lo.state == State.DONE else None)
                    break
                # FAILED / CANCELED
                if core.parts[partition].is_done(
                        (msgs[0].offset, msgs[-1].offset + 1)):
                    break   # a speculative duplicate already committed it
                attempts += 1
                with core.counter_lock:
                    core.retried += 1
                if attempts > core.max_retries:
                    ps.next_offset = msgs[-1].offset + 1
                    core.broker.commit(core.group, core.topic, partition, ps.next_offset)
                    # counted after the commit so drain() can't observe
                    # the count before the offset has advanced
                    with core.counter_lock:
                        core.failed_batches += 1
                        core.abandoned += len(msgs)
                    break
                delay = core.retry_delay(attempts)
                if delay > 0.0:
                    self._stop.wait(delay)     # interruptible backoff
                    if self._stop.is_set():
                        return

    def stop(self, timeout: float = 5.0) -> None:
        """Stop consumers and the ticker; ``timeout`` is a *global*
        deadline shared by all joins.  The seed passed ``timeout`` to each
        consumer join in turn, so stopping n stuck partitions took up to
        ``n_partitions × timeout`` — with a shared deadline the worst case
        is ``timeout`` regardless of partition count (consumers are daemon
        threads; any still busy past the deadline die with the process)."""
        self._stop.set()
        with self._admin_lock:
            wakeups = list(self._wakeups)
            threads = list(self._threads)
            ticker = self._ticker
        for ev in wakeups:
            ev.set()
        if ticker is not None:
            ticker.stop()
        deadline = time.perf_counter() + timeout
        for t in threads:
            t.join(timeout=max(0.0, deadline - time.perf_counter()))

    def drain(self, n_expected: int, timeout: float = 60.0) -> None:
        """Block until ``n_expected`` *unique* messages are accounted for
        AND the consumer group's lag is zero.

        Counts *actual* abandoned messages (``core.abandoned``), not the
        ``failed_batches * batch_max`` estimate the seed used: the final
        batch of a partition can be smaller than ``batch_max``, so the
        estimate over-counted and drain could return with messages still
        pending in the topic.

        Under at-least-once redelivery ``processed`` is an exactly-once
        count (idempotent accounting), so drained-but-unacked duplicates
        never double-count toward ``n_expected`` — but their re-appended
        copies still occupy the log, so the counter check alone could
        return before the duplicate offsets commit.  The lag conjunct
        closes that: drain returns only once every appended offset
        (duplicates included) is committed.
        """
        core = self.core
        deadline = time.perf_counter() + timeout
        while time.perf_counter() < deadline:
            if core.processed + core.abandoned >= n_expected \
                    and core.broker.lag(core.group, core.topic) == 0:
                return
            time.sleep(self.poll_interval)
        raise TimeoutError(
            f"drained {core.processed}+{core.abandoned} abandoned"
            f"/{n_expected} messages "
            f"(lag={core.broker.lag(core.group, core.topic)})")
