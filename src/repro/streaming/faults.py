"""Declarative fault injection: failure semantics as a scenario axis.

Real serverless and HPC platforms fail constantly — container churn, spot
preemption, batch-queue evictions, stalled shards, redelivered messages —
and the model-driven controller (``core.autoscale``) is only credible if
its violations/cost edge survives them.  This module makes those failures
a *first-class experiment knob*, like partitions or message size:

* ``FaultPlan`` — a seeded, declarative schedule of fault events: crashes
  and preemptions at explicit times or Poisson rates, partition stalls,
  duplicate redeliveries.  ``events_for(horizon)`` expands rates into a
  concrete, deterministic event list (same seed → same schedule).
* ``FaultInjector`` — binds a plan to a running pipeline through the same
  ``EngineControlSurface`` the control loop uses (``now``/``call_later``),
  so the identical plan drives the virtual clock and the wall clock.
  Crashes and preemptions go through the backend's fault surface
  (``Backend.inject_crash`` / ``Backend.preempt``); stalls through
  ``engine.stall_partition``; duplicates are re-appended to the broker
  with their original stable ``msg_id`` (producer-retry semantics), which
  the engine's idempotent accounting settles as ``dup_delivered``.

The injector exposes ``window_dirty()`` — a latched "did anything fire (or
is a stall in effect) since you last asked" read the ``ControlLoop`` uses
to exclude fault-poisoned windows from the online USL estimator (the
capacity-revoking faults are already excluded by the granted==target
gating, because ``effective_allocation`` dips while they are in force).

Plan spec (JSON-able; every key optional):

    dict(seed=0,                    # rate-expansion stream (defaults to the
                                    # experiment seed)
         horizon_s=120.0,           # rate-expansion horizon
         crash_rate_hz=0.05,        # Poisson worker/container crashes
         duplicate_rate_hz=0.1,     # Poisson duplicate redeliveries
         stall_rate_hz=0.02,        # Poisson partition stalls ...
         stall_s=5.0,               # ... of this duration each
         preempt_times=[45.0, 80.0],  # spot reclamations at these times ...
         preempt_count=4,           # ... revoking this many units each
         events=[dict(t=30.0, kind="crash", count=2), ...])  # explicit

Everything on the sim path is deterministic given the seed: rates expand
through one ``np.random.default_rng(seed)`` stream at plan time, and event
targets are resolved by a deterministic counter at fire time.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

__all__ = ["FaultEvent", "FaultPlan", "FaultInjector", "FAULT_KINDS",
           "expand_plan"]

FAULT_KINDS = ("crash", "stall", "duplicate", "preempt",
               "backend_outage", "grant_starvation")


@dataclass(frozen=True, slots=True)
class FaultEvent:
    """One scheduled fault.

    ``target`` is a partition index for stall/duplicate (``None`` → the
    injector picks round-robin over active partitions) and a federation
    member index for backend_outage/grant_starvation; ``duration_s`` is
    the stall/outage/starvation length; ``count`` the multiplicity for
    crash/preempt.
    """

    t: float
    kind: str
    target: int | None = None
    duration_s: float = 5.0
    count: int = 1

    @classmethod
    def from_spec(cls, spec: dict) -> "FaultEvent":
        kind = spec["kind"]
        if kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {kind!r}; known: {FAULT_KINDS}")
        return cls(t=float(spec["t"]), kind=kind,
                   target=spec.get("target"),
                   duration_s=float(spec.get("duration_s", 5.0)),
                   count=int(spec.get("count", 1)))

    def to_spec(self) -> dict:
        """Inverse of ``from_spec``: a JSON-able dict that round-trips
        losslessly (``FaultEvent.from_spec(e.to_spec()) == e``), so fault
        scenarios serialize into cache keys and fig8 cell descriptions."""
        spec: dict = dict(t=self.t, kind=self.kind,
                          duration_s=self.duration_s, count=self.count)
        if self.target is not None:
            spec["target"] = self.target
        return spec


@dataclass
class FaultPlan:
    """Seeded, declarative fault schedule (see module docstring for the
    JSON spec)."""

    seed: int = 0
    horizon_s: float = 120.0
    crash_rate_hz: float = 0.0
    duplicate_rate_hz: float = 0.0
    stall_rate_hz: float = 0.0
    stall_s: float = 5.0
    preempt_times: tuple = ()
    preempt_count: int = 1
    events: list = field(default_factory=list)     # explicit FaultEvents

    @classmethod
    def from_spec(cls, spec: dict, *, default_seed: int = 0,
                  default_horizon_s: float = 120.0) -> "FaultPlan":
        unknown = set(spec) - {"seed", "horizon_s", "crash_rate_hz",
                               "duplicate_rate_hz", "stall_rate_hz", "stall_s",
                               "preempt_times", "preempt_count", "events"}
        if unknown:
            raise ValueError(f"unknown FaultPlan keys: {sorted(unknown)}")
        return cls(
            seed=int(spec.get("seed", default_seed)),
            horizon_s=float(spec.get("horizon_s", default_horizon_s)),
            crash_rate_hz=float(spec.get("crash_rate_hz", 0.0)),
            duplicate_rate_hz=float(spec.get("duplicate_rate_hz", 0.0)),
            stall_rate_hz=float(spec.get("stall_rate_hz", 0.0)),
            stall_s=float(spec.get("stall_s", 5.0)),
            preempt_times=tuple(float(t) for t in spec.get("preempt_times", ())),
            preempt_count=int(spec.get("preempt_count", 1)),
            events=[FaultEvent.from_spec(e) for e in spec.get("events", ())],
        )

    def to_spec(self) -> dict:
        """Inverse of ``from_spec``: a JSON-able spec dict such that
        ``FaultPlan.from_spec(plan.to_spec()) == plan``."""
        return dict(seed=self.seed, horizon_s=self.horizon_s,
                    crash_rate_hz=self.crash_rate_hz,
                    duplicate_rate_hz=self.duplicate_rate_hz,
                    stall_rate_hz=self.stall_rate_hz, stall_s=self.stall_s,
                    preempt_times=list(self.preempt_times),
                    preempt_count=self.preempt_count,
                    events=[e.to_spec() for e in self.events])

    def _poisson_times(self, rng: np.random.Generator, rate_hz: float,
                       horizon: float) -> list[float]:
        """Deterministic Poisson arrivals on [0, horizon): exponential gaps
        accumulated from one seeded stream."""
        times: list[float] = []
        if rate_hz <= 0.0 or horizon <= 0.0:
            return times
        t = float(rng.exponential(1.0 / rate_hz))
        while t < horizon:
            times.append(t)
            t += float(rng.exponential(1.0 / rate_hz))
        return times

    def events_for(self, horizon_s: float | None = None) -> list[FaultEvent]:
        """Expand the plan into a concrete, time-sorted event list.

        Rates are sampled in a fixed kind order from one seeded stream, so
        the schedule is a pure function of the plan — the determinism the
        fault benchmark cells and the conformance tests rely on.
        """
        horizon = self.horizon_s if horizon_s is None else float(horizon_s)
        rng = np.random.default_rng(self.seed)
        out: list[FaultEvent] = []
        for t in self._poisson_times(rng, self.crash_rate_hz, horizon):
            out.append(FaultEvent(t=t, kind="crash"))
        for t in self._poisson_times(rng, self.duplicate_rate_hz, horizon):
            out.append(FaultEvent(t=t, kind="duplicate"))
        for t in self._poisson_times(rng, self.stall_rate_hz, horizon):
            out.append(FaultEvent(t=t, kind="stall", duration_s=self.stall_s))
        for t in self.preempt_times:
            out.append(FaultEvent(t=float(t), kind="preempt",
                                  count=self.preempt_count))
        out.extend(self.events)
        # (t, kind) sort: ties resolve identically on every run
        return sorted(out, key=lambda e: (e.t, e.kind, e.count))


def expand_plan(spec, *, default_seed: int = 0,
                default_horizon_s: float = 120.0) -> tuple["FaultPlan", list[FaultEvent]]:
    """Pre-expand a fault plan spec into ``(plan, events)``.

    This is the plan-side contract the fast replay (``sim.batched``)
    depends on: the entire fault schedule is known *before* the run
    starts — rates expand through one ``default_rng(plan.seed)`` stream
    at plan time, never at fire time — so a replay can arm the exact
    event list the scalar ``FaultInjector`` would arm, in the same
    order, without constructing an injector at all.

    ``spec`` is a JSON-able plan dict (see module docstring) or an
    already-built ``FaultPlan``; defaults mirror ``miniapp``'s wiring
    (``default_seed`` = experiment seed, ``default_horizon_s`` =
    experiment horizon).  The returned event list is exactly
    ``plan.events_for()`` — time-sorted with deterministic ties.
    """
    if isinstance(spec, FaultPlan):
        plan = spec
    else:
        plan = FaultPlan.from_spec(spec, default_seed=default_seed,
                                   default_horizon_s=default_horizon_s)
    return plan, plan.events_for()


class FaultInjector:
    """Binds a ``FaultPlan`` to a live pipeline and fires its events.

    Clock-agnostic by construction: every event is scheduled through the
    engine's ``call_later`` (DES event on the sim clock, ticker callback on
    the wall clock), and every action goes through clock-agnostic surfaces
    (backend fault hooks, ``engine.stall_partition``, ``broker.append``).
    On the wall-clock path all callbacks run on the single ticker thread —
    the same thread that runs control ticks — so the counters need no lock.
    """

    def __init__(self, plan: FaultPlan, engine, broker, topic: str, pilot, *,
                 metrics=None, run_id: str | None = None) -> None:
        self.plan = plan
        self.engine = engine
        self.broker = broker
        self.topic = topic
        self.pilot = pilot
        self.metrics = metrics
        self.run_id = run_id
        # outcome counters (the experiment report card reads these)
        self.injected = 0
        self.crashes = 0
        self.preemptions = 0
        self.stalls = 0
        self.dup_injected = 0
        self.outages = 0          # backend_outage events that acted
        self.starvations = 0      # grant_starvation events that acted
        self.skipped = 0          # events that found nothing to act on
        self._rr = 0              # deterministic round-robin target pick
        self._fired_since_probe = 0
        self._stall_until = 0.0

    # -- lifecycle -----------------------------------------------------------
    def start(self, horizon_s: float | None = None) -> int:
        """Schedule every plan event relative to ``engine.now()``; returns
        the number of events armed."""
        events = self.plan.events_for(horizon_s)
        for ev in events:
            self.engine.call_later(ev.t, lambda ev=ev: self._fire(ev))
        return len(events)

    # -- control-loop signal --------------------------------------------------
    def window_dirty(self) -> bool:
        """Latched read: True if any fault fired since the last probe, or a
        partition stall is still in effect.  The control loop calls this
        once per tick to mark fault epochs as unstable windows."""
        dirty = self._fired_since_probe > 0 \
            or self.engine.now() < self._stall_until
        self._fired_since_probe = 0
        return dirty

    # -- firing ----------------------------------------------------------------
    def _pick_partition(self, ev: FaultEvent) -> int:
        n = max(1, self.broker.num_partitions(self.topic))
        if ev.target is not None:
            return ev.target % n
        self._rr += 1
        return (self._rr - 1) % n

    def _fire(self, ev: FaultEvent) -> None:
        self.injected += 1
        self._fired_since_probe += 1
        acted = 0
        if ev.kind == "crash":
            acted = self.pilot.backend.inject_crash(self.pilot, ev.count)
            self.crashes += acted
        elif ev.kind == "preempt":
            acted = self.pilot.backend.preempt(self.pilot, ev.count)
            self.preemptions += acted
        elif ev.kind == "stall":
            p = self._pick_partition(ev)
            self.engine.stall_partition(p, ev.duration_s)
            until = self.engine.now() + ev.duration_s
            self._stall_until = max(self._stall_until, until)
            self.stalls += 1
            acted = 1
        elif ev.kind == "duplicate":
            acted = self._inject_duplicate(ev)
        elif ev.kind == "backend_outage":
            # federation-level fault: only backends exposing the hook (the
            # federated backend) can act; everything else skips gracefully
            fn = getattr(self.pilot.backend, "inject_outage", None)
            if fn is not None:
                acted = fn(self.pilot, member=ev.target,
                           duration_s=ev.duration_s)
                self.outages += 1 if acted else 0
        elif ev.kind == "grant_starvation":
            fn = getattr(self.pilot.backend, "inject_grant_starvation", None)
            if fn is not None:
                acted = fn(self.pilot, member=ev.target,
                           duration_s=ev.duration_s)
                self.starvations += 1 if acted else 0
        if not acted:
            self.skipped += 1
        if self.metrics is not None and self.run_id is not None:
            self.metrics.record(self.run_id, "fault", ev.kind,
                                self.engine.now(), count=ev.count, acted=acted)

    def _inject_duplicate(self, ev: FaultEvent) -> int:
        """Re-append the newest message of a partition with its original
        stable ``msg_id`` — the broker-side shape of a producer retry /
        redelivery.  The engine commits the new offset but settles the
        message as ``dup_delivered``, not ``processed``."""
        p = self._pick_partition(ev)
        end = self.broker.end_offset(self.topic, p)
        if end == 0:
            return 0
        orig = self.broker.fetch(self.topic, p, end - 1, 1)[0]
        self.broker.append(self.topic, orig.value, ts=self.engine.now(),
                           key=orig.key, partition=p, run_id=orig.run_id,
                           msg_id=orig.msg_id, size_bytes=orig.size_bytes)
        self.dup_injected += 1
        return 1
