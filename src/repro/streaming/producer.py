"""Synthetic data producer: intelligent backoff OR open-loop rate programs.

Closed-loop mode (paper §IV): "To conduct measurements at the maximum
sustained throughput, the framework utilizes an intelligent backoff strategy
during data production."  We use AIMD (additive-increase /
multiplicative-decrease) on the production rate, driven by consumer-group
lag: while the processing system keeps up (lag < lo watermark) the rate
creeps up; when lag crosses the hi watermark — the back-pressure signal —
the rate is cut.  At convergence the production rate oscillates just under
the system's maximum sustained throughput, exactly the operating point the
paper measures.

Open-loop mode (paper §V, the EILC direction): adaptation experiments need
the *incoming* rate to be externally imposed — the system must adapt to the
workload, not the workload to the system.  ``RateProgram`` is a composable,
deterministic time-varying rate trace r(t): constant, step, ramp, diurnal
sine, and bursty (Poisson-modulated on/off) programs, plus ``+`` / ``*``
combinators.  Programs are constructed from plain JSON-able spec dicts
(``rate_program_from_spec``) so a rate trace can travel inside an experiment
dataclass as a first-class design axis.  ``mean_messages(t0, t1)`` is the
exact integral ∫r dt — the expected message count, which the unit tests
check actual production against.

Ingest modeling: Kinesis shards cap ingest at ~1 MB/s each; Kafka appends
ride the shared filesystem.  Both are expressed as an ``ingest`` policy the
mini-app wires in (per-partition ``SharedResource`` for Kinesis; the HPC
backend's Lustre resource for Kafka), so broker-side contention emerges from
the same mechanisms as processing-side contention.
"""

from __future__ import annotations

import bisect
import math
from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

from repro.core.metrics import MetricRegistry
from repro.sim.des import SharedResource, Simulator
from repro.streaming.broker import Broker

__all__ = ["AIMD", "PartitionIngest", "SyntheticProducer", "RateProgram",
           "ConstantRate", "StepRate", "RampRate", "DiurnalRate", "BurstyRate",
           "rate_program_from_spec"]


# -- time-varying rate programs ----------------------------------------------

class RateProgram:
    """Deterministic rate trace r(t) ≥ 0 on the virtual clock.

    Programs compose: ``a + b`` superimposes rates, ``a * k`` scales one.
    ``mean_messages(t0, t1)`` is ∫r dt — exact for every built-in program,
    midpoint-rule numeric for arbitrary compositions that do not override
    it.
    """

    def rate(self, t: float) -> float:
        raise NotImplementedError

    def mean_messages(self, t0: float, t1: float) -> float:
        """Expected messages in [t0, t1] (∫ r dt); numeric fallback."""
        if t1 <= t0:
            return 0.0
        n = max(64, min(8192, int((t1 - t0) * 8)))
        mids = np.linspace(t0, t1, n, endpoint=False) + (t1 - t0) / (2 * n)
        return float(sum(self.rate(float(t)) for t in mids) * (t1 - t0) / n)

    def __add__(self, other: "RateProgram") -> "RateProgram":
        return _SumRate(self, other)

    def __mul__(self, k: float) -> "RateProgram":
        return _ScaledRate(self, float(k))

    __rmul__ = __mul__


class _SumRate(RateProgram):
    def __init__(self, a: RateProgram, b: RateProgram) -> None:
        self.a, self.b = a, b

    def rate(self, t: float) -> float:
        return self.a.rate(t) + self.b.rate(t)

    def mean_messages(self, t0: float, t1: float) -> float:
        return self.a.mean_messages(t0, t1) + self.b.mean_messages(t0, t1)


class _ScaledRate(RateProgram):
    def __init__(self, inner: RateProgram, k: float) -> None:
        self.inner, self.k = inner, k

    def rate(self, t: float) -> float:
        return self.k * self.inner.rate(t)

    def mean_messages(self, t0: float, t1: float) -> float:
        return self.k * self.inner.mean_messages(t0, t1)


class ConstantRate(RateProgram):
    def __init__(self, rate_hz: float) -> None:
        self.rate_hz = float(rate_hz)

    def rate(self, t: float) -> float:
        return self.rate_hz

    def mean_messages(self, t0: float, t1: float) -> float:
        return self.rate_hz * max(t1 - t0, 0.0)


class StepRate(RateProgram):
    """Piecewise-constant: ``base_hz`` until ``t_step``, then ``high_hz``
    (until optional ``t_end``, after which the rate falls back to base)."""

    def __init__(self, base_hz: float, high_hz: float, t_step: float,
                 t_end: float | None = None) -> None:
        self.base_hz = float(base_hz)
        self.high_hz = float(high_hz)
        self.t_step = float(t_step)
        self.t_end = float(t_end) if t_end is not None else None

    def rate(self, t: float) -> float:
        if t < self.t_step:
            return self.base_hz
        if self.t_end is not None and t >= self.t_end:
            return self.base_hz
        return self.high_hz

    def mean_messages(self, t0: float, t1: float) -> float:
        if t1 <= t0:
            return 0.0
        hi_end = self.t_end if self.t_end is not None else t1
        hi = max(0.0, min(t1, hi_end) - max(t0, self.t_step))
        return self.base_hz * (t1 - t0 - hi) + self.high_hz * hi


class RampRate(RateProgram):
    """Linear ramp from ``start_hz`` at ``t0`` to ``end_hz`` at ``t1``,
    constant outside the ramp window."""

    def __init__(self, start_hz: float, end_hz: float, t0: float, t1: float) -> None:
        if t1 <= t0:
            raise ValueError("ramp needs t1 > t0")
        self.start_hz, self.end_hz = float(start_hz), float(end_hz)
        self.t0, self.t1 = float(t0), float(t1)

    def rate(self, t: float) -> float:
        if t <= self.t0:
            return self.start_hz
        if t >= self.t1:
            return self.end_hz
        frac = (t - self.t0) / (self.t1 - self.t0)
        return self.start_hz + frac * (self.end_hz - self.start_hz)

    def mean_messages(self, t0: float, t1: float) -> float:
        if t1 <= t0:
            return 0.0
        # exact: piecewise (constant, linear, constant); the linear piece's
        # integral is the trapezoid of its endpoint rates
        total = 0.0
        lo = max(t0, self.t0)
        hi = min(t1, self.t1)
        if t0 < self.t0:
            total += self.start_hz * (min(t1, self.t0) - t0)
        if hi > lo:
            total += 0.5 * (self.rate(lo) + self.rate(hi)) * (hi - lo)
        if t1 > self.t1:
            total += self.end_hz * (t1 - max(t0, self.t1))
        return total


class DiurnalRate(RateProgram):
    """Sinusoidal load curve: ``mean_hz * (1 + amplitude*sin(...))`` with
    period ``period_s`` (amplitude is a fraction of the mean, ≤ 1)."""

    def __init__(self, mean_hz: float, amplitude: float, period_s: float,
                 phase: float = 0.0) -> None:
        if not 0.0 <= amplitude <= 1.0:
            raise ValueError("amplitude is a fraction of the mean (0..1)")
        self.mean_hz = float(mean_hz)
        self.amplitude = float(amplitude)
        self.period_s = float(period_s)
        self.phase = float(phase)

    def _angle(self, t: float) -> float:
        return 2.0 * math.pi * t / self.period_s + self.phase

    def rate(self, t: float) -> float:
        return self.mean_hz * (1.0 + self.amplitude * math.sin(self._angle(t)))

    def mean_messages(self, t0: float, t1: float) -> float:
        if t1 <= t0:
            return 0.0
        w = 2.0 * math.pi / self.period_s
        anti = lambda t: self.mean_hz * (t - self.amplitude / w   # noqa: E731
                                         * math.cos(self._angle(t)))
        return anti(t1) - anti(t0)


class BurstyRate(RateProgram):
    """Poisson-modulated bursts: ``base_hz`` background plus ``burst_hz``
    during burst windows.  Burst starts arrive as a Poisson process with
    mean gap ``mean_gap_s`` (exponential inter-arrivals drawn from
    ``seed``); each burst lasts ``burst_len_s``.  Fully deterministic given
    the seed — windows are generated lazily and memoized, so two programs
    built from the same spec agree everywhere."""

    def __init__(self, base_hz: float, burst_hz: float, burst_len_s: float,
                 mean_gap_s: float, seed: int = 0) -> None:
        self.base_hz = float(base_hz)
        self.burst_hz = float(burst_hz)
        self.burst_len_s = float(burst_len_s)
        self.mean_gap_s = float(mean_gap_s)
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)
        self._starts: list[float] = []
        self._next_start = float(self._rng.exponential(self.mean_gap_s))

    def _extend_to(self, t: float) -> None:
        while self._next_start <= t:
            self._starts.append(self._next_start)
            self._next_start += self.burst_len_s + float(
                self._rng.exponential(self.mean_gap_s))

    def _in_burst(self, t: float) -> bool:
        self._extend_to(t)
        i = bisect.bisect_right(self._starts, t)
        return i > 0 and t < self._starts[i - 1] + self.burst_len_s

    def rate(self, t: float) -> float:
        return self.base_hz + (self.burst_hz if self._in_burst(t) else 0.0)

    def mean_messages(self, t0: float, t1: float) -> float:
        if t1 <= t0:
            return 0.0
        self._extend_to(t1)
        burst = sum(max(0.0, min(t1, s + self.burst_len_s) - max(t0, s))
                    for s in self._starts)
        return self.base_hz * (t1 - t0) + self.burst_hz * burst


_RATE_KINDS = {
    "constant": ConstantRate,
    "step": StepRate,
    "ramp": RampRate,
    "diurnal": DiurnalRate,
    "burst": BurstyRate,
}


def rate_program_from_spec(spec) -> RateProgram:
    """Build a ``RateProgram`` from a JSON-able spec.

    ``{"kind": "step", "base_hz": 2, "high_hz": 20, "t_step": 30}`` etc.;
    ``{"kind": "sum", "parts": [spec, ...]}`` and
    ``{"kind": "scale", "factor": k, "part": spec}`` compose.  An existing
    ``RateProgram`` passes through unchanged, so callers accept either."""
    if isinstance(spec, RateProgram):
        return spec
    if not isinstance(spec, dict) or "kind" not in spec:
        raise ValueError(f"rate spec must be a dict with 'kind': {spec!r}")
    kw = {k: v for k, v in spec.items() if k != "kind"}
    kind = spec["kind"]
    if kind == "sum":
        parts = [rate_program_from_spec(p) for p in kw.pop("parts")]
        if kw or not parts:
            raise ValueError(f"bad sum spec: {spec!r}")
        out = parts[0]
        for p in parts[1:]:
            out = out + p
        return out
    if kind == "scale":
        part, factor = kw.pop("part"), float(kw.pop("factor"))
        if kw:
            raise ValueError(f"bad scale spec (unknown keys {sorted(kw)}): {spec!r}")
        return rate_program_from_spec(part) * factor
    if kind not in _RATE_KINDS:
        raise ValueError(f"unknown rate kind {kind!r}; "
                         f"known: {sorted(_RATE_KINDS) + ['sum', 'scale']}")
    return _RATE_KINDS[kind](**kw)


@dataclass
class AIMD:
    """Additive-increase / multiplicative-decrease rate controller."""

    rate_hz: float = 20.0
    min_rate_hz: float = 0.5
    max_rate_hz: float = 5000.0
    increase_hz: float = 2.0
    decrease_factor: float = 0.7
    lo_watermark: int = 4
    hi_watermark: int = 32

    def update(self, lag: int) -> float:
        if lag >= self.hi_watermark:
            self.rate_hz = max(self.rate_hz * self.decrease_factor, self.min_rate_hz)
        elif lag <= self.lo_watermark:
            self.rate_hz = min(self.rate_hz + self.increase_hz, self.max_rate_hz)
        return self.rate_hz


class PartitionIngest:
    """Per-partition ingest bandwidth limit (Kinesis: ~1 MB/s per shard)."""

    def __init__(self, sim: Simulator, partitions: int, bw_per_partition: float = 1e6,
                 request_latency: float = 0.01) -> None:
        self.request_latency = request_latency
        self.resources = [SharedResource(sim, bw_per_partition, name=f"shard{i}")
                          for i in range(partitions)]
        self.sim = sim

    def submit(self, partition: int, size_bytes: int, on_done: Callable[[], None]) -> None:
        res = self.resources[partition % len(self.resources)]
        self.sim.schedule_fast(self.request_latency,
                               lambda: res.submit(float(size_bytes), on_done))


class SharedFsIngest:
    """Kafka-on-HPC ingest: appends ride the shared filesystem resource."""

    def __init__(self, sim: Simulator, fs: SharedResource, request_latency: float = 0.002) -> None:
        self.sim = sim
        self.fs = fs
        self.request_latency = request_latency

    def submit(self, partition: int, size_bytes: int, on_done: Callable[[], None]) -> None:
        self.sim.schedule_fast(self.request_latency,
                               lambda: self.fs.submit(float(size_bytes), on_done))


class _ImmediateIngest:
    def submit(self, partition: int, size_bytes: int, on_done: Callable[[], None]) -> None:
        on_done()


class SyntheticProducer:
    """Rate-controlled producer on the virtual clock.

    ``msg_factory(i)`` returns ``(key, value, size_bytes)`` for message i.

    Two rate modes: closed-loop AIMD backoff (default; converges to max
    sustained throughput, the paper's measurement operating point), or an
    open-loop ``rate_program`` over ``horizon_s`` virtual seconds (the
    adaptation experiments' externally imposed incoming rate — the system
    scales, the workload does not back off).
    """

    def __init__(
        self,
        sim: Simulator,
        broker: Broker,
        topic: str,
        *,
        msg_factory: Callable[[int], tuple[Any, Any, int]],
        n_messages: int,
        run_id: str,
        metrics: MetricRegistry,
        group: str = "engine",
        aimd: AIMD | None = None,
        ingest=None,
        rate_program: RateProgram | dict | None = None,
        horizon_s: float | None = None,
        idle_resolution_s: float = 0.25,
    ) -> None:
        self.sim = sim
        self.broker = broker
        self.topic = topic
        self.msg_factory = msg_factory
        self.n_messages = n_messages
        self.run_id = run_id
        self.metrics = metrics
        self.group = group
        self.aimd = aimd or AIMD()
        self.ingest = ingest or _ImmediateIngest()
        self.rate_program = (rate_program_from_spec(rate_program)
                             if rate_program is not None else None)
        self.horizon_s = horizon_s
        self.idle_resolution_s = idle_resolution_s
        self.sent = 0
        self.appended = 0
        self.done = False
        self._production_over = False
        self._rec_produce = metrics.recorder(run_id, "producer", "produce")
        self._rec_append = metrics.recorder(run_id, "broker", "append")

    def start(self) -> None:
        self.sim.schedule_fast(
            0.0, self._tick_program if self.rate_program is not None
            else self._tick)

    def _emit_one(self) -> None:
        """Produce message ``sent`` and submit it to the ingest path."""
        i = self.sent
        self.sent += 1
        key, value, size = self.msg_factory(i)
        msg_id = f"{self.run_id}/{i}"
        partition = self.broker.partition_for(self.topic, key) if key is not None \
            else i % self.broker.num_partitions(self.topic)
        self._rec_produce(self.sim.now, msg_id=msg_id, size=size,
                          partition=partition)

        def appended() -> None:
            self.broker.append(self.topic, value, ts=self.sim.now, key=key,
                               partition=partition, run_id=self.run_id,
                               msg_id=msg_id, size_bytes=size)
            self.appended += 1
            self._rec_append(self.sim.now, msg_id=msg_id, size=size,
                             partition=partition)
            if self._production_over and self.appended >= self.sent:
                self.done = True
            elif self.rate_program is None and self.appended >= self.n_messages:
                self.done = True

        self.ingest.submit(partition, size, appended)

    def _finish_production(self) -> None:
        self._production_over = True
        if self.appended >= self.sent:
            self.done = True

    # -- closed loop: AIMD backoff ------------------------------------------
    def _tick(self) -> None:
        if self.sent >= self.n_messages:
            return
        self._emit_one()
        rate = self.aimd.update(self.broker.lag(self.group, self.topic))
        self.sim.schedule_fast(1.0 / rate, self._tick)

    # -- open loop: externally imposed rate program -------------------------
    def _tick_program(self) -> None:
        now = self.sim.now
        if (self.horizon_s is not None and now >= self.horizon_s) \
                or self.sent >= self.n_messages:
            self._finish_production()
            return
        rate = self.rate_program.rate(now)
        if rate <= 1e-9:
            # rate trace is momentarily zero: probe again shortly instead
            # of dividing by it
            self.sim.schedule_fast(self.idle_resolution_s, self._tick_program)
            return
        self._emit_one()
        self.sim.schedule_fast(1.0 / rate, self._tick_program)
