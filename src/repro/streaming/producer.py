"""Synthetic data producer with intelligent backoff (paper §IV).

"To conduct measurements at the maximum sustained throughput, the framework
utilizes an intelligent backoff strategy during data production."  We use
AIMD (additive-increase / multiplicative-decrease) on the production rate,
driven by consumer-group lag: while the processing system keeps up
(lag < lo watermark) the rate creeps up; when lag crosses the hi watermark —
the back-pressure signal — the rate is cut.  At convergence the production
rate oscillates just under the system's maximum sustained throughput,
exactly the operating point the paper measures.

Ingest modeling: Kinesis shards cap ingest at ~1 MB/s each; Kafka appends
ride the shared filesystem.  Both are expressed as an ``ingest`` policy the
mini-app wires in (per-partition ``SharedResource`` for Kinesis; the HPC
backend's Lustre resource for Kafka), so broker-side contention emerges from
the same mechanisms as processing-side contention.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

from repro.core.metrics import MetricRegistry
from repro.sim.des import SharedResource, Simulator
from repro.streaming.broker import Broker

__all__ = ["AIMD", "PartitionIngest", "SyntheticProducer"]


@dataclass
class AIMD:
    """Additive-increase / multiplicative-decrease rate controller."""

    rate_hz: float = 20.0
    min_rate_hz: float = 0.5
    max_rate_hz: float = 5000.0
    increase_hz: float = 2.0
    decrease_factor: float = 0.7
    lo_watermark: int = 4
    hi_watermark: int = 32

    def update(self, lag: int) -> float:
        if lag >= self.hi_watermark:
            self.rate_hz = max(self.rate_hz * self.decrease_factor, self.min_rate_hz)
        elif lag <= self.lo_watermark:
            self.rate_hz = min(self.rate_hz + self.increase_hz, self.max_rate_hz)
        return self.rate_hz


class PartitionIngest:
    """Per-partition ingest bandwidth limit (Kinesis: ~1 MB/s per shard)."""

    def __init__(self, sim: Simulator, partitions: int, bw_per_partition: float = 1e6,
                 request_latency: float = 0.01) -> None:
        self.request_latency = request_latency
        self.resources = [SharedResource(sim, bw_per_partition, name=f"shard{i}")
                          for i in range(partitions)]
        self.sim = sim

    def submit(self, partition: int, size_bytes: int, on_done: Callable[[], None]) -> None:
        res = self.resources[partition % len(self.resources)]
        self.sim.schedule_fast(self.request_latency,
                               lambda: res.submit(float(size_bytes), on_done))


class SharedFsIngest:
    """Kafka-on-HPC ingest: appends ride the shared filesystem resource."""

    def __init__(self, sim: Simulator, fs: SharedResource, request_latency: float = 0.002) -> None:
        self.sim = sim
        self.fs = fs
        self.request_latency = request_latency

    def submit(self, partition: int, size_bytes: int, on_done: Callable[[], None]) -> None:
        self.sim.schedule_fast(self.request_latency,
                               lambda: self.fs.submit(float(size_bytes), on_done))


class _ImmediateIngest:
    def submit(self, partition: int, size_bytes: int, on_done: Callable[[], None]) -> None:
        on_done()


class SyntheticProducer:
    """Rate-controlled producer on the virtual clock.

    ``msg_factory(i)`` returns ``(key, value, size_bytes)`` for message i.
    """

    def __init__(
        self,
        sim: Simulator,
        broker: Broker,
        topic: str,
        *,
        msg_factory: Callable[[int], tuple[Any, Any, int]],
        n_messages: int,
        run_id: str,
        metrics: MetricRegistry,
        group: str = "engine",
        aimd: AIMD | None = None,
        ingest=None,
    ) -> None:
        self.sim = sim
        self.broker = broker
        self.topic = topic
        self.msg_factory = msg_factory
        self.n_messages = n_messages
        self.run_id = run_id
        self.metrics = metrics
        self.group = group
        self.aimd = aimd or AIMD()
        self.ingest = ingest or _ImmediateIngest()
        self.sent = 0
        self.appended = 0
        self.done = False
        self._rec_produce = metrics.recorder(run_id, "producer", "produce")
        self._rec_append = metrics.recorder(run_id, "broker", "append")

    def start(self) -> None:
        self.sim.schedule_fast(0.0, self._tick)

    def _tick(self) -> None:
        if self.sent >= self.n_messages:
            return
        i = self.sent
        self.sent += 1
        key, value, size = self.msg_factory(i)
        msg_id = f"{self.run_id}/{i}"
        partition = self.broker.partition_for(self.topic, key) if key is not None \
            else i % self.broker.num_partitions(self.topic)
        self._rec_produce(self.sim.now, msg_id=msg_id, size=size,
                          partition=partition)

        def appended() -> None:
            self.broker.append(self.topic, value, ts=self.sim.now, key=key,
                               partition=partition, run_id=self.run_id,
                               msg_id=msg_id, size_bytes=size)
            self.appended += 1
            self._rec_append(self.sim.now, msg_id=msg_id, size=size,
                             partition=partition)
            if self.appended >= self.n_messages:
                self.done = True

        self.ingest.submit(partition, size, appended)

        rate = self.aimd.update(self.broker.lag(self.group, self.topic))
        self.sim.schedule_fast(1.0 / rate, self._tick)
