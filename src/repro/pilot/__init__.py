from repro.pilot.api import (ComputeUnit, ComputeUnitDescription, Pilot,
                             PilotComputeService, PilotDescription, State,
                             TaskProfile)

__all__ = ["Pilot", "PilotDescription", "ComputeUnit", "ComputeUnitDescription",
           "PilotComputeService", "State", "TaskProfile"]
