"""The Pilot-API: unified resource management across HPC, cloud, serverless
and TPU meshes (paper §III).

Two entities (paper): *pilot-job* — a user-defined set of resources — and
*compute-unit* — a self-contained task, the key abstraction for expressing
the application workload.  Resources are requested with a
``PilotDescription``; once a ``Pilot`` is running, ``ComputeUnit``s are
submitted to it.  The description is *normative*: the same attributes
(``number_of_nodes``, ``cores_per_node``, ``memory_mb``, ``concurrency``,
``partitions``) configure every backend; backend-specific details live in
``attrs`` (mirroring the paper's Lambda layers / memory-limit passthrough).

Backends are plugins keyed by the URL scheme of ``PilotDescription.resource``:

    local://            in-process thread pool (real execution, wall clock)
    serverless://       AWS Lambda + Kinesis mechanism simulation (virtual clock)
    hpc://<machine>     Kafka + Dask on HPC mechanism simulation (virtual clock)
    jax://mesh          mesh-slice resource containers over jax devices

This mirrors the paper's plugin architecture (Fig 2): the Pilot-Manager
offers one API; plugins encapsulate platform detail.
"""

from __future__ import annotations

import enum
import threading
from dataclasses import dataclass, field
from typing import Any, Callable

__all__ = [
    "State",
    "TaskProfile",
    "PilotDescription",
    "ComputeUnitDescription",
    "ComputeUnit",
    "Pilot",
    "PilotComputeService",
    "register_backend",
]


class State(enum.Enum):
    NEW = "new"
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    CANCELED = "canceled"

    @property
    def is_final(self) -> bool:
        return self in _FINAL_STATES


# frozenset membership (identity hash) beats rebuilding a tuple of members
# on every is_final call — the engine checks finality per event
_FINAL_STATES = frozenset((State.DONE, State.FAILED, State.CANCELED))


@dataclass(frozen=True)
class TaskProfile:
    """Mechanism-level cost profile of a compute-unit (used by the simulated
    backends to derive service times; ignored by real-execution backends).

    flops           embarrassingly-parallel floating-point ops (e.g. the
                    K-Means distance phase)
    serial_flops    work on the *shared model* (read-modify-write: partial-fit
                    merge + serialization).  Backends with a consistent shared
                    store (HPC/Lustre) execute this under a global lock — the
                    paper's sigma; isolated backends (Lambda/S3, last-writer-
                    wins) run it lock-free inside the container.
    read_bytes      bytes read from shared state (model download, S3 GET)
    write_bytes     bytes written to shared state (model upload, S3 PUT)
    msg_bytes       size of the triggering message (broker → worker transfer)
    coherence_peers if > 0, the task synchronizes with that many peers
                    (e.g. reads each peer's model delta) — the paper's
                    all-to-all model-parameter sharing
    memory_mb       working-set size; must fit the container
    """

    flops: float = 0.0
    serial_flops: float = 0.0
    read_bytes: float = 0.0
    write_bytes: float = 0.0
    msg_bytes: float = 0.0
    coherence_peers: int = 0
    memory_mb: float = 64.0


@dataclass
class PilotDescription:
    """Normative resource request (paper Table/Fig 2: one attribute set for
    Kinesis shards and Kafka partitions alike)."""

    resource: str = "local://"
    number_of_nodes: int = 1
    cores_per_node: int = 1
    memory_mb: int = 3008          # per container (Lambda) / per worker
    concurrency: int | None = None # max simultaneous containers/tasks
    walltime_s: float = 900.0      # serverless hard limit: 15 min
    partitions: int = 1            # broker shards / processing partitions
    attrs: dict = field(default_factory=dict)

    @property
    def scheme(self) -> str:
        return self.resource.split("://", 1)[0]


@dataclass(slots=True)
class ComputeUnitDescription:
    """A self-contained task: a real callable and/or a cost profile."""

    func: Callable[..., Any] | None = None
    args: tuple = ()
    kwargs: dict = field(default_factory=dict)
    profile: TaskProfile | None = None
    name: str = "cu"
    run_id: str | None = None
    partition: int | None = None   # streaming mode: broker partition binding


class ComputeUnit:
    """Handle for a submitted task.

    ``__slots__``: the streaming engine mints one per micro-batch, so the
    per-instance ``__dict__`` was measurable across a sweep."""

    __slots__ = ("desc", "uid", "pilot", "state", "result_value", "exception",
                 "submit_ts", "start_ts", "end_ts", "_done", "callbacks",
                 "attrs")

    def __init__(self, desc: ComputeUnitDescription, uid: int, pilot: "Pilot") -> None:
        self.desc = desc
        self.uid = uid
        self.pilot = pilot
        self.state = State.NEW
        self.result_value: Any = None
        self.exception: BaseException | None = None
        self.submit_ts: float = 0.0
        self.start_ts: float = 0.0
        self.end_ts: float = 0.0
        # lazily created: nothing blocks on it in the simulated backends,
        # and the mini-app creates one CU per micro-batch — a kernel-backed
        # Event per CU was pure allocation overhead on the hot path
        self._done: threading.Event | None = None
        self.callbacks: list = []   # fn(cu) invoked once, on any final state
        self.attrs: dict = {}       # backend-set placement info (container/worker)

    @property
    def done_event(self) -> threading.Event:
        """Event set on any final state (created on first access)."""
        if self._done is None:
            self._done = threading.Event()
            if self.state.is_final:
                self._done.set()
        return self._done

    def add_done_callback(self, fn) -> None:
        if self.state.is_final:
            fn(self)
        else:
            self.callbacks.append(fn)

    def _fire_callbacks(self) -> None:
        cbs, self.callbacks = self.callbacks, []
        for fn in cbs:
            fn(self)

    # -- lifecycle (driven by the backend) ----------------------------------
    def _set_running(self, ts: float) -> None:
        self.state = State.RUNNING
        self.start_ts = ts

    def _set_done(self, ts: float, result: Any) -> None:
        self.state = State.DONE
        self.end_ts = ts
        self.result_value = result
        if self._done is not None:
            self._done.set()
        self._fire_callbacks()

    def _set_failed(self, ts: float, exc: BaseException) -> None:
        self.state = State.FAILED
        self.end_ts = ts
        self.exception = exc
        if self._done is not None:
            self._done.set()
        self._fire_callbacks()

    def _set_canceled(self, ts: float) -> None:
        self.state = State.CANCELED
        self.end_ts = ts
        if self._done is not None:
            self._done.set()
        self._fire_callbacks()

    # -- user API ------------------------------------------------------------
    def wait(self, timeout: float | None = None) -> "ComputeUnit":
        self.pilot.backend.drive_until(lambda: self.state.is_final, timeout)
        return self

    def result(self, timeout: float | None = None) -> Any:
        self.wait(timeout)
        if self.state == State.FAILED:
            raise self.exception  # noqa: raise original
        if self.state == State.CANCELED:
            raise RuntimeError(f"compute unit {self.uid} canceled")
        return self.result_value

    @property
    def runtime(self) -> float:
        return self.end_ts - self.start_ts

    @property
    def wait_time(self) -> float:
        return self.start_ts - self.submit_ts


class Pilot:
    """A resource container on some backend."""

    def __init__(self, desc: PilotDescription, backend: "Backend", uid: int) -> None:
        self.desc = desc
        self.backend = backend
        self.uid = uid
        self.state = State.PENDING
        self._cu_uid = 0
        self.compute_units: list[ComputeUnit] = []

    def submit_compute_unit(self, desc: ComputeUnitDescription | None = None, **kw) -> ComputeUnit:
        if desc is None:
            desc = ComputeUnitDescription(**kw)
        if self.state.is_final:
            raise RuntimeError(f"pilot {self.uid} is {self.state}")
        cu = ComputeUnit(desc, self._cu_uid, self)
        self._cu_uid += 1
        self.compute_units.append(cu)
        self.backend.submit(self, cu)
        return cu

    def wait_all(self, timeout: float | None = None) -> None:
        self.backend.drive_until(
            lambda: all(cu.state.is_final for cu in self.compute_units), timeout)

    def cancel(self) -> None:
        self.backend.cancel_pilot(self)
        self.state = State.CANCELED


class Backend:
    """Backend plugin interface."""

    scheme = "abstract"

    def start_pilot(self, pilot: Pilot) -> None:
        raise NotImplementedError

    def submit(self, pilot: Pilot, cu: ComputeUnit) -> None:
        raise NotImplementedError

    def shared_resource(self, pilot: Pilot, name: str):
        """Public accessor for a pilot's named shared resource (e.g. the HPC
        backend's ``"fs"`` Lustre ``SharedResource``).  Backends without
        shared infrastructure raise ``LookupError`` — e.g. serverless
        containers are isolated by construction (that isolation is what
        makes sigma, kappa ≈ 0 emerge in the USL fit)."""
        raise LookupError(
            f"backend {self.scheme!r} exposes no shared resource {name!r}")

    # -- elasticity (the EILC hook: Pilot-Streaming's dynamic resource-
    # -- container management) ----------------------------------------------
    def scale_to(self, pilot: Pilot, n: int) -> int:
        """Grow/shrink the pilot's execution capacity to ``n`` units
        mid-run (containers on serverless, workers on HPC).  Returns the
        *granted* target (backends may clamp, e.g. the Lambda concurrency
        cap).  Growth is asynchronous where the platform makes it so:
        serverless containers pay a cold start on first invocation, HPC
        workers become usable only after the scheduler's queue/grant
        delay.  Static backends raise ``NotImplementedError``."""
        raise NotImplementedError(f"backend {self.scheme!r} is not elastic")

    def allocation(self, pilot: Pilot) -> int:
        """Current target capacity (execution units) of the pilot."""
        raise NotImplementedError(f"backend {self.scheme!r} is not elastic")

    def effective_allocation(self, pilot: Pilot) -> int:
        """Capacity actually *granted* right now, which can trail the
        target: HPC workers grown mid-run wait out the scheduler's
        queue/grant delay, busy containers survive a shrink until their
        task finishes.  The online USL estimator attributes observed rates
        to this, not the target.  Defaults to ``allocation``."""
        return self.allocation(pilot)

    # -- fault surface (driven by streaming.faults.FaultInjector) -------------
    def inject_crash(self, pilot: Pilot, count: int = 1) -> int:
        """Crash up to ``count`` execution units (containers/workers):
        in-flight work fails with ``ConnectionError`` (the engines' retry
        path re-dispatches it) and the platform replaces the capacity per
        its own semantics — serverless restarts a fresh cold container
        immediately, HPC workers restart through the batch queue.  Returns
        the number of units actually crashed; backends without fault
        support inject nothing."""
        return 0

    def preempt(self, pilot: Pilot, count: int = 1) -> int:
        """Spot-style preemption: revoke up to ``count`` units of *granted*
        capacity through the platform — serverless kills live containers,
        HPC evicts granted workers back into the queue, wall-clock
        backends shrink admitted worker slots.  ``effective_allocation``
        dips while the revocation is in force; capacity returns per
        backend semantics (restore delay / re-queued grant).  Returns the
        number of units actually revoked."""
        return 0

    def cancel_pilot(self, pilot: Pilot) -> None:
        pass

    def drive_until(self, predicate: Callable[[], bool], timeout: float | None) -> None:
        """Advance execution until ``predicate`` holds.  Simulated backends
        step their event queue; real backends block on conditions."""
        raise NotImplementedError

    def close(self) -> None:
        pass


_BACKENDS: dict[str, Callable[..., Backend]] = {}


def register_backend(scheme: str, factory: Callable[..., Backend]) -> None:
    _BACKENDS[scheme] = factory


class PilotComputeService:
    """Entry point (the paper's Pilot-Manager): routes PilotDescriptions to
    backend plugins and tracks live pilots."""

    def __init__(self, **backend_kwargs) -> None:
        self._pilot_uid = 0
        self.pilots: list[Pilot] = []
        self._backends: dict[str, Backend] = {}
        self._backend_kwargs = backend_kwargs

    def _backend(self, scheme: str) -> Backend:
        if scheme not in self._backends:
            if scheme not in _BACKENDS:
                # late registration: import built-in plugins on demand
                from repro.pilot import backends as _b  # noqa: F401
            if scheme not in _BACKENDS:
                raise ValueError(f"no backend registered for scheme '{scheme}'; "
                                 f"known: {sorted(_BACKENDS)}")
            self._backends[scheme] = _BACKENDS[scheme](**self._backend_kwargs)
        return self._backends[scheme]

    def submit_pilot(self, desc: PilotDescription) -> Pilot:
        backend = self._backend(desc.scheme)
        pilot = Pilot(desc, backend, self._pilot_uid)
        self._pilot_uid += 1
        backend.start_pilot(pilot)
        self.pilots.append(pilot)
        return pilot

    def close(self) -> None:
        for b in self._backends.values():
            b.close()
