"""Federated multi-backend: health-checked failover and cost-aware placement.

One ``FederatedBackend`` owns N member backends (any mix of the *simulated*
backends — serverless / hpcsim — sharing ONE virtual clock) and presents
the ordinary ``Backend`` surface, so the streaming engine, ``ControlLoop``
and ``FaultInjector`` drive a federation exactly like a single backend.
This is the paper's EILC story taken to backend-level blast radius: one
workload, heterogeneous capacity, one model-driven controller — burst onto
serverless while HPC grants are pending, drain back when cheaper capacity
arrives, and survive a whole-member outage as a degradation instead of a
failure (Lithops' multi-backend invoker/monitor design, Pilot-Streaming's
unified resource abstraction).

Architecture
------------

* **Membership.** ``PilotDescription.attrs["federation"]["members"]`` lists
  member specs (``machine`` or ``resource`` URL, ``price`` per
  unit-second, ``max_units``, optional ``usl`` prior ``(sigma, kappa,
  gamma)``, optional ``grant_latency_s`` prior, per-member backend
  ``attrs``).  Each member gets its own backend *instance* constructed on
  the federation's shared :class:`~repro.sim.des.Simulator` plus an inner
  ``Pilot``, so member state (queues, containers, fault surfaces) stays
  isolated while time is coherent.

* **Routing.** ``scale_to(n)`` splits the total target across members with
  a greedy marginal-score placement: each unit lands on the member
  maximizing ``marginal predicted throughput / (price * (1 +
  grant_latency / glat_scale_s))`` — the price x grant-latency x
  predicted-capacity score, with the per-member prediction coming from a
  per-member :class:`~repro.core.autoscale.OnlineUSLEstimator` (prior from
  the member spec).  Partitions ``0..n-1`` are then assigned to members
  sticky-first (a partition keeps its owner while that owner retains
  quota), and pinned compute units are routed to the owning member with a
  member-local partition rank so each member's own pinning stays dense.

* **Health + circuit breaker (clock-agnostic).** Per-member error-rate and
  grant-latency EWMAs are fed purely from CU completions; breaker
  transitions are evaluated lazily at observation points (submits,
  completions, ``effective_allocation`` reads — i.e. every control tick)
  by *reading* the clock, never by scheduling on it.  States: ``closed``
  (healthy) -> ``open`` on outage signal (error EWMA >=
  ``open_error_rate``, or an injected ``backend_outage``) -> after
  ``open_cooldown_s`` -> ``half_open`` (re-admitted at ``probe_units``
  capacity) -> ``closed`` after ``probe_successes`` clean completions with
  the error EWMA back under ``close_error_rate``; a failure while probing
  re-opens.

* **Drain-and-migrate.** Opening a breaker re-splits the same total target
  across the survivors: the failed member's partitions are re-owned
  sticky-first by survivors, its in-flight CUs die with
  ``ConnectionError`` (the engine's un-pinned retry redelivers them on a
  survivor), and subsequent pinned dispatch routes to the new owners — so
  the PR 7 at-least-once invariant (``lost == 0``) holds through a full
  member outage.  Partition *count* changes still flow through the
  ordinary ``ControlLoop`` -> ``Broker.repartition`` -> engine migration
  path; failover itself only re-routes ownership.

* **Faults.** ``inject_outage(member, duration_s)`` (the ``backend_outage``
  fault kind) revokes the member's capacity through its own ``preempt``
  surface, fail-fasts submissions while in force and trips the breaker;
  ``inject_grant_starvation`` (the ``grant_starvation`` kind) freezes the
  member's scale-UP and inflates its grant-latency score so bursts land on
  the other members.  ``inject_crash``/``preempt`` fan out round-robin
  across healthy members.  Any fault dirties the member's current
  estimator window: fault-poisoned windows contribute **zero** samples to
  the per-member USL fits (``dirty_windows`` counts them,
  ``dirty_samples`` stays 0 by construction — gated in perf_smoke).

Determinism: the module is sim-classified (simlint manifest) — no wall
clock, no unseeded randomness, no locks; every decision is a pure function
of the shared DES clock and seeded member backends, so federated runs are
bit-identical under a seed.  Mixing sim and wall (``local://``) members is
not supported: the shared clock cannot span both worlds.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any

from repro.core.autoscale import OnlineUSLEstimator
from repro.core.usl import USLFit
from repro.pilot.api import (Backend, ComputeUnit, Pilot, PilotDescription,
                             State, register_backend)
from repro.sim.des import Simulator

DEFAULTS = dict(
    err_alpha=0.35,          # EWMA weight of the newest completion outcome
    glat_alpha=0.3,          # grant-latency EWMA weight
    open_error_rate=0.5,     # closed -> open at this error EWMA
    close_error_rate=0.2,    # half_open -> closed needs EWMA back under this
    open_cooldown_s=10.0,    # open -> half_open after this long
    probe_units=1,           # capacity cap while half_open
    probe_successes=3,       # clean completions to re-close
    glat_scale_s=10.0,       # grant-latency normalization in the score
    min_window_s=0.5,        # min dt between member capacity samples
    refit_interval_s=10.0,   # per-member estimator refit cadence
)

#: breaker states, in escalation order
BREAKER_STATES = ("closed", "open", "half_open")


def _member_resource(spec: dict) -> str:
    """Resolve a member spec to a backend resource URL (same mapping as
    the platform cells: ``serverless`` -> aws-sim, anything else -> an
    hpcsim machine)."""
    if "resource" in spec:
        return spec["resource"]
    machine = spec.get("machine", "serverless")
    if machine == "serverless":
        return "serverless://aws-sim"
    return f"hpc://{machine}-sim"


@dataclass
class _Member:
    """One federation member: its backend, inner pilot, health state and
    capacity model.  Everything here is driven by CU completions and the
    shared virtual clock — nothing schedules."""

    index: int
    name: str
    backend: Backend
    pilot: Pilot
    price: float = 1.0
    max_units: int = 64
    # breaker / health
    state: str = "closed"
    err_ewma: float = 0.0
    glat_ewma: float = 0.0
    probe_ok: int = 0
    opens: int = 0                 # closed/half_open -> open transitions
    outage_until: float = 0.0
    starved_until: float = 0.0
    open_until: float = 0.0
    # placement / accounting
    target: int = 0                # units the split currently asks it to hold
    outstanding: int = 0           # submitted-but-unfinished CUs
    submitted: int = 0
    completed: int = 0
    failures: int = 0
    cost_integral: float = 0.0     # price-weighted integral of target units
    # estimator feed
    estimator: OnlineUSLEstimator | None = None
    last_sample_t: float = 0.0
    last_completed: int = 0
    dirty: bool = False            # a fault touched this member this window
    dirty_windows: int = 0         # windows skipped because dirty
    dirty_samples: int = 0         # samples admitted while dirty (must stay 0)
    est_samples: int = 0
    # hot-path caches (set once in start_pilot): the submit/finish pair runs
    # per CU, so EWMA constants and the callback live here, not in cfg dicts
    err_keep: float = 0.65         # 1 - err_alpha
    glat_alpha: float = 0.3
    final_cb: Any = None           # pre-bound _on_cu_final(st, m, .)
    cu_list: Any = None            # pilot.compute_units

    def usable(self, now: float) -> bool:
        return self.state != "open" and now >= self.outage_until


class FederatedBackend(Backend):
    """N member backends behind one ``Backend`` surface (see module doc)."""

    scheme = "federated"

    def __init__(self, sim: Simulator | None = None, seed: int = 0,
                 **_kw) -> None:
        self.sim = sim or Simulator(seed=seed)
        self._seed = seed
        self._pilots: dict[int, dict] = {}

    # -- lifecycle -----------------------------------------------------------
    def start_pilot(self, pilot: Pilot) -> None:
        from repro.pilot.api import _BACKENDS   # plugin registry

        spec = dict(pilot.desc.attrs.get("federation") or {})
        member_specs = spec.pop("members", None)
        if not member_specs:
            raise ValueError(
                "federated pilot needs attrs['federation']['members'] "
                "(a list of member specs)")
        cfg = dict(DEFAULTS)
        unknown = set(spec) - set(cfg)
        if unknown:
            raise ValueError(f"unknown federation keys: {sorted(unknown)}")
        cfg.update(spec)

        total = max(1, pilot.desc.partitions)
        members: list[_Member] = []
        for i, mspec in enumerate(member_specs):
            resource = _member_resource(mspec)
            mscheme = resource.split("://", 1)[0]
            if mscheme == self.scheme:
                raise ValueError("federations do not nest")
            backend = _BACKENDS[mscheme](sim=self.sim, seed=self._seed)
            units0 = max(1, total // len(member_specs))
            desc = PilotDescription(
                resource=resource, memory_mb=pilot.desc.memory_mb,
                partitions=units0, concurrency=units0,
                walltime_s=pilot.desc.walltime_s,
                attrs=dict(mspec.get("attrs") or {}))
            inner = Pilot(desc, backend, uid=pilot.uid * 1000 + i)
            backend.start_pilot(inner)
            prior = mspec.get("usl")
            fit = (USLFit(sigma=prior[0], kappa=prior[1], gamma=prior[2],
                          r2=1.0, rmse=0.0, n_obs=0)
                   if prior else
                   # near-linear but concave prior: marginal throughput
                   # shrinks slightly with load, so equal-price members
                   # spread instead of piling onto the lowest index
                   USLFit(sigma=0.0, kappa=1e-3, gamma=1.0,
                          r2=0.0, rmse=0.0, n_obs=0))
            members.append(_Member(
                index=i,
                name=mspec.get("name") or f"{i}:{resource}",
                backend=backend, pilot=inner,
                price=float(mspec.get("price", 1.0)),
                max_units=int(mspec.get("max_units", 64)),
                glat_ewma=float(mspec.get("grant_latency_s", 0.0)),
                estimator=OnlineUSLEstimator(
                    fit, refit_interval_s=cfg["refit_interval_s"]),
            ))
        st = {
            "cfg": cfg,
            "members": members,
            "target": total,
            "granted": total,
            "owner": [],          # partition -> member index
            "rank": [],           # partition -> member-local rank
            "resplit": False,     # a breaker transition wants a re-split
            "fault_rr": 0,        # round-robin cursor for crash/preempt fan-out
            "last_cost_t": self.sim.now,
            "last_probe_t": -1.0,
            # submit fast-path key: the lone member, or None when federated
            "single": members[0] if len(members) == 1 else None,
        }
        self._pilots[pilot.uid] = st
        for m in members:
            m.err_keep = 1.0 - cfg["err_alpha"]
            m.glat_alpha = cfg["glat_alpha"]
            m.final_cb = partial(self._on_cu_final, st, m)
            m.cu_list = m.pilot.compute_units
        self._resplit(st)
        pilot.state = State.RUNNING

    # -- placement -----------------------------------------------------------
    def _caps(self, st: dict, m: _Member, now: float) -> int:
        """Units member *m* may hold right now, breaker- and fault-aware."""
        if m.state == "open" or now < m.outage_until:
            return 0
        if m.state == "half_open":
            return int(st["cfg"]["probe_units"])
        if now < m.starved_until:
            return m.target        # starved: hold, never grow
        return m.max_units

    def _score(self, st: dict, m: _Member, units: int, now: float) -> float:
        """Marginal value of giving member *m* its ``units+1``-th unit:
        predicted marginal throughput over price x normalized grant
        latency — the cost-aware placement score."""
        fit = m.estimator.fit
        marginal = fit.predict(units + 1) - fit.predict(units)
        glat = m.glat_ewma
        if now < m.starved_until:            # pending grants won't arrive
            glat = max(glat, m.starved_until - now)
        denom = m.price * (1.0 + glat / st["cfg"]["glat_scale_s"])
        return marginal / max(denom, 1e-12)

    def _resplit(self, st: dict) -> None:
        """Split ``st['target']`` units across members by greedy marginal
        score, then re-own partitions sticky-first.  Deterministic: ties
        break on member index."""
        now = self.sim.now
        members = st["members"]
        n = st["target"]
        if len(members) == 1:
            # no placement choice: the cap alone decides, no scoring
            units = [min(n, self._caps(st, members[0], now))]
        else:
            units = [0] * len(members)
            # half-open members get their probe quota RESERVED, not competed
            # for: re-admission needs probe traffic even when the member's
            # score loses to every survivor (e.g. it is the expensive one)
            budget = n
            for m in members:
                if m.state == "half_open" and budget > 0:
                    units[m.index] = min(int(st["cfg"]["probe_units"]), budget)
                    budget -= units[m.index]
            for _ in range(budget):
                best, best_score = None, 0.0
                for m in members:
                    if units[m.index] >= self._caps(st, m, now):
                        continue
                    s = self._score(st, m, units[m.index], now)
                    if best is None or s > best_score:
                        best, best_score = m, s
                if best is None:
                    break
                units[best.index] += 1
        if sum(units) == 0:
            # every member is down: park the target on member 0 so the
            # Backend contract (granted >= 1) holds; work fail-fasts and
            # the engine's retry/abandon budget bounds the damage
            units[0] = n
        # sticky re-ownership: a partition keeps its owner while the owner
        # retains quota; freed/new partitions fill from the lowest index.
        # Every partition gets an owner even when caps shrink the split
        # below n (half-open probe, starvation): the surplus partitions
        # cycle over the members that hold units, so pinned dispatch always
        # routes somewhere live
        remaining = list(units)
        owner = [-1] * n
        old = st["owner"]
        for p in range(min(n, len(old))):
            if old[p] >= 0 and remaining[old[p]] > 0:
                owner[p] = old[p]
                remaining[old[p]] -= 1
        fill = [i for i, k in enumerate(remaining) for _ in range(k)]
        holders = [i for i, k in enumerate(units) if k > 0] or [0]
        cyc = 0
        for p in range(n):
            if owner[p] < 0:
                if fill:
                    owner[p] = fill.pop(0)
                else:
                    owner[p] = holders[cyc % len(holders)]
                    cyc += 1
        seen = [0] * len(members)
        rank = [0] * n
        for p in range(n):
            rank[p] = seen[owner[p]]
            seen[owner[p]] += 1
        st["owner"], st["rank"] = owner, rank
        for m in members:
            want = units[m.index]
            if want != m.target or m.target == 0:
                m.target = want
                # member backends clamp to >= 1; a 0-target member keeps one
                # idle unit underneath but it is never routed to nor billed
                m.backend.scale_to(m.pilot, max(1, want))
        st["granted"] = sum(units)
        st["resplit"] = False

    # -- health monitor ------------------------------------------------------
    def _health_feed(self, st: dict, m: _Member, *, failed: bool,
                     grant_s: float | None = None) -> None:
        cfg = st["cfg"]
        a = cfg["err_alpha"]
        m.err_ewma = a * (1.0 if failed else 0.0) + (1.0 - a) * m.err_ewma
        if grant_s is not None:
            g = cfg["glat_alpha"]
            m.glat_ewma = g * grant_s + (1.0 - g) * m.glat_ewma
        now = self.sim.now
        if failed:
            m.dirty = True
            if m.state == "closed" and m.err_ewma >= cfg["open_error_rate"]:
                self._open(st, m, cfg["open_cooldown_s"])
            elif m.state == "half_open":       # failed the probe: back off
                self._open(st, m, cfg["open_cooldown_s"])
        elif m.state == "half_open":
            m.probe_ok += 1
            if (m.probe_ok >= cfg["probe_successes"]
                    and m.err_ewma <= cfg["close_error_rate"]
                    and now >= m.outage_until):
                m.state = "closed"
                st["resplit"] = True           # full re-admission next probe

    def _open(self, st: dict, m: _Member, cooldown_s: float) -> None:
        m.state = "open"
        m.opens += 1
        m.probe_ok = 0
        m.open_until = self.sim.now + cooldown_s
        m.dirty = True
        st["resplit"] = True                   # drain-and-migrate to survivors

    def _probe(self, st: dict) -> None:
        """Lazy observation point: accrue cost, advance breaker timers, and
        sample per-member capacity windows into the estimators.  Runs at
        most once per distinct timestamp (the control loop reads
        ``effective_allocation`` twice per tick)."""
        now = self.sim.now
        if now == st["last_probe_t"]:
            if st["resplit"]:
                self._resplit(st)
            return
        st["last_probe_t"] = now
        dt = now - st["last_cost_t"]
        st["last_cost_t"] = now
        cfg = st["cfg"]
        for m in st["members"]:
            if dt > 0.0:
                m.cost_integral += m.price * m.target * dt
            if m.state == "open" and now >= m.open_until:
                m.state = "half_open"
                m.probe_ok = 0
                st["resplit"] = True           # grant the probe capacity
            wdt = now - m.last_sample_t
            if wdt >= cfg["min_window_s"]:
                done = m.completed
                if m.dirty or not m.usable(now) or now < m.starved_until:
                    # fault-poisoned window: contribute ZERO samples
                    m.dirty_windows += 1
                elif m.target > 0 and m.estimator is not None:
                    rate = (done - m.last_completed) / wdt
                    if m.estimator.observe(now, m.target, rate,
                                           lag=m.outstanding):
                        m.est_samples += 1
                    # the fit is only ever read by _score, and _score only
                    # matters when there is a placement choice: a single-
                    # member federation skips re-fits so the wrapper costs
                    # nothing but the EWMAs
                    if len(st["members"]) > 1:
                        m.estimator.maybe_refit(now)
                m.last_sample_t = now
                m.last_completed = done
                m.dirty = False
        if st["resplit"]:
            self._resplit(st)

    # -- routing -------------------------------------------------------------
    def _route(self, st: dict, cu: ComputeUnit) -> _Member:
        members = st["members"]
        p = cu.desc.partition
        if p is not None and st["owner"]:
            return members[st["owner"][p % len(st["owner"])]]
        # un-pinned (retry / straggler copy): round-robin over usable members
        now = self.sim.now
        usable = [m for m in members if m.usable(now)] or members
        m = usable[st["fault_rr"] % len(usable)]
        st["fault_rr"] += 1
        return m

    def submit(self, pilot: Pilot, cu: ComputeUnit) -> None:
        st = self._pilots[pilot.uid]
        if st["single"] is not None:
            # single-member fast path: routing and rank are the identity
            # (rank[p % n] == p % n, and the member backend pins p % n
            # itself), so skip both.  A fresh CU is never final, so the
            # callback list append needs no is_final gate
            m = st["single"]
            if m.state == "closed" and self.sim.now >= m.outage_until:
                m.submitted += 1
                m.outstanding += 1
                cu.callbacks.append(m.final_cb)
                m.cu_list.append(cu)
                m.backend.submit(m.pilot, cu)
                return
        now = self.sim.now
        m = self._route(st, cu)
        if now < m.outage_until:
            # fail fast, like dispatch to a dead worker: the engine's
            # un-pinned ConnectionError retry re-routes to a survivor
            m.failures += 1
            self._health_feed(st, m, failed=True)
            cu.submit_ts = now
            cu._set_failed(now, ConnectionError(
                f"federated member {m.name} is in outage"))
            return
        if cu.desc.partition is not None and st["rank"]:
            # member-local rank keeps the member's own pinning dense
            cu.desc.partition = st["rank"][cu.desc.partition % len(st["rank"])]
        m.submitted += 1
        m.outstanding += 1
        cu.attrs["member"] = m.index
        cu.add_done_callback(m.final_cb)
        # the member's fault surface scans its own pilot's CU list
        m.cu_list.append(cu)
        m.backend.submit(m.pilot, cu)

    def _on_cu_final(self, st: dict, m: _Member, cu: ComputeUnit,
                     _DONE=State.DONE, _FAILED=State.FAILED) -> None:
        m.outstanding -= 1
        if cu.state is _DONE:
            m.completed += 1
            if m.state == "closed":
                # the per-CU common case, inlined: the same EWMA updates
                # _health_feed would make, minus its breaker branches (all
                # no-ops while closed and healthy)
                m.err_ewma *= m.err_keep
                g = m.glat_alpha
                m.glat_ewma = (g * (cu.start_ts - cu.submit_ts)
                               + (1.0 - g) * m.glat_ewma)
            else:
                self._health_feed(st, m, failed=False, grant_s=cu.wait_time)
        elif cu.state is _FAILED:
            m.failures += 1
            self._health_feed(st, m, failed=True)

    # -- elasticity ----------------------------------------------------------
    def scale_to(self, pilot: Pilot, n: int) -> int:
        st = self._pilots[pilot.uid]
        self._probe(st)
        st["target"] = max(1, int(n))
        self._resplit(st)
        return st["granted"]

    def allocation(self, pilot: Pilot) -> int:
        return self._pilots[pilot.uid]["target"]

    def effective_allocation(self, pilot: Pilot) -> int:
        st = self._pilots[pilot.uid]
        self._probe(st)
        now = self.sim.now
        eff = 0
        for m in st["members"]:
            if m.target <= 0 or not m.usable(now):
                continue
            eff += min(m.backend.effective_allocation(m.pilot), m.target)
        return eff

    # -- fault surface -------------------------------------------------------
    def _fanout(self, st: dict, count: int, hook: str) -> int:
        """Spread ``count`` worker-level faults round-robin across usable
        members via their own fault surfaces."""
        now = self.sim.now
        members = [m for m in st["members"] if m.usable(now)] or st["members"]
        acted = 0
        for i in range(max(0, int(count))):
            m = members[(st["fault_rr"] + i) % len(members)]
            acted += getattr(m.backend, hook)(m.pilot, 1)
            m.dirty = True
        st["fault_rr"] += count
        return acted

    def inject_crash(self, pilot: Pilot, count: int = 1) -> int:
        return self._fanout(self._pilots[pilot.uid], count, "inject_crash")

    def preempt(self, pilot: Pilot, count: int = 1) -> int:
        return self._fanout(self._pilots[pilot.uid], count, "preempt")

    def inject_outage(self, pilot: Pilot, member: int | None = None,
                      duration_s: float = 20.0) -> int:
        """``backend_outage`` fault kind: take one whole member down for
        ``duration_s`` — capacity revoked through its own ``preempt``
        surface, submissions fail fast, breaker opens until the outage
        lifts, partitions migrate to survivors immediately."""
        st = self._pilots[pilot.uid]
        members = st["members"]
        m = members[(member or 0) % len(members)]
        now = self.sim.now
        m.outage_until = max(m.outage_until, now + duration_s)
        revoked = m.backend.preempt(
            m.pilot, m.backend.effective_allocation(m.pilot))
        self._open(st, m, max(duration_s, st["cfg"]["open_cooldown_s"]))
        self._resplit(st)                      # migrate now, not next tick
        return max(1, revoked)

    def inject_grant_starvation(self, pilot: Pilot, member: int | None = None,
                                duration_s: float = 20.0) -> int:
        """``grant_starvation`` fault kind: the member's scale-UP freezes
        and its grant-latency score inflates for ``duration_s``, so bursts
        land on the other members until grants flow again."""
        st = self._pilots[pilot.uid]
        members = st["members"]
        m = members[(member or 0) % len(members)]
        m.starved_until = max(m.starved_until, self.sim.now + duration_s)
        m.dirty = True
        st["resplit"] = True
        return 1

    # -- introspection -------------------------------------------------------
    def member_ledger(self, pilot: Pilot) -> list[dict]:
        """Per-member report card (JSON-able): placement, health, breaker
        history, price-weighted cost and estimator hygiene."""
        st = self._pilots[pilot.uid]
        self._probe(st)
        return [dict(
            name=m.name, price=m.price, units=m.target, state=m.state,
            opens=m.opens, submitted=m.submitted, completed=m.completed,
            failures=m.failures, outstanding=m.outstanding,
            err_ewma=round(m.err_ewma, 6), glat_ewma=round(m.glat_ewma, 6),
            cost_integral=round(m.cost_integral, 6),
            est_samples=m.est_samples, dirty_windows=m.dirty_windows,
            dirty_samples=m.dirty_samples,
            refits=m.estimator.refits if m.estimator else 0,
        ) for m in st["members"]]

    def shared_resource(self, pilot: Pilot, name: str):
        for m in self._pilots[pilot.uid]["members"]:
            try:
                return m.backend.shared_resource(m.pilot, name)
            except LookupError:
                continue
        raise LookupError(f"no federation member exposes {name!r}")

    # -- teardown ------------------------------------------------------------
    def cancel_pilot(self, pilot: Pilot) -> None:
        for m in self._pilots[pilot.uid]["members"]:
            m.backend.cancel_pilot(m.pilot)
        pilot.state = State.CANCELED

    def drive_until(self, predicate, timeout: float | None = None) -> None:
        # all members share self.sim, so one run drives the federation
        self.sim.run_until(
            t=None if timeout is None else self.sim.now + timeout,
            predicate=predicate)
        if not predicate():
            raise TimeoutError("federated drive_until exhausted events/timeout")

    def close(self) -> None:
        for st in self._pilots.values():
            for m in st["members"]:
                m.backend.close()
        self._pilots.clear()


register_backend(FederatedBackend.scheme, FederatedBackend)
