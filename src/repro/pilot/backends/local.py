"""Local backend: real in-process execution on a thread pool (wall clock).

Used by the quickstart/serving examples and integration tests; it is the
"cloud VM / login node" analogue — no simulation, callables actually run.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor

from repro.pilot.api import Backend, ComputeUnit, Pilot, State, register_backend


class LocalBackend(Backend):
    scheme = "local"

    def __init__(self, **_kw) -> None:
        self._pools: dict[int, ThreadPoolExecutor] = {}
        self._cv = threading.Condition()

    def start_pilot(self, pilot: Pilot) -> None:
        workers = pilot.desc.concurrency or (
            pilot.desc.number_of_nodes * pilot.desc.cores_per_node)
        self._pools[pilot.uid] = ThreadPoolExecutor(max_workers=max(1, workers))
        pilot.state = State.RUNNING

    def submit(self, pilot: Pilot, cu: ComputeUnit) -> None:
        cu.submit_ts = time.perf_counter()
        cu.state = State.PENDING
        pool = self._pools[pilot.uid]

        def run() -> None:
            cu._set_running(time.perf_counter())
            try:
                out = cu.desc.func(*cu.desc.args, **cu.desc.kwargs) if cu.desc.func else None
                cu._set_done(time.perf_counter(), out)
            except BaseException as exc:  # noqa: BLE001 — report task failure
                cu._set_failed(time.perf_counter(), exc)
            with self._cv:
                self._cv.notify_all()

        pool.submit(run)

    def cancel_pilot(self, pilot: Pilot) -> None:
        pool = self._pools.pop(pilot.uid, None)
        if pool is not None:
            pool.shutdown(wait=False, cancel_futures=True)
        now = time.perf_counter()
        for cu in pilot.compute_units:
            if not cu.state.is_final:
                cu._set_canceled(now)

    def drive_until(self, predicate, timeout) -> None:
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._cv:
            while not predicate():
                remaining = None if deadline is None else deadline - time.perf_counter()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError("local backend drive_until timed out")
                self._cv.wait(timeout=remaining if remaining is not None else 0.2)

    def close(self) -> None:
        for pool in self._pools.values():
            pool.shutdown(wait=False, cancel_futures=True)


register_backend("local", LocalBackend)
