"""Local backend: real in-process execution on a thread pool (wall clock).

Used by the quickstart/serving examples, the wall-clock adaptation path and
integration tests; it is the "cloud VM / login node" analogue — no
simulation, callables actually run.

Elasticity: the pool's thread count is fixed at pilot start (the physical
ceiling, like a node's core count), but the *admitted* concurrency is a
capacity counter that ``scale_to`` moves live — tasks beyond the current
capacity queue on a condition variable until a slot frees or the capacity
grows.  Grants are immediate (``effective_allocation == allocation``): a
login node has no batch queue.  This is what lets the threaded streaming
engine's ``ControlLoop`` resize a wall-clock run the same way the simulated
backends resize virtual ones.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor

from repro.pilot.api import Backend, ComputeUnit, Pilot, State, register_backend


class LocalBackend(Backend):
    scheme = "local"

    def __init__(self, **_kw) -> None:
        self._pools: dict[int, ThreadPoolExecutor] = {}
        self._caps: dict[int, dict] = {}   # uid -> {capacity, running, ceiling}
        self._cv = threading.Condition()

    def start_pilot(self, pilot: Pilot) -> None:
        workers = pilot.desc.concurrency or (
            pilot.desc.number_of_nodes * pilot.desc.cores_per_node)
        workers = max(1, workers)
        self._pools[pilot.uid] = ThreadPoolExecutor(max_workers=workers)
        self._caps[pilot.uid] = {"capacity": workers, "running": 0,
                                 "ceiling": workers,
                                 "revoked": 0,       # preempted worker slots
                                 "crash_next": 0}    # injected crash budget
        pilot.state = State.RUNNING

    # -- elasticity ----------------------------------------------------------
    def scale_to(self, pilot: Pilot, n: int) -> int:
        """Move the admitted concurrency, clamped to [1, pool size]."""
        with self._cv:
            st = self._caps[pilot.uid]
            st["capacity"] = max(1, min(int(n), st["ceiling"]))
            self._cv.notify_all()
            return st["capacity"]

    def allocation(self, pilot: Pilot) -> int:
        with self._cv:
            return self._caps[pilot.uid]["capacity"]

    def effective_allocation(self, pilot: Pilot) -> int:
        """Admitted slots actually available: capacity minus slots revoked
        by an in-force preemption (never below 1, so the pipeline can
        still drain)."""
        with self._cv:
            st = self._caps[pilot.uid]
            return max(1, st["capacity"] - st["revoked"])

    # -- fault surface ---------------------------------------------------------
    def inject_crash(self, pilot: Pilot, count: int = 1) -> int:
        """Fail the next ``count`` task executions with ``ConnectionError``
        — the wall-clock analogue of a worker crash killing the in-flight
        batch (the consumer's retry path re-submits)."""
        with self._cv:
            self._caps[pilot.uid]["crash_next"] += int(count)
        return int(count)

    def preempt(self, pilot: Pilot, count: int = 1) -> int:
        """Spot-style revocation of admitted worker slots: capacity drops
        by up to ``count`` (always keeping one slot) and returns after
        ``preempt_restore_s`` (pilot attrs, default 2 s) on a timer
        thread.  In-flight tasks finish — the wall-clock pool cannot kill
        a running thread, so revocation bites at the admission gate, which
        is the same queueing semantics the sim backends express."""
        with self._cv:
            st = self._caps[pilot.uid]
            take = max(0, min(int(count),
                              st["capacity"] - st["revoked"] - 1))
            st["revoked"] += take
            self._cv.notify_all()
        if take:
            restore_s = float(pilot.desc.attrs.get("preempt_restore_s", 2.0))
            t = threading.Timer(restore_s, self._restore, args=(pilot, take))
            t.daemon = True
            t.start()
        return take

    def _restore(self, pilot: Pilot, n: int) -> None:
        with self._cv:
            st = self._caps.get(pilot.uid)
            if st is None:
                return
            st["revoked"] = max(0, st["revoked"] - n)
            self._cv.notify_all()

    def submit(self, pilot: Pilot, cu: ComputeUnit) -> None:
        cu.submit_ts = time.perf_counter()
        cu.state = State.PENDING
        pool = self._pools[pilot.uid]
        st = self._caps[pilot.uid]

        def run() -> None:
            with self._cv:
                while st["running"] >= max(1, st["capacity"] - st["revoked"]) \
                        and not cu.state.is_final:
                    self._cv.wait(0.1)
                if cu.state.is_final:       # canceled while queued
                    return
                st["running"] += 1
                crash = st["crash_next"] > 0
                if crash:
                    st["crash_next"] -= 1
            try:
                cu._set_running(time.perf_counter())
                try:
                    if crash:
                        raise ConnectionError("worker crashed (injected)")
                    out = cu.desc.func(*cu.desc.args, **cu.desc.kwargs) if cu.desc.func else None
                    cu._set_done(time.perf_counter(), out)
                except BaseException as exc:  # noqa: BLE001 — report task failure
                    cu._set_failed(time.perf_counter(), exc)
            finally:
                with self._cv:
                    st["running"] -= 1
                    self._cv.notify_all()

        pool.submit(run)

    def cancel_pilot(self, pilot: Pilot) -> None:
        pool = self._pools.pop(pilot.uid, None)
        if pool is not None:
            pool.shutdown(wait=False, cancel_futures=True)
        now = time.perf_counter()
        for cu in pilot.compute_units:
            if not cu.state.is_final:
                cu._set_canceled(now)
        with self._cv:
            self._cv.notify_all()

    def drive_until(self, predicate, timeout) -> None:
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._cv:
            while not predicate():
                remaining = None if deadline is None else deadline - time.perf_counter()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError("local backend drive_until timed out")
                self._cv.wait(timeout=remaining if remaining is not None else 0.2)

    def close(self) -> None:
        for pool in self._pools.values():
            pool.shutdown(wait=False, cancel_futures=True)
        with self._cv:
            self._cv.notify_all()


register_backend("local", LocalBackend)
