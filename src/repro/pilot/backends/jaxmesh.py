"""JAX mesh backend: pilots are *mesh-slice resource containers*.

TPU-native analogue of the paper's resource containers (DESIGN.md §2): a
pilot owns a contiguous slice of the available jax devices, exposed as a
``jax.sharding.Mesh`` whose shape/axes come from the PilotDescription.
Compute-units are jitted callables executed with the pilot's mesh installed;
elastic scaling = releasing the pilot and re-slicing.

On this CPU host there is a single device, so pilots degrade to a 1×1 mesh —
the full 256/512-chip meshes are exercised by ``launch/dryrun.py`` via
``ShapeDtypeStruct`` lowering (no allocation), per the assignment.
"""

from __future__ import annotations

import threading
import time

import jax
from jax.sharding import Mesh

from repro.pilot.api import Backend, ComputeUnit, Pilot, State, register_backend


class JaxMeshBackend(Backend):
    scheme = "jax"

    def __init__(self, devices=None, **_kw) -> None:
        self.devices = list(devices if devices is not None else jax.devices())
        self._allocated: dict[int, list] = {}
        self._cv = threading.Condition()

    # -- device accounting ----------------------------------------------------
    def _free_devices(self) -> list:
        used = {id(d) for devs in self._allocated.values() for d in devs}
        return [d for d in self.devices if id(d) not in used]

    def start_pilot(self, pilot: Pilot) -> None:
        import numpy as np

        shape = tuple(pilot.desc.attrs.get("mesh_shape", (1,)))
        axes = tuple(pilot.desc.attrs.get("mesh_axes", ("data",)))
        if len(shape) != len(axes):
            raise ValueError(f"mesh_shape {shape} / mesh_axes {axes} mismatch")
        n = int(np.prod(shape))
        free = self._free_devices()
        if n > len(free):
            raise RuntimeError(
                f"pilot wants {n} devices, only {len(free)} free of {len(self.devices)}")
        devs = free[:n]
        self._allocated[pilot.uid] = devs
        pilot.mesh = Mesh(np.asarray(devs, dtype=object).reshape(shape), axes)
        pilot.state = State.RUNNING

    def cancel_pilot(self, pilot: Pilot) -> None:
        self._allocated.pop(pilot.uid, None)
        now = time.perf_counter()
        for cu in pilot.compute_units:
            if not cu.state.is_final:
                cu._set_canceled(now)

    # -- execution: run under the pilot's mesh ---------------------------------
    def submit(self, pilot: Pilot, cu: ComputeUnit) -> None:
        cu.submit_ts = time.perf_counter()
        cu._set_running(time.perf_counter())
        try:
            with pilot.mesh:
                out = cu.desc.func(*cu.desc.args, **cu.desc.kwargs) if cu.desc.func else None
            cu._set_done(time.perf_counter(), out)
        except BaseException as exc:  # noqa: BLE001
            cu._set_failed(time.perf_counter(), exc)
        with self._cv:
            self._cv.notify_all()

    def drive_until(self, predicate, timeout) -> None:
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._cv:
            while not predicate():
                remaining = None if deadline is None else deadline - time.perf_counter()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError("jaxmesh drive_until timed out")
                self._cv.wait(timeout=remaining if remaining is not None else 0.1)


register_backend("jax", JaxMeshBackend)
