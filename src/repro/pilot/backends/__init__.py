# Importing this package registers all built-in backend plugins.
from repro.pilot.backends.local import LocalBackend
from repro.pilot.backends.serverless import ServerlessSimBackend
from repro.pilot.backends.hpcsim import HpcSimBackend
from repro.pilot.backends.jaxmesh import JaxMeshBackend

__all__ = ["LocalBackend", "ServerlessSimBackend", "HpcSimBackend", "JaxMeshBackend"]
