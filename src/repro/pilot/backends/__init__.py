# Importing this package registers all built-in backend plugins.
#
# The jax mesh backend is registered *lazily*: importing jax costs over a
# second of wall time, which used to land inside the first simulated cell's
# measurement (the serverless reference cell in perf_smoke paid ~1.1 s of
# jax import it never used).  The simulation backends import eagerly; the
# "jax" scheme resolves to a factory that imports jaxmesh on first use.
from repro.pilot.api import register_backend
from repro.pilot.backends.federated import FederatedBackend
from repro.pilot.backends.hpcsim import HpcSimBackend
from repro.pilot.backends.local import LocalBackend
from repro.pilot.backends.serverless import ServerlessSimBackend

__all__ = ["LocalBackend", "ServerlessSimBackend", "HpcSimBackend",
           "FederatedBackend", "JaxMeshBackend"]


def _jaxmesh_factory(**kwargs):
    from repro.pilot.backends.jaxmesh import JaxMeshBackend
    return JaxMeshBackend(**kwargs)


register_backend("jax", _jaxmesh_factory)


def __getattr__(name):
    if name == "JaxMeshBackend":
        from repro.pilot.backends.jaxmesh import JaxMeshBackend
        return JaxMeshBackend
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
