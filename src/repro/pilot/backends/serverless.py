"""Serverless (AWS Lambda + Kinesis) mechanism simulation backend.

Reproduces, on a virtual clock, the Lambda execution mechanics the paper
measures (§IV-B1, Figs 3–6):

* **CPU ∝ memory** — "AWS scales the CPU allotment proportional to the
  memory": ``cpu_share = memory_mb / 1792`` vCPUs, memory capped at
  3,008 MB (the 2019 limit the paper cites).
* **Concurrency** — AWS never starts more containers than Kinesis
  partitions; the paper observed at most 30 concurrent containers.  We model
  a container pool of ``min(partitions, max_containers=30)``.
* **Cold starts** — first invocation on a fresh container pays a start
  penalty; containers are reused (warm) afterwards.
* **Walltime** — tasks exceeding the 15-minute limit are killed (FAILED).
* **Isolation** — each container has a *private* CPU and S3 bandwidth
  share; there is no cross-container shared resource.  This is what makes
  sigma, kappa ≈ 0 emerge in the USL fit (paper Fig 6, "Lambda containers
  are well isolated").
* **Jitter** — run-to-run fluctuation shrinks with container size
  (paper Fig 3); modeled as lognormal noise with cv ∝ 1/memory.

Service-time model for a task with profile p on a container with memory m:

    t = cold_start?                     (once per container)
      + p.msg_bytes / net_bw            (broker → container transfer)
      + p.flops / (cpu_share(m) * FLOPS_PER_VCPU)
      + (p.read_bytes + p.write_bytes) / s3_bw + 2 * s3_latency
      + coherence: p.coherence_peers * (s3_latency + peer_delta/s3_bw)

All constants are overridable via PilotDescription.attrs.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass

from repro.pilot.api import Backend, ComputeUnit, Pilot, State, TaskProfile, register_backend
from repro.sim.des import Simulator

# Calibration constants (overridable via attrs). FLOPS_PER_VCPU is an
# effective numpy-workload rate, not peak.
DEFAULTS = dict(
    flops_per_vcpu=2.4e9,
    mb_per_vcpu=1792.0,
    memory_cap_mb=3008.0,
    max_containers=30,
    cold_start_s=0.35,
    net_bw=100e6,          # broker->container, bytes/s (per container)
    s3_bw=85e6,            # S3 per-connection bandwidth, bytes/s
    s3_latency=0.018,      # per S3 request, s
    jitter_cv_ref=0.03,    # cv at memory_cap; cv = ref * cap/memory
    invoke_overhead_s=0.002,
    preempt_restore_s=30.0,  # spot capacity returns after this delay
)


def service_time_mean(cfg: dict, memory_mb: float, profile: TaskProfile,
                      cold: bool) -> tuple[float, float]:
    """Deterministic Lambda service-time model: ``(mean_s, jitter_cv)``.

    Pure function of the calibration constants, container memory, task
    profile and cold flag — the stochastic part (one lognormal draw around
    ``mean_s`` with ``jitter_cv``) stays with the caller's simulator.
    Shared between ``ServerlessSimBackend.service_time`` and the what-if
    fast replay (``sim.batched``), so both paths run the *same* float
    arithmetic in the same order: bit-agreement between them is by
    construction, not by parallel maintenance.
    """
    m = min(memory_mb, cfg["memory_cap_mb"])
    cpu_share = m / cfg["mb_per_vcpu"]
    t = cfg["invoke_overhead_s"]
    if cold:
        t += cfg["cold_start_s"]
    t += profile.msg_bytes / cfg["net_bw"]
    # serial_flops run lock-free here: S3 model sharing is last-writer-
    # wins (no consistent read-modify-write), the paper's "better
    # resource isolation" on Lambda.
    t += (profile.flops + profile.serial_flops) / (cpu_share * cfg["flops_per_vcpu"])
    io_bytes = profile.read_bytes + profile.write_bytes
    if io_bytes > 0:
        t += io_bytes / cfg["s3_bw"] + 2 * cfg["s3_latency"]
    if profile.coherence_peers > 0:
        # state is externalized: peers' deltas fetched from S3 —
        # isolated per-container bandwidth, so cost is linear in peers
        # with a small constant (no shared medium -> tiny kappa).
        delta = max(profile.write_bytes, 1.0) * 0.05
        t += profile.coherence_peers * (cfg["s3_latency"] * 0.1 + delta / cfg["s3_bw"])
    cv = cfg["jitter_cv_ref"] * (cfg["memory_cap_mb"] / m)
    return t, cv


@dataclass
class _Container:
    cid: int
    warm: bool = False
    busy: bool = False
    dead: bool = False                  # crashed/preempted: finish is void
    cu: ComputeUnit | None = None       # in-flight invocation, if busy


class ServerlessSimBackend(Backend):
    scheme = "serverless"

    def __init__(self, sim: Simulator | None = None, seed: int = 0, **_kw) -> None:
        self.sim = sim or Simulator(seed=seed)
        self._pilots: dict[int, dict] = {}

    # -- pilot lifecycle -----------------------------------------------------
    def start_pilot(self, pilot: Pilot) -> None:
        cfg = dict(DEFAULTS)
        cfg.update(pilot.desc.attrs)
        n_containers = min(
            pilot.desc.concurrency or pilot.desc.partitions,
            int(cfg["max_containers"]),
        )
        containers = [_Container(i) for i in range(max(1, n_containers))]
        self._pilots[pilot.uid] = {
            "cfg": cfg,
            "containers": containers,
            # idle pool: popleft/appendleft beats rescanning every
            # container's busy flag per dispatch.  Seeded in cid order
            # (first-round cold starts match the scan it replaces) and
            # freed containers return to the HEAD, so the most recently
            # warmed container is reused first — sequential demand pays
            # one cold start, like the lowest-cid scan did, instead of
            # round-robining the whole pool cold
            "free": deque(containers),
            "queue": deque(),
            "target": len(containers),
            "next_cid": len(containers),
        }
        pilot.state = State.RUNNING

    # -- elasticity ----------------------------------------------------------
    def scale_to(self, pilot: Pilot, n: int) -> int:
        """Elastic concurrency: grow the container pool with *fresh* (cold)
        containers, shrink by retiring idle ones immediately and busy ones
        as they finish.  New containers pay ``cold_start_s`` on their first
        invocation — the per-container scale-up price the control loop's
        cost/SLO traces must account for.  Clamped to [1, max_containers]."""
        st = self._pilots[pilot.uid]
        n = max(1, min(int(n), int(st["cfg"]["max_containers"])))
        st["target"] = n
        containers, free = st["containers"], st["free"]
        # shrink: retire from the TAIL of the free pool (the coldest end —
        # recently warmed containers at the head keep serving)
        while len(containers) > n and free:
            containers.remove(free.pop())
        # grow: fresh containers join cold; they warm on first use
        while len(containers) < n:
            c = _Container(st["next_cid"])
            st["next_cid"] += 1
            containers.append(c)
            free.append(c)
        self._dispatch(pilot)
        return n

    def allocation(self, pilot: Pilot) -> int:
        return self._pilots[pilot.uid]["target"]

    def effective_allocation(self, pilot: Pilot) -> int:
        """Containers that exist right now: growth is instant (fresh
        containers are usable immediately, merely cold), but a shrink's
        busy containers linger until their in-flight task finishes."""
        return len(self._pilots[pilot.uid]["containers"])

    def cancel_pilot(self, pilot: Pilot) -> None:
        st = self._pilots.get(pilot.uid)
        if st:
            st["queue"].clear()
        for cu in pilot.compute_units:
            if not cu.state.is_final:
                cu._set_canceled(self.sim.now)

    # -- fault surface ---------------------------------------------------------
    def _kill(self, st: dict, container: _Container, why: str) -> None:
        """Remove one container; its in-flight invocation (if any) fails
        with ``ConnectionError`` so the engine's unpinned retry path takes
        over.  The pending ``finish`` event is voided by the dead flag."""
        container.dead = True
        st["containers"].remove(container)
        if container in st["free"]:
            st["free"].remove(container)
        cu = container.cu
        container.cu = None
        if cu is not None and not cu.state.is_final:
            cu._set_failed(self.sim.now,
                           ConnectionError(f"container {container.cid} {why}"))

    def inject_crash(self, pilot: Pilot, count: int = 1) -> int:
        """Crash up to ``count`` containers (busy first — a crash that hits
        nothing is a non-event): the invocation fails and Lambda restarts
        the sandbox immediately, so a fresh *cold* replacement joins the
        pool at once — the crash costs a retry plus a cold start, not
        capacity."""
        st = self._pilots[pilot.uid]
        victims = [c for c in st["containers"] if c.busy][:count]
        if len(victims) < count:
            victims += [c for c in st["containers"]
                        if not c.busy][:count - len(victims)]
        for c in victims:
            self._kill(st, c, "crashed")
            fresh = _Container(st["next_cid"])
            st["next_cid"] += 1
            st["containers"].append(fresh)
            st["free"].append(fresh)
        if victims:
            self._dispatch(pilot)
        return len(victims)

    def preempt(self, pilot: Pilot, count: int = 1) -> int:
        """Spot reclamation: revoke up to ``count`` live containers (newest
        idle first, then busy ones — in-flight work fails like a crash).
        Unlike a crash the capacity is *gone*: ``effective_allocation``
        dips until fresh cold containers restore the pool toward target
        after ``preempt_restore_s``."""
        st = self._pilots[pilot.uid]
        containers = st["containers"]
        idle = [c for c in reversed(containers) if not c.busy]
        busy = [c for c in reversed(containers) if c.busy]
        victims = (idle + busy)[:count]
        for c in victims:
            self._kill(st, c, "preempted")
        n = len(victims)
        if n:
            self.sim.schedule_fast(float(st["cfg"]["preempt_restore_s"]),
                                   lambda: self._restore_preempted(pilot, n))
        return n

    def _restore_preempted(self, pilot: Pilot, n: int) -> None:
        st = self._pilots.get(pilot.uid)
        if st is None:
            return
        restored = 0
        while restored < n and len(st["containers"]) < st["target"]:
            c = _Container(st["next_cid"])
            st["next_cid"] += 1
            st["containers"].append(c)
            st["free"].append(c)
            restored += 1
        if restored:
            self._dispatch(pilot)

    # -- execution -------------------------------------------------------------
    def submit(self, pilot: Pilot, cu: ComputeUnit) -> None:
        cu.submit_ts = self.sim.now
        cu.state = State.PENDING
        st = self._pilots[pilot.uid]
        st["queue"].append(cu)
        # dispatch synchronously: invocation latency is modeled inside
        # service_time (invoke_overhead_s), so the zero-delay hop event the
        # seed scheduled here bought nothing but heap traffic.  Completion
        # is always a future event, so callers attach done-callbacks before
        # any completion can fire.
        self._dispatch(pilot)

    def _dispatch(self, pilot: Pilot) -> None:
        st = self._pilots[pilot.uid]
        queue, free_pool = st["queue"], st["free"]
        while queue:
            if not free_pool:
                return
            cu = queue.popleft()
            if cu.state.is_final:
                continue
            self._start(pilot, cu, free_pool.popleft())

    def service_time(self, cfg: dict, memory_mb: float, profile: TaskProfile,
                     cold: bool) -> float:
        t, cv = service_time_mean(cfg, memory_mb, profile, cold)
        return self.sim.lognormal_jitter(t, cv)

    def _start(self, pilot: Pilot, cu: ComputeUnit, container: _Container) -> None:
        st = self._pilots[pilot.uid]
        cfg = st["cfg"]
        profile = cu.desc.profile or TaskProfile()
        if profile.memory_mb > min(pilot.desc.memory_mb, cfg["memory_cap_mb"]):
            st["free"].appendleft(container)   # never started: back in the pool
            cu._set_failed(self.sim.now, MemoryError(
                f"task working set {profile.memory_mb} MB exceeds container "
                f"{pilot.desc.memory_mb} MB"))
            return
        container.busy = True
        container.cu = cu
        cold = not container.warm
        container.warm = True
        cu._set_running(self.sim.now)
        cu.attrs = {"container": container.cid, "cold": cold}
        dt = self.service_time(cfg, pilot.desc.memory_mb, profile, cold)

        def finish() -> None:
            if container.dead:
                return     # crashed/preempted mid-flight: already failed
            container.busy = False
            container.cu = None
            if len(st["containers"]) > st["target"]:
                # a scale-down landed while this container was busy: retire
                # it now instead of returning it to the pool
                st["containers"].remove(container)
            else:
                st["free"].appendleft(container)
            if dt > pilot.desc.walltime_s:
                cu._set_failed(self.sim.now, TimeoutError(
                    f"walltime {pilot.desc.walltime_s}s exceeded (needed {dt:.1f}s)"))
            else:
                result = None
                if cu.desc.func is not None:
                    try:
                        result = cu.desc.func(*cu.desc.args, **cu.desc.kwargs)
                    except BaseException as exc:  # noqa: BLE001
                        cu._set_failed(self.sim.now, exc)
                        self._dispatch(pilot)
                        return
                cu._set_done(self.sim.now, result)
            self._dispatch(pilot)

        self.sim.schedule_fast(min(dt, pilot.desc.walltime_s), finish)

    def drive_until(self, predicate, timeout) -> None:
        self.sim.run_until(t=None if timeout is None else self.sim.now + timeout,
                           predicate=predicate)
        if not predicate():
            raise TimeoutError("serverless sim drive_until exhausted events/timeout")


register_backend("serverless", ServerlessSimBackend)
