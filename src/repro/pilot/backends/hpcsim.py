"""HPC (Kafka + Dask on Wrangler / Stampede2) mechanism simulation backend.

Reproduces, on a virtual clock, the mechanisms the paper identifies as the
cause of HPC streaming-scalability limits (§IV-C):

* **Shared filesystem (Lustre)** — data production, brokering *and*
  processing all use the shared filesystem.  Modeled as a processor-sharing
  resource: aggregate bandwidth split across all concurrent flows.  More
  partitions → more concurrent flows → per-flow bandwidth drops → the
  *contention* (sigma) the USL fit recovers.
* **Coherence** — the K-Means model is shared across tasks via the shared
  filesystem; each task reads every peer's model delta, so coherence traffic
  grows with (N-1) per task — N(N-1) system-wide — *and* rides the shared
  medium.  This is the kappa term ("synchronization of the model updates via
  the shared filesystem").
* **Serial scheduler** — Dask's single scheduler dispatches tasks serially;
  a fixed per-task dispatch cost bounds the parallel fraction.
* **Faster cores, better absolute performance** — HPC cores beat a Lambda
  vCPU slice; the paper's "HPC provides better absolute performance" at
  small N comes from this, while degradation at larger N comes from the
  shared resources above.

Machines (paper §IV-B): wrangler = 48 cores/128 GB nodes; stampede2 = 68-core
KNL/96 GB (slower per-core).  Select via resource URL ``hpc://wrangler-sim``.

The backend also supports **failure injection** (``kill_worker``) used by the
fault-tolerance tests: the running task fails, the worker leaves the pool,
and the streaming engine re-dispatches.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.pilot.api import Backend, ComputeUnit, Pilot, State, TaskProfile, register_backend
from repro.sim.des import SharedResource, SimLock, Simulator

MACHINES = {
    "wrangler": dict(cores_per_node=48, mem_per_node_gb=128, flops_per_core=5.2e9,
                     fs_bw=950e6),
    "stampede2": dict(cores_per_node=68, mem_per_node_gb=96, flops_per_core=2.6e9,
                      fs_bw=1200e6),
}

DEFAULTS = dict(
    dispatch_s=0.0015,      # serial Dask scheduler cost per task
    coherence_delta_frac=1.0,   # peers' full model deltas are read back
    fs_meta_latency=0.008,  # Lustre metadata/open cost per peer file
    jitter_cv=0.08,         # shared-environment noise
    net_bw=1.1e9,           # node NIC, bytes/s (per flow, before FS sharing)
    grant_delay_s=10.0,     # scheduler queue wait before a grown worker runs
    # Empirical batch-queue wait distribution (log-normal).  When p50/p95
    # are set (p95 > p50 > 0) every grant — elastic growth, crash restart,
    # preemption re-queue — waits out a seeded log-normal sample shaped by
    # those quantiles; unset, the wait is degenerate at grant_delay_s (the
    # flat calibrated delay the fig8 tuning was built on).
    queue_wait_p50_s=None,
    queue_wait_p95_s=None,
)

_Z95 = 1.6448536269514722   # standard-normal 95th percentile


def coupling_terms(cfg: dict, profile: TaskProfile) -> tuple[float, float, float, float]:
    """The per-task coupling terms of the processor-sharing model, as a
    pure function of ``(cfg, profile)``.

    Returns ``(arrival_io_bytes, compute_mean_s, critical_mean_s,
    write_io_bytes)`` — exactly the four quantities the ``_TaskExec``
    phase chain feeds into the shared filesystem and the model lock:

    * ``arrival_io_bytes`` — message pull + model read on the shared FS,
    * ``compute_mean_s`` — the parallel distance phase (private cores),
    * ``critical_mean_s`` — the model-merge critical section: per-peer
      metadata opens plus the serial merge (the sigma/kappa source),
    * ``write_io_bytes`` — model write-back plus the (N-1)-growing
      coherence delta traffic, all riding the shared FS.

    The backend's task chain and the fast replay (``sim.batched``) both
    consume this function, so the coupled service-time chain the replay
    builds is bit-identical to the scalar DES by construction.
    """
    n_peers = profile.coherence_peers
    arrival_io = profile.msg_bytes + profile.read_bytes
    compute_mean = profile.flops / cfg["flops_per_core"]
    critical_mean = (n_peers * cfg["fs_meta_latency"]
                     + profile.serial_flops / cfg["flops_per_core"])
    write_io = profile.write_bytes + (n_peers * max(profile.write_bytes, 1.0)
                                      * cfg["coherence_delta_frac"])
    return arrival_io, compute_mean, critical_mean, write_io


def queue_wait_sample(cfg: dict, rng: np.random.Generator) -> float:
    """One batch-queue wait sample, seconds — pure given ``(cfg, rng)``.

    Default: degenerate at ``grant_delay_s`` — the flat calibrated wait.
    Setting ``queue_wait_p50_s``/``queue_wait_p95_s`` switches to the
    seeded log-normal those quantiles imply (mu = ln p50, sigma =
    ln(p95/p50)/z95) — the empirical heavy-tailed batch-queue shape.
    The backend and the fast replay draw from identically-seeded
    per-pilot streams (``default_rng([seed, uid])``), so grant schedules
    match bit-for-bit.
    """
    p50 = cfg.get("queue_wait_p50_s")
    if p50 is None:
        p50 = cfg["grant_delay_s"]
    p95 = cfg.get("queue_wait_p95_s")
    if p95 is None or p50 <= 0.0 or p95 <= p50:
        return float(p50)
    mu = math.log(p50)
    sigma = math.log(p95 / p50) / _Z95
    return float(rng.lognormal(mu, sigma))


@dataclass
class _Worker:
    wid: int
    busy: bool = False
    alive: bool = True
    pending: bool = False   # granted? elastic growth waits out the queue
    retired: bool = False   # released back to the scheduler by a scale-down
    queue: deque = field(default_factory=deque)


class HpcSimBackend(Backend):
    scheme = "hpc"

    def __init__(self, sim: Simulator | None = None, seed: int = 0, **_kw) -> None:
        self.sim = sim or Simulator(seed=seed)
        self._seed = seed
        self._pilots: dict[int, dict] = {}

    def start_pilot(self, pilot: Pilot) -> None:
        machine = pilot.desc.resource.split("://", 1)[1].replace("-sim", "") or "wrangler"
        if machine not in MACHINES:
            raise ValueError(f"unknown HPC machine '{machine}'; known: {sorted(MACHINES)}")
        cfg = dict(DEFAULTS)
        cfg.update(MACHINES[machine])
        cfg.update(pilot.desc.attrs)
        n_workers = pilot.desc.partitions
        self._pilots[pilot.uid] = {
            "cfg": cfg,
            "machine": machine,
            "workers": [_Worker(i) for i in range(max(1, n_workers))],
            "fs": SharedResource(self.sim, cfg["fs_bw"], name="lustre"),
            "model_lock": SimLock(self.sim, name="model"),
            "sched_queue": deque(),
            "sched_busy": False,
            "rr": 0,
            "target": max(1, n_workers),
            "mapping": None,     # cached non-retired worker list
            # dedicated queue-wait stream: decoupled from the service-time
            # jitter stream so enabling the empirical wait distribution
            # cannot perturb unrelated draws (per-pilot, seeded)
            "queue_rng": np.random.default_rng([self._seed, pilot.uid]),
        }
        pilot.state = State.RUNNING

    def _queue_wait(self, st: dict) -> float:
        """One batch-queue wait sample from the pilot's dedicated stream
        (see ``queue_wait_sample`` — the pure sampler shared with the
        fast replay)."""
        return queue_wait_sample(st["cfg"], st["queue_rng"])

    # -- elasticity ----------------------------------------------------------
    def _mapping(self, st: dict) -> list[_Worker]:
        """Non-retired workers, in wid order — the partition → worker map.
        Dead (killed) workers stay in the map so pinned dispatch to them
        keeps failing fast (the engine's unpin-and-retry path owns that)."""
        m = st["mapping"]
        if m is None:
            m = st["mapping"] = [w for w in st["workers"] if not w.retired]
        return m

    def scale_to(self, pilot: Pilot, n: int) -> int:
        """Elastic worker pool with HPC semantics: growth submits new
        workers to the batch scheduler and they only start accepting work
        after ``grant_delay_s`` (queue wait + node grant); work pinned to a
        not-yet-granted worker queues on it and waits the grant out.
        Shrink releases the most recently granted workers back to the
        scheduler: running tasks finish, queued ones are reassigned under
        the new mapping."""
        st = self._pilots[pilot.uid]
        n = max(1, int(n))
        st["target"] = n
        workers = st["workers"]
        active = [w for w in workers if not w.retired]
        if n > len(active):
            for _ in range(n - len(active)):
                w = _Worker(len(workers), pending=True)
                workers.append(w)

                def grant(w: _Worker = w) -> None:
                    w.pending = False
                    self._pump_worker(pilot, w)

                self.sim.schedule_fast(self._queue_wait(st), grant)
        elif n < len(active):
            victims = active[n:]
            for w in victims:
                w.retired = True
            st["mapping"] = None
            for w in victims:
                orphans = [cu for cu in w.queue if not cu.state.is_final]
                w.queue.clear()
                for cu in orphans:
                    self._assign(pilot, cu)
        st["mapping"] = None
        return n

    def allocation(self, pilot: Pilot) -> int:
        return self._pilots[pilot.uid]["target"]

    def effective_allocation(self, pilot: Pilot) -> int:
        """Workers granted by the batch scheduler: grown workers still in
        the queue (``pending``) don't count until ``grant_delay_s``
        elapses — the window where the target runs ahead of reality and a
        capacity observation must not be credited to the target N."""
        return sum(1 for w in self._pilots[pilot.uid]["workers"]
                   if not w.retired and not w.pending)

    def cancel_pilot(self, pilot: Pilot) -> None:
        st = self._pilots.get(pilot.uid)
        if st:
            st["sched_queue"].clear()
            for w in st["workers"]:
                w.queue.clear()
        for cu in pilot.compute_units:
            if not cu.state.is_final:
                cu._set_canceled(self.sim.now)

    _SHARED_RESOURCES = ("fs", "model_lock")

    def shared_resource(self, pilot: Pilot, name: str):
        """Public accessor for the pilot's shared infrastructure: ``"fs"``
        (the Lustre ``SharedResource``) or ``"model_lock"`` (the shared-model
        ``SimLock``)."""
        if name not in self._SHARED_RESOURCES:
            raise LookupError(
                f"hpc backend exposes {self._SHARED_RESOURCES}, not {name!r}")
        return self._pilots[pilot.uid][name]

    # -- failure injection ------------------------------------------------
    def kill_worker(self, pilot: Pilot, wid: int) -> list[ComputeUnit]:
        """Simulate a node failure: fail the running CU, drop queued ones."""
        st = self._pilots[pilot.uid]
        w = st["workers"][wid]
        w.alive = False
        orphans = []
        for cu in pilot.compute_units:
            if getattr(cu, "attrs", {}).get("worker") == wid and not cu.state.is_final:
                cu._set_failed(self.sim.now, ConnectionError(f"worker {wid} died"))
                orphans.append(cu)
        orphans.extend(w.queue)
        for cu in list(w.queue):
            if not cu.state.is_final:
                cu._set_failed(self.sim.now, ConnectionError(f"worker {wid} died (queued)"))
        w.queue.clear()
        return orphans

    def _evict(self, pilot: Pilot, st: dict, w: _Worker, why: str) -> None:
        """Evict one granted worker back into the batch queue: the running
        CU fails with ``ConnectionError`` (the engine's unpinned retry path
        re-dispatches), queued work is reassigned under the current
        mapping, and the worker re-grants after a fresh queue-wait
        sample."""
        w.pending = True
        for cu in pilot.compute_units:
            if not cu.state.is_final \
                    and cu.attrs.get("worker") == w.wid \
                    and cu.state == State.RUNNING:
                cu._set_failed(self.sim.now,
                               ConnectionError(f"worker {w.wid} {why}"))
        orphans = [cu for cu in w.queue if not cu.state.is_final]
        w.queue.clear()

        def regrant(w: _Worker = w) -> None:
            w.pending = False
            self._pump_worker(pilot, w)

        self.sim.schedule_fast(self._queue_wait(st), regrant)
        for cu in orphans:
            self._assign(pilot, cu)

    def inject_crash(self, pilot: Pilot, count: int = 1) -> int:
        """Node crash with restart-through-the-queue semantics (busy
        workers first): the running CU fails, queued work is reassigned,
        and the node re-enters the batch queue — re-granted only after a
        fresh queue-wait sample, unlike serverless's instant sandbox
        restart."""
        st = self._pilots[pilot.uid]
        granted = [w for w in st["workers"]
                   if w.alive and not w.retired and not w.pending]
        busy = [w for w in granted if w.busy]
        idle = [w for w in granted if not w.busy]
        victims = (busy + idle)[:count]
        for w in victims:
            self._evict(pilot, st, w, "crashed")
        return len(victims)

    def preempt(self, pilot: Pilot, count: int = 1) -> int:
        """Spot-style eviction of granted workers back into the batch
        queue, most recently granted first: running work fails, queued
        work is reassigned, and the evicted workers wait out a fresh
        queue-wait sample — during which ``effective_allocation`` dips
        below target (the signal the control loop's granted==target
        gating keys on)."""
        st = self._pilots[pilot.uid]
        granted = [w for w in st["workers"]
                   if w.alive and not w.retired and not w.pending]
        victims = granted[-count:] if count > 0 else []
        for w in victims:
            self._evict(pilot, st, w, "preempted")
        return len(victims)

    # -- scheduling: serial dispatcher --------------------------------------
    def submit(self, pilot: Pilot, cu: ComputeUnit) -> None:
        cu.submit_ts = self.sim.now
        cu.state = State.PENDING
        st = self._pilots[pilot.uid]
        st["sched_queue"].append(cu)
        self._pump_scheduler(pilot)

    def _pump_scheduler(self, pilot: Pilot) -> None:
        st = self._pilots[pilot.uid]
        if st["sched_busy"] or not st["sched_queue"]:
            return
        st["sched_busy"] = True
        cu = st["sched_queue"].popleft()

        def dispatched() -> None:
            st["sched_busy"] = False
            if not cu.state.is_final:
                self._assign(pilot, cu)
            self._pump_scheduler(pilot)

        self.sim.schedule_fast(st["cfg"]["dispatch_s"], dispatched)

    def _assign(self, pilot: Pilot, cu: ComputeUnit) -> None:
        st = self._pilots[pilot.uid]
        mapping = self._mapping(st)
        if cu.desc.partition is not None:
            # pinned: modulo over the non-retired mapping (identical to the
            # raw worker list until the first elastic scale-down)
            w = mapping[cu.desc.partition % len(mapping)]
            if not w.alive:
                cu._set_failed(self.sim.now, ConnectionError(
                    f"worker {w.wid} for partition {cu.desc.partition} is dead"))
                return
        else:
            alive = [w for w in mapping if w.alive]
            if not alive:
                cu._set_failed(self.sim.now, ConnectionError("no alive workers"))
                return
            # not-yet-granted workers rank last: queueing real work on a
            # node still in the batch queue only helps if everyone else is
            # loaded deeper than the grant delay is long
            w = min(alive, key=lambda w: (w.pending,
                                          len(w.queue) + (1 if w.busy else 0),
                                          w.wid))
        w.queue.append(cu)
        self._pump_worker(pilot, w)

    # -- worker execution: compute + shared-FS I/O + coherence -----------------
    def _pump_worker(self, pilot: Pilot, w: _Worker) -> None:
        if w.busy or w.pending or not w.queue or not w.alive:
            return
        cu = w.queue.popleft()
        if cu.state.is_final:
            self._pump_worker(pilot, w)
            return
        st = self._pilots[pilot.uid]
        w.busy = True
        cu._set_running(self.sim.now)
        cu.attrs = {"worker": w.wid}
        # phase 1: pull message from the broker log (shared FS resident) and
        #          read the current model from the shared FS
        # phase 2: parallel compute — the distance phase (private cores)
        # phase 3: model read-modify-write CRITICAL SECTION on the shared
        #          model file: acquire the global lock, read every peer's
        #          delta (coherence — metadata + bytes, both on the shared
        #          FS), merge (serial_flops), write back, release.
        #          Constant lock-hold → sigma; (N-1)-growing hold → kappa.
        task = _TaskExec(self, pilot, w, cu, st)
        st["fs"].submit(task.arrival_io, task.phase_compute)

    def drive_until(self, predicate, timeout) -> None:
        self.sim.run_until(t=None if timeout is None else self.sim.now + timeout,
                           predicate=predicate)
        if not predicate():
            raise TimeoutError("hpc sim drive_until exhausted events/timeout")


class _TaskExec:
    """Per-task phase chain, one ``__slots__`` object with bound-method
    continuations instead of a fresh stack of closures per task (the
    mini-app pushes hundreds of tasks per cell through this path)."""

    __slots__ = ("backend", "pilot", "w", "cu", "st", "cfg",
                 "arrival_io", "compute_mean", "critical_mean", "write_io")

    def __init__(self, backend: HpcSimBackend, pilot: Pilot, w: _Worker,
                 cu: ComputeUnit, st: dict) -> None:
        self.backend = backend
        self.pilot = pilot
        self.w = w
        self.cu = cu
        self.st = st
        self.cfg = st["cfg"]
        p = cu.desc.profile or TaskProfile()
        (self.arrival_io, self.compute_mean,
         self.critical_mean, self.write_io) = coupling_terms(self.cfg, p)

    def phase_compute(self) -> None:
        sim = self.backend.sim
        sim.schedule_fast(sim.lognormal_jitter(self.compute_mean,
                                               self.cfg["jitter_cv"]),
                          self.phase_model_update)

    def phase_model_update(self) -> None:
        self.st["model_lock"].acquire(self.in_critical_section)

    def in_critical_section(self) -> None:
        sim = self.backend.sim
        sim.schedule_fast(sim.lognormal_jitter(self.critical_mean,
                                               self.cfg["jitter_cv"]),
                          self.do_io)

    def do_io(self) -> None:
        self.st["fs"].submit(self.write_io, self.unlock)

    def unlock(self) -> None:
        self.st["model_lock"].release()
        self.finish()

    def finish(self) -> None:
        backend, w, cu = self.backend, self.w, self.cu
        if not w.alive:
            return  # kill_worker already failed the CU
        w.busy = False
        if not cu.state.is_final:
            result = None
            if cu.desc.func is not None:
                try:
                    result = cu.desc.func(*cu.desc.args, **cu.desc.kwargs)
                except BaseException as exc:  # noqa: BLE001
                    cu._set_failed(backend.sim.now, exc)
                    backend._pump_worker(self.pilot, w)
                    return
            cu._set_done(backend.sim.now, result)
        backend._pump_worker(self.pilot, w)


register_backend("hpc", HpcSimBackend)
