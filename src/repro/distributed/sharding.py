"""Logical-axis sharding: one rule table maps model-space axis names to mesh
axes (GSPMD style, as in MaxText/T5X).

Model code annotates tensors with *logical* axes (``batch``, ``seq``,
``heads``, ``ff``, ``experts``, ``vocab`` ...); the active ``Rules``
(a contextvar, installed by the launcher/dry-run around tracing) resolve
them to mesh axes.  With no rules installed every annotation is a no-op, so
the same model code runs single-device CPU tests and 512-chip dry-runs.

Meshes (launch/mesh.py): single-pod ``(16,16) = ("data","model")``;
multi-pod ``(2,16,16) = ("pod","data","model")``.  ``batch`` maps to
``("pod","data")`` so the pod axis shards the global batch across pods.
"""

from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["Rules", "DEFAULT_RULES", "use_rules", "current_rules", "constrain",
           "logical_to_pspec", "named_sharding", "shard_map"]


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-skew compat wrapper around jax's shard_map.

    Newer jax exports ``jax.shard_map`` (replication checking controlled by
    ``check_vma``); older releases only ship
    ``jax.experimental.shard_map.shard_map`` with the same knob spelled
    ``check_rep``.  All manual-collective call sites (MoE expert
    parallelism) go through this wrapper so a single interpreter can run
    either API generation.
    """
    impl = getattr(jax, "shard_map", None)
    if impl is not None:
        return impl(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy_impl
    return legacy_impl(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_rep=check_vma)


@dataclass
class Rules:
    """logical axis -> mesh axis (or tuple of mesh axes, or None).

    ``mesh`` (optional) carries the concrete mesh for modules that need
    explicit shard_map control (MoE expert parallelism).
    """

    table: dict = field(default_factory=dict)
    mesh: object = None

    def resolve(self, logical: str | None):
        if logical is None:
            return None
        return self.table.get(logical)

    def pspec(self, logical_axes: tuple) -> P:
        return P(*[self.resolve(a) for a in logical_axes])


def make_default_rules(multi_pod: bool = False, *, seq_shard: bool = False) -> Rules:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    table = {
        "batch": batch_axes if len(batch_axes) > 1 else batch_axes[0],
        "batch_noshard": None,          # long-context B=1 cells
        "seq": "model" if seq_shard else None,  # SP for activations between blocks
        "act_seq": "model",             # residual-stream seq sharding (activation ZeRO)
        "embed": None,                  # d_model replicated
        "heads": "model",               # attention head dim (padded if uneven)
        "kv_heads": None,               # few KV heads (<=8) -> replicate
        "kv_seq": "model",              # decode KV-cache sequence sharding
        "ff": "model",                  # MLP hidden TP axis
        "experts": "model",             # expert parallelism
        "vocab": "model",               # embedding/logits TP
        "embed_tbl": "model",           # untied input table: d_model-sharded
        "opt": batch_axes if len(batch_axes) > 1 else batch_axes[0],  # ZeRO axis
        "fsdp": "data",                 # ZeRO-3 weight sharding (MoE experts)
        "ssm_inner": None,              # Mamba-2 runs pure-DP (see DESIGN.md)
        "lru": "model",                 # RG-LRU width TP
        # activation-side TP axes (split from the weight axes so policies
        # like FSDP can unshard activations while weights stay sharded)
        "act_heads": "model",
        "act_kv": None,
        "act_ff": "model",
        "act_vocab": "model",
        "act_lru": "model",
    }
    return Rules(table)


def make_fsdp_rules(multi_pod: bool = False, ep: bool = False) -> Rules:
    """ZeRO-3/FSDP policy (§Perf iteration 2): the batch shards over BOTH
    mesh axes (B_loc = 1 sequence per chip at train_4k), weights keep their
    model-axis shards and are all-gathered at each use by GSPMD (re-gathered
    in backward under remat).  Collective volume per step becomes ~3× the
    per-device parameter bytes instead of ~6× the activation bytes — an
    order of magnitude for the dense-train cells."""
    rules = make_default_rules(multi_pod)
    table = dict(rules.table)
    if ep:
        # MoE variant ("fsdp_ep"): the model axis keeps the experts
        # (shard_map), so the batch stays on pod×data; attention/embedding
        # weights remain model-sharded and are gathered at use (tiny vs the
        # f32 activation all-reduces they replace).
        table["batch"] = ("pod", "data") if multi_pod else "data"
    else:
        table["batch"] = (("pod", "data", "model") if multi_pod
                          else ("data", "model"))
    table["act_seq"] = None        # no TP regions -> no seq sharding needed
    for a in ("act_heads", "act_kv", "act_ff", "act_lru"):
        table[a] = None            # activations carry only the batch shard
    # loss: vocab-parallel only when the model axis is free (ep variant);
    # pure FSDP owns the model axis with the batch, so logits stay local
    table["act_vocab"] = "model" if ep else None
    rules.table = table
    return rules


def make_moe_noseq_rules(multi_pod: bool = False) -> Rules:
    """MoE train policy (§Perf iteration 6): keep TP/EP but drop the
    sequence-sharded residual.  The seq-sharded stream forces an x
    all-gather at the qkv projection AND inside the MoE shard_map every
    layer; a replicated-over-model residual (537 MB resident at qwen3-moe
    train) removes both at ~1 GB/layer wire."""
    rules = make_default_rules(multi_pod)
    table = dict(rules.table)
    table["act_seq"] = None
    rules.table = table
    return rules


def make_moe_a2a_rules(multi_pod: bool = False) -> Rules:
    """MoE train policy (§Perf iteration 7): all-to-all token dispatch in the
    expert shard_map (see models/moe._moe_a2a) instead of all-gather +
    psum-scatter of the full residual."""
    rules = make_default_rules(multi_pod)
    table = dict(rules.table)
    table["moe_dispatch"] = "a2a"
    rules.table = table
    return rules


def make_decode_kv_rules(multi_pod: bool = False) -> Rules:
    """Decode policy (§Perf iteration 3): shard KV *heads* (padded up to the
    model axis) instead of cache sequence.  Attention is then fully local
    per shard — no cache all-gather — at the cost of padded-KV cache memory
    (2× for kv=8 on a 16-way axis)."""
    rules = make_default_rules(multi_pod)
    table = dict(rules.table)
    table["kv_heads"] = "model"
    table["act_kv"] = "model"
    table["kv_seq"] = None
    rules.table = table
    return rules


DEFAULT_RULES = make_default_rules()

_active_rules: contextvars.ContextVar[Rules | None] = contextvars.ContextVar(
    "repro_sharding_rules", default=None)


@contextlib.contextmanager
def use_rules(rules: Rules | None):
    token = _active_rules.set(rules)
    try:
        yield rules
    finally:
        _active_rules.reset(token)


def current_rules() -> Rules | None:
    return _active_rules.get()


def constrain(x, logical_axes: tuple):
    """Annotate an activation with logical axes (no-op without rules)."""
    rules = current_rules()
    if rules is None:
        return x
    if x.ndim != len(logical_axes):
        raise ValueError(f"rank {x.ndim} vs logical axes {logical_axes}")
    return jax.lax.with_sharding_constraint(x, rules.pspec(logical_axes))


def logical_to_pspec(logical_axes: tuple, rules: Rules | None = None) -> P:
    rules = rules or current_rules() or DEFAULT_RULES
    return rules.pspec(logical_axes)


def named_sharding(mesh, logical_axes: tuple, rules: Rules | None = None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_pspec(logical_axes, rules))


# ---------------------------------------------------------------------------
# tree-level sharding builders (used by launchers and the dry-run)
# ---------------------------------------------------------------------------

def _is_spec(s) -> bool:
    return isinstance(s, tuple)


def tree_pspecs(spec_tree, rules: Rules):
    """Logical spec tree -> PartitionSpec tree."""
    return jax.tree.map(lambda s: rules.pspec(s), spec_tree, is_leaf=_is_spec)


def tree_shardings(mesh, spec_tree, rules: Rules):
    return jax.tree.map(lambda s: NamedSharding(mesh, rules.pspec(s)),
                        spec_tree, is_leaf=_is_spec)


def zero_specs(spec_tree, shape_tree, rules: Rules, mesh, *, min_size=2**16):
    """ZeRO: give each large param's optimizer moments an extra sharded dim.

    For every leaf, find the first dimension that is (a) unsharded in the
    param spec, (b) divisible by the 'opt' rule's mesh-axis size — and shard
    it there.  Small leaves (norm scales, biases) stay as the param spec.
    Returns a logical spec tree for the fp32 moments.
    """
    opt_axes = rules.resolve("opt")
    if opt_axes is None:
        return spec_tree
    if isinstance(opt_axes, str):
        opt_axes = (opt_axes,)
    opt_axes_names = set(opt_axes)
    divisor = 1
    for a in opt_axes:
        divisor *= mesh.shape[a]

    def _axes_of(s):
        r = rules.resolve(s)
        if r is None:
            return set()
        return set(r) if isinstance(r, tuple) else {r}

    def per_leaf(spec, shape):
        import numpy as np
        if int(np.prod(shape)) < min_size:
            return spec
        used = set().union(*[_axes_of(s) for s in spec]) if spec else set()
        if used & set(opt_axes_names):
            return spec          # already sharded on the ZeRO axes (FSDP)
        new = list(spec)
        for d, (s, size) in enumerate(zip(spec, shape)):
            if s is None and size % divisor == 0:
                new[d] = "opt"
                return tuple(new)
        return spec

    return jax.tree.map(per_leaf, spec_tree, shape_tree, is_leaf=_is_spec)


def shapes_of(tree):
    return jax.tree.map(lambda x: tuple(x.shape), tree)
