"""Granite-3.0-3B-A800M MoE.  [hf:ibm-granite/granite-3.0-3b-a800m-base
(family card hf:ibm-granite/granite-3.0-1b-a400m-base)]

32L d_model=1536 24H (GQA kv=8) per-expert d_ff=512 vocab=49155,
40 experts top-8, tied embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab_size=49155, d_head=64, tie_embeddings=True,
    block_pattern=("moe",),
    n_experts=40, experts_per_token=8, capacity_factor=1.25,
    source="hf:ibm-granite/granite-3.0-3b-a800m-base",
)
REDUCED = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
    vocab_size=128, d_head=16, tie_embeddings=True,
    block_pattern=("moe",),
    n_experts=8, experts_per_token=2, capacity_factor=8.0, attn_chunk=32,
)
register(CONFIG, REDUCED)
