"""GLM-4-9B dense decoder.  [hf:THUDM/glm-4-9b]

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.  RoPE, RMSNorm,
SwiGLU.  (GLM's partial-rotary detail is simplified to full RoPE; noted in
DESIGN.md.)
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab_size=151552, d_head=128, rope_theta=10000.0,
    source="hf:THUDM/glm-4-9b",
)
REDUCED = ModelConfig(
    name="glm4-9b", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192,
    vocab_size=128, d_head=16, attn_chunk=32,
)
register(CONFIG, REDUCED)
