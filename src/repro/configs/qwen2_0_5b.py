"""Qwen2-0.5B dense decoder.  [arXiv:2407.10671; hf:Qwen/Qwen2-0.5B]

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936, QKV bias, tied
embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151936, d_head=64, qkv_bias=True, tie_embeddings=True,
    rope_theta=1e6,
    source="arXiv:2407.10671; hf:Qwen/Qwen2-0.5B",
)
REDUCED = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192,
    vocab_size=128, d_head=16, qkv_bias=True, tie_embeddings=True, attn_chunk=32,
)
register(CONFIG, REDUCED)
