"""MusicGen-Medium decoder backbone over EnCodec tokens.  [arXiv:2306.05284; hf]

48L d_model=1536 24H (kv=24 -> MHA) d_ff=6144 vocab=2048.  LayerNorm + GELU
MLP + sinusoidal positions (the MusicGen transformer).  The EnCodec frontend
is a stub: ``input_specs`` supplies precomputed frame embeddings for the
first ``n_prefix`` positions (conditioning prompt).
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab_size=2048, d_head=64,
    norm_type="layer", mlp_type="gelu", pos_emb="sinusoidal",
    frontend="audio_frames", n_prefix=256,
    source="arXiv:2306.05284; hf:facebook/musicgen-medium",
)
REDUCED = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=128, d_head=16,
    norm_type="layer", mlp_type="gelu", pos_emb="sinusoidal",
    frontend="audio_frames", n_prefix=4, attn_chunk=32,
)
register(CONFIG, REDUCED)
