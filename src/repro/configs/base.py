"""Model configuration system + architecture registry.

Every assigned architecture registers an exact ``ModelConfig`` (from public
literature, see per-file citations) plus a ``reduced()`` variant for CPU
smoke tests.  Shapes (the assignment's per-arch input-shape set) are global
and defined here.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "register", "get_config",
           "list_configs", "reduced"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    norm_type: str = "rms"             # rms | layer
    mlp_type: str = "swiglu"           # swiglu | geglu | gelu
    pos_emb: str = "rope"              # rope | sinusoidal | none
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 2048     # GShard-style dispatch group
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # --- hybrid (RecurrentGemma / Griffin) ---
    block_pattern: tuple = ("attn",)   # layer kinds of one scanned group
    tail_pattern: tuple = ()           # remainder layers (not scanned)
    local_window: int = 0              # local attention window (0 = full)
    lru_width: int = 0                 # RG-LRU recurrence width (0 = d_model)
    logits_soft_cap: float = 0.0
    # --- modality frontend stub ---
    frontend: str | None = None        # None | "audio_frames" | "vision_patches"
    n_prefix: int = 0                  # frontend embedding positions
    # --- numerics / runtime ---
    dtype: str = "bfloat16"
    remat: str = "full"                # full | dots | none
    scan_layers: bool = True
    attn_chunk: int = 1024             # KV-chunk for memory-bounded attention
    loss_chunk: int = 0                # 0 = unchunked vocab loss
    # --- mesh padding (set by pad_for_mesh; 0 = unpadded) -------------------
    # jit argument shardings require exact divisibility, so dims sharded over
    # the model axis are padded in the PARAMETERS and masked inert at runtime
    # (zero gradients, zero forward contribution) — the logical architecture
    # is unchanged.
    n_heads_padded: int = 0
    n_kv_heads_padded: int = 0
    vocab_padded: int = 0
    n_experts_padded: int = 0
    # provenance
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def heads_p(self) -> int:
        return self.n_heads_padded or self.n_heads

    @property
    def kv_heads_p(self) -> int:
        return self.n_kv_heads_padded or self.n_kv_heads

    @property
    def vocab_p(self) -> int:
        return self.vocab_padded or self.vocab_size

    @property
    def experts_p(self) -> int:
        return self.n_experts_padded or self.n_experts

    @property
    def n_groups(self) -> int:
        """Number of scanned groups of ``block_pattern``."""
        body = self.n_layers - len(self.tail_pattern)
        assert body % len(self.block_pattern) == 0, (
            f"{self.name}: {body} layers not divisible by pattern "
            f"{self.block_pattern}")
        return body // len(self.block_pattern)

    @property
    def is_attention_free(self) -> bool:
        kinds = set(self.block_pattern) | set(self.tail_pattern)
        return not kinds & {"attn", "local_attn", "moe"}

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch never attends over the full sequence ("moe"
        blocks carry full GQA attention)."""
        kinds = set(self.block_pattern) | set(self.tail_pattern)
        return not kinds & {"attn", "moe"}

    def supports_shape(self, shape: ShapeSpec) -> tuple[bool, str]:
        if shape.name == "long_500k" and not self.is_subquadratic:
            return False, "full-attention arch: 500k decode skipped per assignment"
        return True, ""

    # -- parameter counting (for 6ND roofline term) -------------------------
    def param_count(self) -> int:
        d, dh = self.d_model, self.head_dim
        n_attn = self.d_model * dh * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * dh * d
        if self.qkv_bias:
            n_attn += dh * (self.n_heads + 2 * self.n_kv_heads)
        n_mlp_dense = 3 * d * self.d_ff          # SwiGLU
        n_moe = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        d_inner = self.ssm_expand * d
        n_heads_ssm = d_inner // self.ssm_head_dim if self.ssm_head_dim else 0
        n_ssm = (d * (2 * d_inner + 2 * self.ssm_state + n_heads_ssm)
                 + self.ssm_conv * (d_inner + 2 * self.ssm_state)
                 + 2 * n_heads_ssm + d_inner * d)
        w = self.lru_width or d
        n_rglru = (d * 2 * w) + 4 * w * 2 + 2 * w + w * d  # proj + conv4 + gates + out
        per_kind = {"attn": n_attn + n_mlp_dense,
                    "local_attn": n_attn + n_mlp_dense,
                    "moe": n_attn + n_moe,
                    "ssm": n_ssm,
                    "rglru": n_rglru + n_mlp_dense}
        kinds = list(self.block_pattern) * self.n_groups + list(self.tail_pattern)
        total = sum(per_kind[k] for k in kinds)
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total += d * (2 * self.n_layers + 1)     # norms
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.n_experts == 0:
            return self.param_count()
        dense_like = replace(self, n_experts=self.experts_per_token)
        return dense_like.param_count()


_REGISTRY: dict[str, "ModelConfig"] = {}
_REDUCED: dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig, reduced_cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    _REDUCED[cfg.name] = reduced_cfg
    return cfg


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    _ensure_loaded()
    table = _REDUCED if reduced else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return table[name]


def reduced(name: str) -> ModelConfig:
    return get_config(name, reduced=True)


def list_configs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def pad_for_mesh(cfg: ModelConfig, tp: int, pad_kv: bool = False) -> ModelConfig:
    """Pad model-axis-sharded dims up to multiples of the TP size.

    Padded slots are inert (masked in attention output / router / logits),
    so the logical architecture is exactly the published config; the cost is
    idle compute on the padded fraction, reported in the roofline notes.
    """
    def up(n: int, m: int) -> int:
        return -(-n // m) * m

    hp = up(cfg.n_heads, tp) if cfg.n_heads % tp else cfg.n_heads
    kvp = cfg.n_kv_heads
    if cfg.n_kv_heads == cfg.n_heads:          # MHA: pad KV with the heads
        kvp = hp
    elif pad_kv and cfg.n_kv_heads % tp:
        # decode kv-shard policy: pad KV heads up to the model axis so the
        # cache shards by head.  Heads must pad to kvp × G with the ORIGINAL
        # group size G — real q head h then keeps its original index and its
        # original kv head h//G (padding kv without this breaks the GQA
        # grouping for real heads).  Each model shard gets exactly its kv
        # heads' aligned q-head groups — fully local attention.
        kvp = up(cfg.n_kv_heads, tp)
        g = cfg.n_heads // cfg.n_kv_heads
        hp = kvp * g
    elif hp % cfg.n_kv_heads:
        raise ValueError(f"{cfg.name}: padded heads {hp} not divisible by "
                         f"kv heads {cfg.n_kv_heads}")
    vp = up(cfg.vocab_size, tp) if cfg.vocab_size % tp else cfg.vocab_size
    ep = cfg.n_experts
    if cfg.n_experts and cfg.n_experts % tp:
        ep = up(cfg.n_experts, tp)
    return replace(cfg, n_heads_padded=hp, n_kv_heads_padded=kvp,
                   vocab_padded=vp, n_experts_padded=ep)


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    from repro.configs import (glm4_9b, granite_moe_3b_a800m, internvl2_1b,  # noqa: F401
                               mamba2_130m, musicgen_medium, qwen2_0_5b,
                               qwen2_5_14b, qwen2_5_3b, qwen3_moe_235b_a22b,
                               recurrentgemma_2b)
