"""Qwen3-235B-A22B MoE.  [hf:Qwen/Qwen3-235B-A22B (family card hf:Qwen/Qwen3-30B-A3B)]

94L d_model=4096 64H (GQA kv=4) per-expert d_ff=1536 vocab=151936,
128 experts top-8.  Every layer is MoE.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=1536,
    vocab_size=151936, d_head=128, rope_theta=1e6,
    block_pattern=("moe",),
    n_experts=128, experts_per_token=8, capacity_factor=1.25,
    source="hf:Qwen/Qwen3-235B-A22B; family card hf:Qwen/Qwen3-30B-A3B",
)
REDUCED = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=128, d_head=16,
    block_pattern=("moe",),
    n_experts=8, experts_per_token=2, capacity_factor=8.0, attn_chunk=32,
)
register(CONFIG, REDUCED)
