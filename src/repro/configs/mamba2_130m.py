"""Mamba2-130M (SSD, attention-free).  [arXiv:2405.21060]

24L d_model=768, ssm_state=128, expand=2, head_dim=64, vocab=50280, tied
embeddings.  Attention-free -> runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=12, n_kv_heads=12, d_ff=0,
    vocab_size=50280, d_head=64, tie_embeddings=True, pos_emb="none",
    block_pattern=("ssm",),
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    source="arXiv:2405.21060; hf:state-spaces/mamba2-130m",
)
REDUCED = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=128, d_head=16, tie_embeddings=True, pos_emb="none",
    block_pattern=("ssm",),
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
)
register(CONFIG, REDUCED)
