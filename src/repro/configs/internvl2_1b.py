"""InternVL2-1B: InternViT frontend (stub) + Qwen2-0.5B language backbone.
[arXiv:2404.16821; hf:OpenGVLab/InternVL2-1B]

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.  The ViT is a stub:
``input_specs`` supplies 256 precomputed patch embeddings per sequence.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151655, d_head=64, qkv_bias=True, tie_embeddings=True,
    rope_theta=1e6,
    frontend="vision_patches", n_prefix=256,
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-1B",
)
REDUCED = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192,
    vocab_size=128, d_head=16, qkv_bias=True, tie_embeddings=True,
    frontend="vision_patches", n_prefix=4, attn_chunk=32,
)
register(CONFIG, REDUCED)
