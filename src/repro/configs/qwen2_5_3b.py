"""Qwen2.5-3B dense decoder.  [hf:Qwen/Qwen2.5-3B]

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936, QKV bias, tied
embeddings, RoPE theta 1e6.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, d_ff=11008,
    vocab_size=151936, d_head=128, qkv_bias=True, tie_embeddings=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-3B (family card hf:Qwen/Qwen2.5-0.5B)",
)
REDUCED = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192,
    vocab_size=128, d_head=16, qkv_bias=True, tie_embeddings=True, attn_chunk=32,
)
register(CONFIG, REDUCED)
