"""RecurrentGemma-2B (Griffin): RG-LRU + local attention, 1:2.  [arXiv:2402.19427; hf]

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, window 2048.
Pattern (rec, rec, attn) x 8 + (rec, rec) tail = 26 layers; GeGLU MLP.
Sub-quadratic (local attention only) -> runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab_size=256000, d_head=256, tie_embeddings=True,
    mlp_type="geglu",
    block_pattern=("rglru", "rglru", "local_attn"), tail_pattern=("rglru", "rglru"),
    local_window=2048, lru_width=2560,
    source="arXiv:2402.19427; hf:google/recurrentgemma-2b",
)
REDUCED = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=1, d_ff=192,
    vocab_size=128, d_head=16, tie_embeddings=True,
    mlp_type="geglu",
    block_pattern=("rglru", "rglru", "local_attn"), tail_pattern=("rglru", "rglru"),
    local_window=16, lru_width=64, attn_chunk=32,
)
register(CONFIG, REDUCED)
