"""Qwen2.5-14B dense decoder.  [hf:Qwen/Qwen2.5-14B]

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064, QKV bias.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824,
    vocab_size=152064, d_head=128, qkv_bias=True, rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-14B (family card hf:Qwen/Qwen2.5-0.5B)",
)
REDUCED = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=2, d_model=80, n_heads=5, n_kv_heads=1, d_ff=192,
    vocab_size=128, d_head=16, qkv_bias=True, attn_chunk=32,
)
register(CONFIG, REDUCED)
