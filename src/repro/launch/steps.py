"""Step-function + sharding assembly shared by launchers and the dry-run.

``build_train`` / ``build_prefill`` / ``build_decode`` return
(jitted_fn, abstract_inputs, rules) for an (arch config, shape, mesh) cell:
abstract inputs are ShapeDtypeStructs (no allocation), shardings follow the
logical rules in ``distributed.sharding``, and batch-replication kicks in
automatically for cells whose global batch cannot fill the data axes
(long_500k B=1).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec, pad_for_mesh
from repro.distributed.sharding import (Rules, make_decode_kv_rules,
                                        make_default_rules, make_fsdp_rules,
                                        make_moe_a2a_rules,
                                        make_moe_noseq_rules, shapes_of,
                                        tree_shardings, use_rules, zero_specs)
from repro.models import model as M
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_loop import make_train_step

__all__ = ["make_rules_for", "build_train", "build_prefill", "build_decode",
           "build_cell"]



def _attach(sds_tree, sh_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        sds_tree, sh_tree)

def make_rules_for(mesh, global_batch: int, policy: str = "default") -> Rules:
    multi_pod = "pod" in mesh.axis_names
    makers = {"default": make_default_rules, "fsdp": make_fsdp_rules,
              "fsdp_ep": lambda mp: make_fsdp_rules(mp, ep=True),
              "moe_noseq": make_moe_noseq_rules,
              "moe_a2a": make_moe_a2a_rules,
              "decode_kv": make_decode_kv_rules}
    rules = makers[policy](multi_pod)
    rules.mesh = mesh
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    if policy == "fsdp":
        dp *= mesh.shape.get("model", 1)
    # fsdp_ep keeps batch on pod×data only (model axis carries experts)
    if global_batch % dp != 0:
        # cannot shard the batch evenly (e.g. B=1 long-context): replicate
        rules.table = dict(rules.table)
        rules.table["batch"] = None
        rules.table["opt"] = None
    return rules


def _batch_sds(cfg: ModelConfig, B: int, S: int, mesh, rules: Rules):
    sh = NamedSharding(mesh, rules.pspec(("batch", None)))
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=sh)}
    if cfg.frontend is not None:
        sh3 = NamedSharding(mesh, rules.pspec(("batch", None, None)))
        batch["embeds"] = jax.ShapeDtypeStruct((B, cfg.n_prefix, cfg.d_model),
                                               jnp.float32, sharding=sh3)
    return batch


def _abstract_params(cfg):
    return jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))


def _replicated(mesh):
    return NamedSharding(mesh, P())


def build_train(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                n_microbatches: int = 1, opt_cfg: OptimizerConfig | None = None,
                policy: str = "default"):
    rules = make_rules_for(mesh, shape.global_batch, policy)
    opt_cfg = opt_cfg or OptimizerConfig()
    params_sds = _abstract_params(cfg)
    opt_sds = jax.eval_shape(lambda: init_opt_state(params_sds))
    pspecs = M.param_specs(cfg)
    param_sh = tree_shardings(mesh, pspecs, rules)
    zspecs = zero_specs(pspecs, shapes_of(params_sds), rules, mesh)
    moment_sh = tree_shardings(mesh, zspecs, rules)
    opt_sh = type(opt_sds)(step=_replicated(mesh), mu=moment_sh, nu=moment_sh)
    batch_sds = _batch_sds(cfg, shape.global_batch, shape.seq_len, mesh, rules)
    batch_sh = jax.tree.map(lambda s: s.sharding, batch_sds)

    step_fn = make_train_step(cfg, opt_cfg, n_microbatches)

    def traced(params, opt_state, batch):
        with use_rules(rules):
            return step_fn(params, opt_state, batch)

    jitted = jax.jit(
        traced,
        in_shardings=(param_sh, opt_sh, batch_sh),
        out_shardings=(param_sh, opt_sh, _replicated(mesh)),
        donate_argnums=(0, 1),
    )
    params_sds = _attach(params_sds, param_sh)
    opt_sds = _attach(opt_sds, opt_sh)
    return jitted, (params_sds, opt_sds, batch_sds), rules


def build_prefill(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                  policy: str = "default"):
    rules = make_rules_for(mesh, shape.global_batch, policy)
    params_sds = _abstract_params(cfg)
    param_sh = tree_shardings(mesh, M.param_specs(cfg), rules)
    batch_sds = _batch_sds(cfg, shape.global_batch, shape.seq_len, mesh, rules)
    cache_sh = tree_shardings(mesh, M.cache_specs(cfg), rules)
    logits_sh = NamedSharding(mesh, rules.pspec(("batch", "vocab")))

    def serve_prefill(params, batch):
        with use_rules(rules):
            return M.prefill(params, cfg, batch["tokens"],
                             cache_len=shape.seq_len,
                             embeds=batch.get("embeds"))

    jitted = jax.jit(
        serve_prefill,
        in_shardings=(param_sh, jax.tree.map(lambda s: s.sharding, batch_sds)),
        out_shardings=(logits_sh, cache_sh),
    )
    params_sds = _attach(params_sds, param_sh)
    return jitted, (params_sds, batch_sds), rules


def build_decode(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                 policy: str = "default"):
    """serve_step: ONE new token against a cache of shape.seq_len entries."""
    rules = make_rules_for(mesh, shape.global_batch, policy)
    params_sds = _abstract_params(cfg)
    param_sh = tree_shardings(mesh, M.param_specs(cfg), rules)
    with use_rules(rules):   # cache dtype from cfg
        caches_sds = jax.eval_shape(
            lambda: M.cache_init(cfg, shape.global_batch, shape.seq_len))
    cache_sh = tree_shardings(mesh, M.cache_specs(cfg), rules)
    tok_sh = NamedSharding(mesh, rules.pspec(("batch",)))
    tok_sds = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32,
                                   sharding=tok_sh)
    logits_sh = NamedSharding(mesh, rules.pspec(("batch", "vocab")))

    def serve_decode(params, caches, token, pos):
        with use_rules(rules):
            return M.decode_step(params, cfg, token, caches, pos)

    jitted = jax.jit(
        serve_decode,
        in_shardings=(param_sh, cache_sh, tok_sh, _replicated(mesh)),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(1,),
    )
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32, sharding=_replicated(mesh))
    params_sds = _attach(params_sds, param_sh)
    caches_sds = _attach(caches_sds, cache_sh)
    return jitted, (params_sds, caches_sds, tok_sds, pos_sds), rules


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, policy: str = "default",
               **kw):
    cfg = pad_for_mesh(cfg, mesh.shape.get("model", 1),
                       pad_kv=(policy == "decode_kv"))
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, policy=policy, **kw)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh, policy=policy)
    return build_decode(cfg, shape, mesh, policy=policy)
