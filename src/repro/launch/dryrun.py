import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape)
cell on the production meshes, and derive the roofline terms.

The two lines above MUST stay first: jax locks the device count at first
initialization, and the dry-run needs 512 placeholder host devices for the
(2, 16, 16) multi-pod mesh.  Run as a module:

    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun

Per cell this produces:
  * scanned compile on the single-pod (16,16) mesh *and* the multi-pod
    (2,16,16) mesh — the runnability proof + memory_analysis();
  * unrolled 1-group / 2-group analysis compiles (single-pod) whose
    per-group cost delta extrapolates exact full-depth FLOPs / bytes /
    collective-bytes (see roofline.analysis docstring for why scanned
    compiles cannot be used for costs);
  * the three roofline terms + bottleneck + MODEL_FLOPS/HLO_FLOPs ratio.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs.base import SHAPES, get_config, list_configs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell
from repro.roofline.analysis import (CellCost, extrapolate, model_flops,
                                     roofline_terms, tree_local_bytes)

# decode cells of full-attention archs at 500k are skipped per assignment
# (DESIGN.md §Arch-applicability)


def _mem_stats(compiled) -> dict:
    m = compiled.memory_analysis()
    if m is None:
        return {}
    return {k: int(getattr(m, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes") if hasattr(m, k)}


def _unrolled_cfg(cfg, n_groups: int):
    n_layers = len(cfg.block_pattern) * n_groups + len(cfg.tail_pattern)
    return dataclasses.replace(cfg, n_layers=n_layers, scan_layers=False,
                               remat="none")


def _memory_floor(shape, sds) -> float:
    """Sharding-exact per-device bytes that must cross HBM once per step."""
    if shape.kind == "train":
        params_sds, opt_sds, batch_sds = sds
        # params: fwd read + bwd read + update write; moments: read + write
        return (3 * tree_local_bytes(params_sds)
                + 2 * tree_local_bytes(opt_sds)
                + tree_local_bytes(batch_sds))
    if shape.kind == "prefill":
        params_sds, batch_sds = sds
        return tree_local_bytes(params_sds) + tree_local_bytes(batch_sds)
    params_sds, caches_sds, tok_sds, _pos = sds   # decode
    return (tree_local_bytes(params_sds) + tree_local_bytes(caches_sds)
            + tree_local_bytes(tok_sds))


def compile_cell(cfg, shape, mesh, label: str, policy: str = "default") -> dict:
    t0 = time.perf_counter()
    with mesh:
        jitted, sds, _rules = build_cell(cfg, shape, mesh, policy=policy)
        lowered = jitted.lower(*sds)
        compiled = lowered.compile()
    info = {"label": label, "compile_s": round(time.perf_counter() - t0, 1),
            "memory": _mem_stats(compiled),
            "memory_floor_bytes": _memory_floor(shape, sds)}
    return info, compiled


def run_cell(arch: str, shape_name: str, *, analysis: bool = True,
             skip_multipod: bool = False, policy: str = "default") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "kind": shape.kind,
           "policy": policy,
           "params_b": cfg.param_count() / 1e9,
           "active_params_b": cfg.active_param_count() / 1e9}
    ok, why = cfg.supports_shape(shape)
    if not ok:
        rec["status"] = "SKIP"
        rec["why"] = why
        return rec
    try:
        # 1) scanned production compile, single-pod
        mesh1 = make_production_mesh(multi_pod=False)
        info1, compiled1 = compile_cell(cfg, shape, mesh1, "single_pod", policy)
        rec["single_pod"] = info1
        # 2) scanned production compile, multi-pod (the 512-chip proof)
        if not skip_multipod:
            mesh2 = make_production_mesh(multi_pod=True)
            info2, _ = compile_cell(cfg, shape, mesh2, "multi_pod", policy)
            rec["multi_pod"] = info2
        # 3) roofline analysis from unrolled 1g / 2g compiles (single-pod)
        if analysis:
            _, comp_g1 = compile_cell(_unrolled_cfg(cfg, 1), shape, mesh1, "g1",
                                      policy)
            _, comp_g2 = compile_cell(_unrolled_cfg(cfg, 2), shape, mesh1, "g2",
                                      policy)
            cost = extrapolate(CellCost.from_compiled(comp_g1),
                               CellCost.from_compiled(comp_g2), cfg.n_groups)
            n_dev = 256
            terms = roofline_terms(
                cost, memory_floor_bytes=info1.get("memory_floor_bytes", 0.0))
            mf = model_flops(cfg, shape, n_dev)
            rec["cost"] = {"flops_per_dev": cost.flops,
                           "bytes_per_dev": cost.bytes_accessed,
                           "collective_bytes_per_dev": cost.collective_bytes,
                           "collectives": cost.collectives}
            rec["roofline"] = terms
            rec["model_flops_per_dev"] = mf
            rec["useful_flops_ratio"] = (mf / cost.flops) if cost.flops else 0.0
        rec["status"] = "OK"
    except Exception as e:  # noqa: BLE001 — record failures per cell
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-analysis", action="store_true")
    ap.add_argument("--skip-multipod", action="store_true")
    ap.add_argument("--out", default=None, help="directory for per-cell JSON")
    ap.add_argument("--policy", default="default",
                    choices=["default", "fsdp", "fsdp_ep", "moe_noseq", "moe_a2a", "decode_kv"])
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in list_configs():
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        print(f"=== {arch} × {shape}", flush=True)
        rec = run_cell(arch, shape, analysis=not args.no_analysis,
                       skip_multipod=args.skip_multipod, policy=args.policy)
        results.append(rec)
        status = rec["status"]
        extra = ""
        if status == "OK" and "roofline" in rec:
            r = rec["roofline"]
            extra = (f" bottleneck={r['bottleneck']}"
                     f" compute={r['compute_s']:.4f}s"
                     f" mem={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s")
        print(f"    -> {status}{extra}", flush=True)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            suffix = "" if args.policy == "default" else f"__{args.policy}"
            with open(os.path.join(args.out, f"{arch}__{shape}{suffix}.json"), "w") as f:
                json.dump(rec, f, indent=1)
    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"SUMMARY: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL / {len(results)}")


if __name__ == "__main__":
    main()
