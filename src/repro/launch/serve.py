"""Streaming serving driver: the paper's event-driven processing mode applied
to LM inference.

Requests arrive as broker messages; the engine micro-batches per partition
and runs prefill + decode compute-units on a pilot (local backend on CPU,
``jax://mesh`` slices on real hardware).  StreamInsight instruments the run
(L^br, L^px, T^px per run-id) and the USL-based autoscaler recommends the
partition count for an offered load.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --requests 24 --partitions 2 --new-tokens 8
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.metrics import MetricRegistry, new_run_id, percentile_summary
from repro.models import model as M
from repro.pilot.api import PilotComputeService, PilotDescription
from repro.streaming.broker import Broker
from repro.streaming.engine import ThreadedStreamingEngine, Workload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--partitions", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--batch-max", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    metrics = MetricRegistry()
    run_id = new_run_id(f"serve-{cfg.name}")

    # jit one fused generate for the micro-batch size(s) we serve
    gen = jax.jit(lambda p, prompt: M.greedy_generate(
        p, cfg, prompt, n_new=args.new_tokens,
        cache_len=args.prompt_len + args.new_tokens))

    def handle(msgs):
        prompts = jnp.stack([jnp.asarray(m.value["tokens"]) for m in msgs])
        out = gen(params, prompts)
        return np.asarray(out)

    pcs = PilotComputeService()
    pilot = pcs.submit_pilot(PilotDescription(
        resource="local://", concurrency=args.partitions))
    broker = Broker()
    broker.create_topic("requests", args.partitions)
    engine = ThreadedStreamingEngine(
        broker, "requests", pilot, Workload(fn=handle, name="generate"),
        metrics, run_id, batch_max=args.batch_max)
    engine.start()

    import time
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for i in range(args.requests):
        tokens = rng.integers(0, cfg.vocab_size, size=(args.prompt_len,))
        msg_id = f"{run_id}/{i}"
        broker.append("requests", {"tokens": tokens}, ts=time.perf_counter(),
                      run_id=run_id, msg_id=msg_id,
                      size_bytes=args.prompt_len * 4)
        metrics.record(run_id, "broker", "append", time.perf_counter(),
                       msg_id=msg_id)
    engine.drain(args.requests, timeout=600)
    dt = time.perf_counter() - t0
    engine.stop()
    pcs.close()

    lat = metrics.latencies(run_id, "append", "complete")
    print(f"served {engine.core.processed}/{args.requests} requests "
          f"in {dt:.2f}s  T^px={engine.core.processed / dt:.2f} req/s")
    print("L^px:", {k: round(v, 4) for k, v in percentile_summary(lat).items()})
    print(f"retries={engine.core.retried} failed={engine.core.failed_batches}")


if __name__ == "__main__":
    main()
