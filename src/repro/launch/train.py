"""End-to-end training driver.

CPU-runnable with reduced configs (``--reduced``) — the quickstart path —
and mesh-ready for real hardware: sharding comes from the same
``launch.steps`` assembly the dry-run proves.  Fault tolerance: periodic
async checkpoints + automatic resume from the latest complete step.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
        --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeSpec, get_config
from repro.data.pipeline import SyntheticLM
from repro.launch.steps import build_train
from repro.models import model as M
from repro.training.checkpoint import CheckpointManager, latest_step
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_loop import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None,
                    help="e.g. '2x4' to train on a (data, model) device mesh")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=min(20, args.steps // 10 + 1),
                              decay_steps=args.steps)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch, seed=args.seed,
                       n_prefix=cfg.n_prefix if cfg.frontend else 0,
                       d_model=cfg.d_model if cfg.frontend else 0)

    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh(shape, ("data", "model")[:len(shape)])
        spec = ShapeSpec("cli", args.seq, args.batch, "train")
        with mesh:
            step_fn, _, _ = build_train(cfg, spec, mesh,
                                        n_microbatches=args.microbatches,
                                        opt_cfg=opt_cfg)
    else:
        mesh = None
        step_fn = jax.jit(make_train_step(cfg, opt_cfg, args.microbatches))

    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    opt_state = init_opt_state(params)
    start = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and latest_step(args.ckpt_dir) is not None:
        tree, start = mgr.restore_latest({"params": params, "opt": opt_state})
        params, opt_state = tree["params"], tree["opt"]
        print(f"resumed from step {start}")

    n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M steps={args.steps}")
    t0 = time.perf_counter()
    losses = []
    for step in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, data.batch_at(step))
        if mesh:
            with mesh:
                params, opt_state, metrics = step_fn(params, opt_state, batch)
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            tok_s = (step - start + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} tok/s {tok_s:,.0f}")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state})
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt_state})
        mgr.wait()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
