"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod
    axis shards the global batch across pods (DCI traffic: gradient
    all-reduce only)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh over however many host devices the test forced."""
    return jax.make_mesh(shape, axes)
