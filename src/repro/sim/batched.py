"""Batched fast replay of closed-loop adaptation cells.

The what-if engine (``core.whatif``) sweeps (scenario × policy × seed)
grids whose cells are dominated by DES heap traffic that is *structurally
predictable*: the producer's emission times are a pure function of the
rate program (no RNG), the ingest paths are processor-sharing queues with
no stochastic input, fault plans expand to a schedule that is fully known
before the run starts (``streaming.faults.expand_plan``), and the random
draws — per-invocation lognormal jitter, retry backoff, HPC batch-queue
waits — come from seeded streams whose consumption order is fixed by the
event order.  This module exploits that structure: it replays only the
*irreducible* events (appends, invocation finishes, fault firings,
control ticks) through a real ``Simulator`` driving the real
``ControlLoop`` / policy / ``OnlineUSLEstimator`` objects.

Bit-agreement with ``run_adaptation`` is a construction invariant, not an
aspiration: the control loop, policy stack, USL estimator, the service
time model (``serverless.service_time_mean``) and the HPC coupling terms
(``hpcsim.coupling_terms`` / ``hpcsim.queue_wait_sample``) are the *same
code objects* the scalar path runs; the replay reproduces the scalar
path's float arithmetic (VFT virtual-time updates, ``now + delay``
timestamp sums, the 256-block normal stream via ``Simulator.normals``,
the ``[seed, uid]``-seeded queue-wait stream) operation for operation,
and ``tests/test_batched.py`` asserts equality field-by-field across
seeds and policies.

Eligibility matrix (static, checked before anything runs):

=====================  =====================================================
cell shape             fast path
=====================  =====================================================
serverless, no faults  windowed replay: columnar ingest shards between
                       control ticks, event-true container pool
serverless + faults    windowed replay + fault splicing: crash/preempt/
                       stall/duplicate events armed from the pre-expanded
                       plan, restart gaps and redelivery spliced into the
                       completion chain (at-least-once ledger bit-identical)
wrangler / stampede2   event-true HPC replay: coupled service-time chain on
(± faults)             a real shared-FS ``SharedResource`` and model
                       ``SimLock``, per-window effective rates from
                       ``hpcsim.coupling_terms``, seeded log-normal queue
                       waits from ``hpcsim.queue_wait_sample``
=====================  =====================================================

Still declining (the scalar DES remains the reference for these):

* ``engine != "sim"`` — the wall clock cannot be replayed;
* ``machine == "federated"`` — member routing, health breakers and
  cost-aware placement form a state machine across backends that the
  replay does not model;
* ``batch_max != 1`` — the replay models one invocation per message (the
  paper's Lambda mapping);
* serverless cells whose working set exceeds the container (the
  memory-failure path is a retry loop, not a replayable fast path).

Runtime fallbacks (the replay *starts*, then discovers the cell leaves
the fast regime): a straggler speculation would fire, or a serverless
invocation would exceed the walltime limit.  Both raise
``_FallbackNeeded``; the caller reruns the cell on the scalar DES and the
reason is logged (INFO — the replay started and bailed; static declines
log at DEBUG, they are expected and per-grid numerous) and recorded on
the summary (``fallback_reason``).

Because summaries are bit-identical, the fast and scalar paths share
``cache_key`` entries in ``streaminsight``'s result cache — including the
newly-eligible fault and HPC shapes: a cached scalar summary satisfies a
fast request and vice versa.  That sharing is only sound while the
bit-identity contract holds; anything weaker must use a distinct key.

The jax lockstep steppers batch S seeds into one ``vmap``: the original
``lockstep_completion_times`` collapses static single-partition cells to
one scan, and ``grid_lockstep_completion_times`` lifts the same S-seed
``vmap`` to controller-driven multi-container cells by freezing the
reference seed's dispatch trajectory (partition/container assignment and
exogenous ready floors) and replaying every seed's jitter draws through
the frozen structure.  Both run in float32 on the accelerator path, so
their agreement contract is a documented tolerance (``LOCKSTEP_RTOL``),
not bit equality; they feed perf-smoke informational rows, never the
tournament results.
"""

from __future__ import annotations

import functools
import heapq
import json
import logging
import math
import statistics
from collections import deque
from dataclasses import replace

import numpy as np

from repro.core.autoscale import ControlLoop, policy_from_spec
from repro.core.metrics import percentile_summary
from repro.core.miniapp import (AdaptationExperiment, AdaptationPlan,
                                AdaptationSummary, KMeansStreamWorkload,
                                POINT_BYTES, adaptation_profile_factory,
                                scaling_policy_spec)
from repro.pilot.backends.hpcsim import (DEFAULTS as HPC_DEFAULTS, MACHINES,
                                         coupling_terms, queue_wait_sample)
from repro.pilot.backends.serverless import DEFAULTS, service_time_mean
from repro.sim.des import SharedResource, SimLock, Simulator
from repro.streaming.faults import expand_plan
from repro.streaming.producer import rate_program_from_spec

__all__ = ["try_fast_adaptation", "lockstep_completion_times",
           "lockstep_eligibility", "grid_lockstep_completion_times",
           "grid_lockstep_eligibility", "LOCKSTEP_RTOL"]

log = logging.getLogger("repro.sim.batched")

# wiring constants of run_adaptation's pipeline (the replay must agree
# with them exactly; they are assembly facts, not knobs)
_REQUEST_LATENCY = 0.01      # PartitionIngest default request_latency
_FS_REQUEST_LATENCY = 0.002  # SharedFsIngest default request_latency
_INGEST_BW = 1e6             # run_adaptation's bw_per_partition (Kinesis)
_IDLE_RESOLUTION_S = 0.25    # SyntheticProducer idle probe spacing
_WALLTIME_S = 900.0          # PilotDescription default walltime
_RETRY_CAP_S = 30.0          # _EngineCore default retry_backoff_cap_s

_INF = float("inf")


class _FallbackNeeded(RuntimeError):
    """The cell left the replayable regime mid-run — rerun it scalar."""


# ---------------------------------------------------------------------------
# emission schedule: pure function of (rate spec, horizon), shared per grid
# ---------------------------------------------------------------------------

_EMISSION_CACHE: dict[tuple, tuple[list[float], float, list[float]]] = {}
_EMISSION_CACHE_MAX = 32


def _emission_schedule(rate_spec: dict, horizon_s: float,
                       cap: int) -> tuple[list[float], float, list[float]]:
    """Replay ``SyntheticProducer._tick_program``'s event chain off-line.

    Returns ``(emit_times, finish_t, sched_times)``: the exact float
    timestamps of every emission, the production-over event time, and for
    each emission the timestamp of the *program event that scheduled it*
    (the previous emission or idle probe — needed to resolve heap-order
    ties when an emission lands exactly on a control-tick boundary).
    The chain is RNG-free, so one schedule serves every seed and policy of
    a what-if grid.
    """
    key = (json.dumps(rate_spec, sort_keys=True, default=str),
           horizon_s, cap)
    hit = _EMISSION_CACHE.get(key)
    if hit is not None:
        return hit
    program = rate_program_from_spec(rate_spec)
    emit: list[float] = []
    sched: list[float] = []
    t = 0.0
    prev = 0.0          # ts of the program event that scheduled event at t
    while True:
        if t >= horizon_s or len(emit) >= cap:
            finish_t = t
            finish_sched = prev
            break
        rate = program.rate(t)
        if rate <= 1e-9:
            prev = t
            t = t + _IDLE_RESOLUTION_S
            continue
        emit.append(t)
        sched.append(prev)
        prev = t
        t = t + 1.0 / rate
    out = (emit, finish_t, sched + [finish_sched])
    if len(_EMISSION_CACHE) >= _EMISSION_CACHE_MAX:
        _EMISSION_CACHE.pop(next(iter(_EMISSION_CACHE)))
    _EMISSION_CACHE[key] = out
    return out


def _program_beats_tick(event_t: float, sched_t: float,
                        interval_s: float) -> bool:
    """Heap order of a producer program event vs the control tick at the
    same timestamp ``event_t`` (an exact-float collision, e.g. a 2 Hz
    emission grid meeting 2 s ticks).

    Both are plain ``(ts, seq)`` heap entries, so the earlier *scheduling*
    wins: the program event was pushed at ``sched_t``, the tick at
    ``event_t - interval_s``.  When those collide too, the chains are
    recursively tied; at the root (t=0) the producer starts before the
    loop in ``run_adaptation``'s assembly order, so the producer wins."""
    tick_armed = event_t - interval_s
    while True:
        if sched_t < tick_armed:
            return True
        if sched_t > tick_armed:
            return False
        if sched_t <= 0.0:
            return True          # setup order: producer.start before loop.start
        # both pushed during events at the same earlier timestamp — compare
        # one step further back along each chain
        event_t, tick_armed = sched_t, tick_armed - interval_s
        sched_t = event_t - interval_s  # conservative: unknown exact program
        # spacing this far back only matters on pathological rate programs;
        # equal spacing keeps recursing toward the t=0 base case


# ---------------------------------------------------------------------------
# ingest shards: SharedResource's VFT algebra, windowed
# ---------------------------------------------------------------------------

class _Shard:
    """One Kinesis shard as ``SharedResource``'s virtual-finish-time state,
    advanced in windows instead of per-event heap traffic.  The float
    updates are copied from ``des.SharedResource`` verbatim so completion
    timestamps agree bitwise."""

    __slots__ = ("capacity", "vtime", "last_ts", "heap", "flows",
                 "next_fid", "next_t", "pending")

    def __init__(self, capacity: float) -> None:
        self.capacity = capacity
        self.vtime = 0.0
        self.last_ts = 0.0
        self.heap: list[tuple[float, int]] = []
        self.flows: dict[int, tuple[int, int]] = {}   # fid -> (msg, partition)
        self.next_fid = 0
        self.next_t: float | None = None
        self.pending: deque = deque()    # (submit_ts, msg_idx, partition)

    def submit(self, t: float, work: float, item: tuple[int, int]) -> None:
        n = len(self.flows)
        if n:
            dt = t - self.last_ts
            if dt > 0:
                self.vtime += dt * (self.capacity / n)
        self.last_ts = t
        fid = self.next_fid
        self.next_fid = fid + 1
        self.flows[fid] = item
        heapq.heappush(self.heap, (self.vtime + work, fid))
        delay = max(self.heap[0][0] - self.vtime, 0.0) \
            * (n + 1) / self.capacity
        self.next_t = t + delay

    def complete(self, t: float) -> tuple[int, int]:
        n = len(self.flows)
        dt = t - self.last_ts
        if dt > 0:
            self.vtime += dt * (self.capacity / n)
        self.last_ts = t
        _vtag, fid = heapq.heappop(self.heap)
        item = self.flows.pop(fid)
        if n > 1:
            delay = max(self.heap[0][0] - self.vtime, 0.0) \
                * (n - 1) / self.capacity
            self.next_t = t + delay
        else:
            self.next_t = None
        return item


# ---------------------------------------------------------------------------
# facades: the data plane as plain state, the control plane real
# ---------------------------------------------------------------------------

class _Container:
    __slots__ = ("warm", "busy", "dead", "rec", "uid")

    def __init__(self, uid: int = 0) -> None:
        self.warm = False
        self.busy = False
        self.dead = False
        self.rec: _Invocation | None = None
        self.uid = uid


class _Invocation:
    """One dispatched batch (batch_max == 1: one message).  ``partition``
    is the engine-side partition; ``pin`` the backend placement hint
    (None after a ConnectionError retry unpins); ``profile`` is bound at
    dispatch time, exactly where the scalar ``make_cu_desc`` binds it."""

    __slots__ = ("partition", "msg", "offset", "pin", "deadline", "profile",
                 "start_ts", "settled", "floor")

    def __init__(self, partition: int, msg: int, offset: int,
                 pin: int | None, deadline: float, profile) -> None:
        self.partition = partition
        self.msg = msg
        self.offset = offset
        self.pin = pin
        self.deadline = deadline
        self.profile = profile
        self.start_ts = 0.0
        self.settled = False
        self.floor = 0.0


class _Partition:
    """Broker partition log + consumer state, fused: the fast path has no
    separate broker object, so offsets index straight into ``log``."""

    __slots__ = ("log", "next_offset", "inflight", "retries",
                 "stalled_until")

    def __init__(self) -> None:
        self.log: list[tuple[int, float]] = []    # offset -> (msg, append_ts)
        self.next_offset = 0
        self.inflight = False
        self.retries = 0
        self.stalled_until = 0.0


class _FastBroker:
    """What the ControlLoop (and the fault injector's partition picker)
    sees of the broker: active/total shard counts."""

    __slots__ = ("active", "total")

    def __init__(self, initial: int) -> None:
        self.active = initial
        self.total = initial

    def repartition(self, topic: str, n: int) -> int:
        if n > self.total:
            self.total = n
        self.active = n
        return n

    def num_partitions(self, topic: str) -> int:
        return self.active


class _FastBackend:
    """``ServerlessSimBackend``'s container pool for one pilot, including
    the fault surface (``inject_crash`` / ``preempt`` / restore).  Queue
    and free-pool disciplines are replicated exactly (FIFO queue, MRU free
    deque, busy-first crash victims, reversed-idle-first preempt victims)
    because they fix the *order* in which invocations draw their jitter
    from the shared normal stream."""

    def __init__(self, run, cfg: dict, memory_mb: int,
                 walltime_s: float, n_containers: int) -> None:
        self._run = run
        self.cfg = cfg
        self.memory_mb = memory_mb
        self.walltime_s = walltime_s
        self._next_uid = 0
        self.containers = [self._fresh() for _ in range(max(1, n_containers))]
        self.free = deque(self.containers)
        self.queue: deque = deque()
        self.target = len(self.containers)
        # (profile id, cold) -> (mean, cv): profile objects are cached for
        # the run's lifetime by adaptation_profile_factory, so ids are stable
        self._svc_cache: dict[tuple[int, bool], tuple[float, float]] = {}

    def _fresh(self) -> _Container:
        c = _Container(self._next_uid)
        self._next_uid += 1
        return c

    # -- ControlLoop's Backend surface (pilot arg unused: one pilot) --------
    def allocation(self, pilot=None) -> int:
        return self.target

    def effective_allocation(self, pilot=None) -> int:
        return len(self.containers)

    def scale_to(self, pilot, n: int) -> int:
        n = max(1, min(int(n), int(self.cfg["max_containers"])))
        self.target = n
        containers, free = self.containers, self.free
        while len(containers) > n and free:
            containers.remove(free.pop())
        while len(containers) < n:
            c = self._fresh()
            containers.append(c)
            free.append(c)
        self.dispatch()
        return n

    # -- fault surface -------------------------------------------------------
    def _kill(self, c: _Container) -> None:
        """Container dies under its invocation: the synchronous failure
        runs the engine's retry path inline, exactly like the scalar
        ``cu._set_failed`` → done-callback chain."""
        c.dead = True
        self.containers.remove(c)
        if c in self.free:
            self.free.remove(c)
        rec = c.rec
        c.rec = None
        if rec is not None and not rec.settled:
            self._run.engine.on_final_failed(rec, connection_error=True)

    def inject_crash(self, count: int = 1) -> int:
        victims = [c for c in self.containers if c.busy][:count]
        if len(victims) < count:
            victims += [c for c in self.containers
                        if not c.busy][:count - len(victims)]
        for c in victims:
            self._kill(c)
            fresh = self._fresh()       # instant sandbox restart
            self.containers.append(fresh)
            self.free.append(fresh)
        if victims:
            self.dispatch()
        return len(victims)

    def preempt(self, count: int = 1) -> int:
        idle = [c for c in reversed(self.containers) if not c.busy]
        busy = [c for c in reversed(self.containers) if c.busy]
        victims = (idle + busy)[:count]
        for c in victims:
            self._kill(c)
        n = len(victims)
        if n:
            self._run.sim.schedule_fast(
                float(self.cfg["preempt_restore_s"]),
                lambda: self._restore_preempted(n))
        return n

    def _restore_preempted(self, n: int) -> None:
        restored = 0
        while restored < n and len(self.containers) < self.target:
            c = self._fresh()
            self.containers.append(c)
            self.free.append(c)
            restored += 1
        if restored:
            self.dispatch()

    # -- execution ----------------------------------------------------------
    def submit(self, rec: _Invocation) -> None:
        self.queue.append(rec)
        self.dispatch()

    def dispatch(self) -> None:
        queue, free = self.queue, self.free
        while queue:
            if not free:
                return
            rec = queue.popleft()
            if rec.settled:
                continue
            self._start(rec, free.popleft())

    def _start(self, rec: _Invocation, c: _Container) -> None:
        run = self._run
        sim = run.sim
        profile = rec.profile
        cold = not c.warm
        c.warm = True
        c.busy = True
        c.rec = rec
        key = (id(profile), cold)
        svc = self._svc_cache.get(key)
        if svc is None:
            svc = self._svc_cache[key] = service_time_mean(
                self.cfg, self.memory_mb, profile, cold)
        t_mean, cv = svc
        dt = sim.lognormal_jitter(t_mean, cv)
        if dt > self.walltime_s:
            raise _FallbackNeeded(
                f"invocation needs {dt:.1f}s > walltime {self.walltime_s}s "
                "(walltime-kill/retry path)")
        rec.start_ts = sim.now
        if run.trace is not None:
            run.trace.append((rec.floor, rec.partition, c.uid, t_mean,
                              sim.now + dt))
        sim.schedule_fast(dt, lambda: self._finish(rec, c))

    def _finish(self, rec: _Invocation, c: _Container) -> None:
        if c.dead:
            return                     # killed mid-flight: already failed
        c.busy = False
        c.rec = None
        if len(self.containers) > self.target:
            self.containers.remove(c)      # scale-down landed mid-flight
        else:
            self.free.appendleft(c)
        self._run.engine.on_final_done(rec)
        self.dispatch()


class _FastEngine:
    """``SimStreamingEngine``'s partition consumer + the loop's
    EngineControlSurface, over partition logs filled by either the
    windowed serverless producer or the event-true HPC producer chain.

    Owns the full at-least-once ledger the scalar ``_EngineCore`` keeps:
    committed offsets, idempotent ``seen`` dedupe, retry/backoff with the
    same ``sim.rng`` draws, abandonment, and the completion record stream
    the latency column is computed from."""

    def __init__(self, run, initial: int) -> None:
        self._run = run
        self.parts = [_Partition() for _ in range(initial)]
        self.inflight_n = 0
        self.appended_seen = 0
        self.paused_until = 0.0
        self.completed_runtimes: list[float] = []
        self._straggler_cache = (0, _INF)
        # ledger
        self.processed = 0
        self.abandoned = 0
        self.dup_delivered = 0
        self.duplicates = 0          # batch-level already-committed copies
        self.retried = 0
        self.failed_batches = 0
        self.appended_total = 0
        self.seen: set[int] = set()
        self.append_ts: dict[int, float] = {}     # msg -> producer append ts
        self.completions: list[tuple[int, float]] = []   # (msg, ts) in order

    # -- EngineControlSurface ------------------------------------------------
    def now(self) -> float:
        return self._run.sim.now

    def call_later(self, delay_s: float, fn) -> None:
        # the only call_later client is the ControlLoop's tick chain; wrap
        # it so each tick is followed by the producer/ingest window advance
        # (emissions in [T, T+interval) see the post-tick partition count,
        # exactly as their heap events would).  The HPC run's after_tick is
        # a no-op: its producer is an event chain, not a window.
        run = self._run

        def tick() -> None:
            pre_active = run.broker.active
            fn()
            run.after_tick(pre_active)

        run.sim.schedule_fast(delay_s, tick)

    def repartition(self, migration_s: float = 0.0) -> None:
        total = self._run.broker.total
        parts = self.parts
        while len(parts) < total:
            parts.append(_Partition())
        if migration_s > 0.0:
            sim = self._run.sim
            resume_at = sim.now + migration_s
            if resume_at > self.paused_until:
                self.paused_until = resume_at
                sim.schedule_fast(migration_s, self._resume)

    def _resume(self) -> None:
        if self._run.sim.now < self.paused_until:
            return     # superseded by a longer, later migration pause
        for p in range(len(self.parts)):
            self.drain(p)

    def stall_partition(self, partition: int, duration_s: float) -> None:
        if partition >= len(self.parts):
            self.repartition()
        ps = self.parts[partition]
        until = self._run.sim.now + duration_s
        if until > ps.stalled_until:
            ps.stalled_until = until
            self._run.sim.schedule_fast(duration_s,
                                        lambda: self.drain(partition))

    # -- consumer ------------------------------------------------------------
    def straggler_timeout(self) -> float:
        runtimes = self.completed_runtimes
        n = len(runtimes)
        if n < 3:
            return _INF
        cached_n, cached = self._straggler_cache
        if n != cached_n and (n < 32 or n % 32 == 0 or cached_n < 3):
            cached = max(4.0 * statistics.median(runtimes), 1e-3)
            self._straggler_cache = (n, cached)
        return cached

    def on_append(self, msg: int, partition: int, ts: float) -> None:
        self.appended_total += 1
        if msg not in self.append_ts:
            self.append_ts[msg] = ts      # producer append; dup re-appends
        self.appended_seen += 1           # never write "append" rows
        if partition >= len(self.parts):
            self.repartition()
        self.parts[partition].log.append((msg, ts))
        self.drain(partition)

    def drain(self, partition: int) -> None:
        run = self._run
        now = run.sim.now
        if now < self.paused_until:
            return     # migrating: the resume sweep re-drains everything
        if partition >= len(self.parts):
            self.repartition()
        ps = self.parts[partition]
        if now < ps.stalled_until:
            return     # stalled shard: the expiry event re-drains
        if ps.inflight:
            return
        if ps.next_offset >= len(ps.log):
            return     # empty fetch
        msg, append_ts = ps.log[ps.next_offset]
        ps.inflight = True
        self.inflight_n += 1
        ps.retries = 0
        floor = max(append_ts, self.paused_until, ps.stalled_until)
        self.dispatch(partition, msg, ps.next_offset, pinned=True,
                      floor=floor)

    def dispatch(self, partition: int, msg: int, offset: int,
                 pinned: bool, floor: float = 0.0) -> None:
        run = self._run
        sim = run.sim
        timeout = self.straggler_timeout()
        deadline = sim.now + timeout if timeout != _INF else _INF
        rec = _Invocation(partition, msg, offset,
                          partition if pinned else None, deadline,
                          run.profile_for(None))
        rec.floor = floor
        run.backend.submit(rec)
        # the straggler watchdog is armed AFTER submit, exactly where the
        # scalar _dispatch arms it — at an exact-timestamp tie with the
        # invocation's finish, heap seq order decides speculation just as
        # it does on the scalar path (cancellation is a settled-check: the
        # scalar cancel only tombstones the event)
        if timeout != _INF:
            sim.schedule_fast(timeout, lambda: self._straggler_check(rec))

    def _straggler_check(self, rec: _Invocation) -> None:
        if rec.settled:
            return            # scalar: event cancelled at cu finality
        ps = self.parts[rec.partition]
        if rec.offset + 1 <= ps.next_offset:
            return            # a duplicate copy already committed the batch
        # at most ONE unpinned backup copy per attempt (speculate=False):
        # the copy arms no watchdog of its own
        run = self._run
        dup = _Invocation(rec.partition, rec.msg, rec.offset, None, _INF,
                          run.profile_for(None))
        dup.floor = rec.floor
        run.backend.submit(dup)

    def retry_delay(self, attempt: int) -> float:
        run = self._run
        base = run.exp.retry_backoff_s
        if base <= 0.0:
            return 0.0
        delay = base * (2.0 ** (attempt - 1))
        delay *= 0.5 + run.sim.rng.random()
        return min(delay, _RETRY_CAP_S)

    def on_final_done(self, rec: _Invocation) -> None:
        run = self._run
        now = run.sim.now
        rec.settled = True
        ps = self.parts[rec.partition]
        if rec.offset + 1 <= ps.next_offset:
            self.duplicates += 1          # a duplicate copy already committed
            return
        ps.next_offset = rec.offset + 1
        if rec.msg in self.seen:
            self.dup_delivered += 1       # redelivery absorbed idempotently
        else:
            self.seen.add(rec.msg)
            self.processed += 1
            self.completions.append((rec.msg, now))
        self.completed_runtimes.append(now - rec.start_ts)
        ps.inflight = False
        self.inflight_n -= 1
        self.drain(rec.partition)

    def on_final_failed(self, rec: _Invocation,
                        connection_error: bool) -> None:
        run = self._run
        now = run.sim.now
        rec.settled = True
        ps = self.parts[rec.partition]
        if rec.offset + 1 <= ps.next_offset:
            return                        # a duplicate copy already committed
        if ps.retries < run.exp.max_retries:
            ps.retries += 1
            self.retried += 1
            # ConnectionError (container/worker death) unpins: any
            # replacement may serve the batch
            pinned = not connection_error
            delay = self.retry_delay(ps.retries)
            if delay > 0.0:
                run.sim.schedule_fast(
                    delay, lambda: self.dispatch(rec.partition, rec.msg,
                                                 rec.offset, pinned))
            else:
                self.dispatch(rec.partition, rec.msg, rec.offset, pinned)
        else:
            self.failed_batches += 1
            self.abandoned += 1           # batch_max == 1: one message
            ps.next_offset = rec.offset + 1
            ps.inflight = False
            self.inflight_n -= 1
            self.drain(rec.partition)

    def is_finished(self) -> bool:
        run = self._run
        if not run.producer_done:
            return False
        if self.inflight_n or (self.processed + self.abandoned
                               + self.dup_delivered) < self.appended_seen:
            return False
        return all(ps.next_offset >= len(ps.log) and not ps.inflight
                   for ps in self.parts)


class _FastInjector:
    """``FaultInjector`` against the fast facades: the same counters, the
    same round-robin partition picker, the same fire-time action order.
    Events are armed directly on the simulator at setup (before the first
    producer/append events are scheduled), so equal-timestamp collisions
    resolve exactly as the scalar assembly order resolves them
    (injector.start() precedes loop.start(); appends are runtime
    events)."""

    def __init__(self, run, events: list) -> None:
        self._run = run
        self.events = events
        self.injected = 0
        self.crashes = 0
        self.preemptions = 0
        self.stalls = 0
        self.dup_injected = 0
        self.skipped = 0
        self._rr = 0
        self._fired_since_probe = 0
        self._stall_until = 0.0

    def start(self) -> int:
        sim = self._run.sim
        for ev in self.events:
            sim.schedule_fast(ev.t, lambda ev=ev: self._fire(ev))
        return len(self.events)

    def window_dirty(self) -> bool:
        dirty = self._fired_since_probe > 0 \
            or self._run.sim.now < self._stall_until
        self._fired_since_probe = 0
        return dirty

    def _pick_partition(self, ev) -> int:
        n = max(1, self._run.broker.num_partitions("points"))
        if ev.target is not None:
            return ev.target % n
        self._rr += 1
        return (self._rr - 1) % n

    def _fire(self, ev) -> None:
        run = self._run
        self.injected += 1
        self._fired_since_probe += 1
        acted = 0
        if ev.kind == "crash":
            acted = run.backend.inject_crash(ev.count)
            self.crashes += acted
        elif ev.kind == "preempt":
            acted = run.backend.preempt(ev.count)
            self.preemptions += acted
        elif ev.kind == "stall":
            p = self._pick_partition(ev)
            run.engine.stall_partition(p, ev.duration_s)
            until = run.sim.now + ev.duration_s
            self._stall_until = max(self._stall_until, until)
            self.stalls += 1
            acted = 1
        elif ev.kind == "duplicate":
            acted = self._inject_duplicate(ev)
        # backend_outage / grant_starvation: the sim backends expose no
        # hook, exactly like the scalar getattr(...) miss — skipped
        if not acted:
            self.skipped += 1

    def _inject_duplicate(self, ev) -> int:
        run = self._run
        p = self._pick_partition(ev)
        if p >= len(run.engine.parts):
            run.engine.repartition()
        plog = run.engine.parts[p].log
        if not plog:
            return 0
        msg, _ts = plog[-1]     # newest offset, original stable msg_id
        run.engine.on_append(msg, p, run.sim.now)
        self.dup_injected += 1
        return 1


class _FastMetrics:
    """The MetricRegistry surface the ControlLoop consumes, O(1) per call:
    ``produce`` counts walk the shared emission schedule (windowed
    serverless run) or read the producer chain's counter (HPC run),
    ``complete`` counts read the processed counter, trace emission is
    dropped (the summary carries no event columns)."""

    def __init__(self, run) -> None:
        self._run = run
        self._produce_i = 0

    def kind_count(self, run_id: str, kind: str) -> int:
        run = self._run
        if kind == "produce":
            if not run.windowed:
                return run.produce_count
            emit = run.emit_times
            first = run.boundary_first
            now = run.sim.now
            i = self._produce_i
            n = len(emit)
            # an emission exactly at a tick timestamp counts iff its heap
            # event popped before the tick's (precomputed boundary order)
            while i < n and (emit[i] < now or (emit[i] == now and first[i])):
                i += 1
            self._produce_i = i
            return i
        if kind == "complete":
            return run.engine.processed
        return 0

    def observe(self, name: str, ts: float, value: float) -> None:
        pass

    def record(self, *args, **kwargs) -> None:
        pass


class _FastPilot:
    __slots__ = ("backend",)

    def __init__(self, backend) -> None:
        self.backend = backend


def _initial_partitions(exp: AdaptationExperiment) -> int:
    static_n = (exp.static_partitions if exp.static_partitions is not None
                else exp.max_partitions)
    initial = static_n if exp.scaling_policy == "static" \
        else exp.initial_partitions
    return max(1, min(initial, exp.max_partitions))


def _build_summary(run, drained: bool) -> AdaptationSummary:
    """The report card, from the engine's ledger — field-for-field what
    ``summarize_adaptation`` computes from the scalar run.  ``lost`` is
    the settled-ledger residue (appends not settled as processing,
    abandonment or duplicate absorption): an undrained run counts its
    in-flight backlog as lost, exactly as the scalar path does."""
    loop = run.loop
    eng = run.engine
    sim = run.sim
    inj = run.injector
    # the scalar latency column: complete records in completion order,
    # paired against the producer's append record for that msg_id
    append_ts = eng.append_ts
    lat = [ts - append_ts[m] for m, ts in eng.completions]
    settled = eng.processed + eng.abandoned + eng.dup_delivered
    wall = max(sim.now, 1e-9)
    return AdaptationSummary(
        experiment=run.plan,
        slo_violations=loop.slo_violations,
        ticks=loop.ticks,
        cost_integral=loop.cost_integral,
        scale_events=loop.scale_events,
        produced=run.produced_count(),
        processed=eng.processed,
        throughput=eng.processed / wall,
        latency_px=percentile_summary(np.asarray(lat, dtype=np.float64)),
        final_allocation=loop.allocation,
        drained=drained,
        drain_s=max(0.0, sim.now - run.exp.horizon_s),
        refits=loop.refit_events,
        abandoned=eng.abandoned,
        dup_delivered=eng.dup_delivered,
        faults_injected=inj.injected if inj is not None else 0,
        preemptions=inj.preemptions if inj is not None else 0,
        fault_windows=loop.fault_windows,
        lost=eng.appended_total - settled,
        member_ledger=[],
        fast_path=True, fallback_reason=None)


# ---------------------------------------------------------------------------
# the serverless replay driver
# ---------------------------------------------------------------------------

class _FastRun:
    """One eligible serverless cell, replayed: real Simulator +
    ControlLoop/policy, columnar producer/ingest, event-true
    backend/engine facades, fault events spliced from the pre-expanded
    plan."""

    windowed = True

    def __init__(self, plan: AdaptationPlan, trace: list | None = None) -> None:
        exp = plan.experiment
        self.plan = plan
        self.exp = exp
        self.sim = Simulator(seed=exp.seed)
        self.trace = trace

        initial = _initial_partitions(exp)

        cfg = dict(DEFAULTS)
        cfg.update(exp.backend_attrs)
        n_containers = min(initial, int(cfg["max_containers"]))

        program = rate_program_from_spec(exp.rate)
        cap = int(program.mean_messages(0.0, exp.horizon_s) * 2 + 1000)
        self.emit_times, self.finish_t, sched_times = _emission_schedule(
            exp.rate, exp.horizon_s, cap)
        self.sent_total = len(self.emit_times)
        self.wl_work = float(exp.points * POINT_BYTES)

        # exact-float collisions between producer program events and control
        # ticks (a 2 Hz grid meeting 2 s ticks does this every boundary):
        # resolve each once, up front
        interval = exp.control_interval_s
        tick_set = _tick_times(interval, max(self.finish_t,
                                             self.emit_times[-1]
                                             if self.emit_times else 0.0))
        self.boundary_first = [
            t in tick_set
            and _program_beats_tick(t, sched_times[i], interval)
            for i, t in enumerate(self.emit_times)]
        self.finish_at_tick_after = (
            self.finish_t in tick_set
            and not _program_beats_tick(self.finish_t, sched_times[-1],
                                        interval))

        self.broker = _FastBroker(initial)
        self.backend = _FastBackend(self, cfg, exp.memory_mb,
                                    _WALLTIME_S, n_containers)
        self.engine = _FastEngine(self, initial)
        self.metrics = _FastMetrics(self)
        self.profile_for = adaptation_profile_factory(
            exp, lambda: self.sim.now, lambda: self.loop.allocation)
        self.shards = [_Shard(_INGEST_BW) for _ in range(exp.max_partitions)]

        self.producer_appended = 0
        self.production_over = False
        self.producer_done = False
        self._next_emit = 0

        if exp.faults:
            _plan, events = expand_plan(exp.faults, default_seed=exp.seed,
                                        default_horizon_s=exp.horizon_s)
            self.injector = _FastInjector(self, events)
        else:
            self.injector = None

        self.loop = ControlLoop(
            self.engine, self.broker, "points", _FastPilot(self.backend),
            policy_from_spec(scaling_policy_spec(exp), initial=initial),
            metrics=self.metrics, run_id="fast",
            interval_s=exp.control_interval_s, slo_lag=exp.slo_lag,
            migration_s_per_delta=exp.migration_s_per_delta,
            fault_signal=(self.injector.window_dirty
                          if self.injector is not None else None))

    def produced_count(self) -> int:
        return self.sent_total

    # -- producer/ingest window machinery -----------------------------------
    def _assign_window(self, window_end: float, pre_active: int) -> None:
        """Assign emissions in [sim.now, window_end) to partitions and step
        each shard's VFT state up to the window's append horizon."""
        emit = self.emit_times
        first = self.boundary_first
        shards = self.shards
        n_shards = len(shards)
        active = self.broker.active
        now = self.sim.now
        i = self._next_emit
        n = len(emit)
        while i < n and emit[i] < window_end:
            t = emit[i]
            # an emission that popped before this tick saw the pre-tick
            # partition count
            p = i % (pre_active if (t == now and first[i]) else active)
            shards[p % n_shards].pending.append(
                (t + _REQUEST_LATENCY, i, p))
            i += 1
        self._next_emit = i
        bound = window_end + _REQUEST_LATENCY
        for sh in shards:
            self._drain_shard(sh, bound)

    def _drain_shard(self, sh: _Shard, bound: float) -> None:
        """Run one shard's submit/complete events with timestamps < bound
        (no later submit can predate ``bound``, so every completion this
        finalizes is final)."""
        pending = sh.pending
        while True:
            t_sub = pending[0][0] if pending else _INF
            t_comp = sh.next_t if sh.next_t is not None else _INF
            if t_comp <= t_sub:
                if t_comp >= bound:
                    return
                msg, part = sh.complete(t_comp)
                self._schedule_append(t_comp, msg, part)
            else:
                if t_sub >= bound:
                    return
                _ts, msg, part = pending.popleft()
                sh.submit(t_sub, self.wl_work, (msg, part))

    def _schedule_append(self, t: float, msg: int, partition: int) -> None:
        def append() -> None:
            self.engine.on_append(msg, partition, t)
            self.producer_appended += 1
            if self.production_over \
                    and self.producer_appended >= self.sent_total:
                self.producer_done = True

        self.sim.schedule_at(t, append)

    def _finish_production(self) -> None:
        self.production_over = True
        if self.producer_appended >= self.sent_total:
            self.producer_done = True

    def after_tick(self, pre_active: int) -> None:
        now = self.sim.now
        if self.finish_at_tick_after and not self.production_over \
                and self.finish_t == now:
            self._finish_production()
        self._assign_window(now + self.exp.control_interval_s, pre_active)

    # -- run -----------------------------------------------------------------
    def run(self) -> AdaptationSummary:
        exp = self.exp
        sim = self.sim
        # fault events are armed first: their setup-order heap seqs beat
        # every same-timestamp runtime event, exactly as the scalar
        # injector.start() (before loop.start(), appends runtime) does
        if self.injector is not None:
            self.injector.start()
        # production-over event (unless it resolves after a colliding tick,
        # which after_tick handles at that exact timestamp)
        if not self.finish_at_tick_after:
            self.sim.schedule_at(self.finish_t, self._finish_production)
        # the pre-first-tick window: assigned at setup, like the producer's
        # t=0 start event
        self._assign_window(exp.control_interval_s, self.broker.active)
        self.loop.start()
        max_virtual = exp.horizon_s * 6.0 + 600.0
        sim.run_until(t=sim.now + max_virtual,
                      predicate=self.engine.is_finished)
        drained = self.engine.is_finished()
        self.loop.stop()
        return _build_summary(self, drained)


# ---------------------------------------------------------------------------
# the HPC replay driver: event-true coupled chain
# ---------------------------------------------------------------------------

class _HpcWorker:
    __slots__ = ("wid", "busy", "alive", "pending", "retired", "queue",
                 "current")

    def __init__(self, wid: int, pending: bool = False) -> None:
        self.wid = wid
        self.busy = False
        self.alive = True
        self.pending = pending
        self.retired = False
        self.queue: deque = deque()
        self.current: "_HpcTask | None" = None


class _HpcBackend:
    """``HpcSimBackend`` for one pilot: serial scheduler, worker pool with
    batch-queue grant waits, eviction/regrant fault surface.  The shared
    filesystem and the model lock are *real* DES primitives on the replay
    simulator — the coupling chain (arrival I/O → jittered compute →
    locked critical section → write-back + coherence I/O) serializes
    across partitions exactly as the scalar backend's ``_TaskExec`` does,
    with the phase terms imported from ``hpcsim.coupling_terms``."""

    def __init__(self, run, cfg: dict, n_workers: int, seed: int) -> None:
        self._run = run
        self.cfg = cfg
        self.workers = [_HpcWorker(i) for i in range(max(1, n_workers))]
        self.fs = SharedResource(run.sim, cfg["fs_bw"], name="lustre")
        self.model_lock = SimLock(run.sim, name="model")
        self.sched_queue: deque = deque()
        self.sched_busy = False
        self.target = max(1, n_workers)
        self._mapping_cache: list[_HpcWorker] | None = None
        # the scalar backend's per-pilot queue-wait stream: run_adaptation's
        # first (only) pilot has uid 0
        self.queue_rng = np.random.default_rng([seed, 0])

    def _queue_wait(self) -> float:
        return queue_wait_sample(self.cfg, self.queue_rng)

    def _mapping(self) -> list[_HpcWorker]:
        m = self._mapping_cache
        if m is None:
            m = self._mapping_cache = [w for w in self.workers
                                       if not w.retired]
        return m

    # -- ControlLoop's Backend surface --------------------------------------
    def allocation(self, pilot=None) -> int:
        return self.target

    def effective_allocation(self, pilot=None) -> int:
        return sum(1 for w in self.workers
                   if not w.retired and not w.pending)

    def scale_to(self, pilot, n: int) -> int:
        n = max(1, int(n))
        self.target = n
        workers = self.workers
        active = [w for w in workers if not w.retired]
        if n > len(active):
            for _ in range(n - len(active)):
                w = _HpcWorker(len(workers), pending=True)
                workers.append(w)

                def grant(w: _HpcWorker = w) -> None:
                    w.pending = False
                    self._pump_worker(w)

                self._run.sim.schedule_fast(self._queue_wait(), grant)
        elif n < len(active):
            victims = active[n:]
            for w in victims:
                w.retired = True
            self._mapping_cache = None
            for w in victims:
                orphans = [r for r in w.queue if not r.settled]
                w.queue.clear()
                for r in orphans:
                    self._assign(r)
        self._mapping_cache = None
        return n

    # -- fault surface -------------------------------------------------------
    def _evict(self, w: _HpcWorker) -> None:
        w.pending = True
        task = w.current
        if task is not None and not task.rec.settled:
            self._run.engine.on_final_failed(task.rec, connection_error=True)
        orphans = [r for r in w.queue if not r.settled]
        w.queue.clear()

        def regrant(w: _HpcWorker = w) -> None:
            w.pending = False
            self._pump_worker(w)

        self._run.sim.schedule_fast(self._queue_wait(), regrant)
        for r in orphans:
            self._assign(r)

    def inject_crash(self, count: int = 1) -> int:
        granted = [w for w in self.workers
                   if w.alive and not w.retired and not w.pending]
        busy = [w for w in granted if w.busy]
        idle = [w for w in granted if not w.busy]
        victims = (busy + idle)[:count]
        for w in victims:
            self._evict(w)
        return len(victims)

    def preempt(self, count: int = 1) -> int:
        granted = [w for w in self.workers
                   if w.alive and not w.retired and not w.pending]
        victims = granted[-count:] if count > 0 else []
        for w in victims:
            self._evict(w)
        return len(victims)

    # -- serial scheduler ----------------------------------------------------
    def submit(self, rec: _Invocation) -> None:
        self.sched_queue.append(rec)
        self._pump_scheduler()

    def _pump_scheduler(self) -> None:
        if self.sched_busy or not self.sched_queue:
            return
        self.sched_busy = True
        rec = self.sched_queue.popleft()

        def dispatched() -> None:
            self.sched_busy = False
            if not rec.settled:
                self._assign(rec)
            self._pump_scheduler()

        self._run.sim.schedule_fast(self.cfg["dispatch_s"], dispatched)

    def _assign(self, rec: _Invocation) -> None:
        mapping = self._mapping()
        if rec.pin is not None:
            w = mapping[rec.pin % len(mapping)]
            if not w.alive:
                self._run.engine.on_final_failed(rec, connection_error=True)
                return
        else:
            alive = [w for w in mapping if w.alive]
            if not alive:
                self._run.engine.on_final_failed(rec, connection_error=True)
                return
            w = min(alive, key=lambda w: (w.pending,
                                          len(w.queue) + (1 if w.busy else 0),
                                          w.wid))
        w.queue.append(rec)
        self._pump_worker(w)

    # -- worker execution ----------------------------------------------------
    def _pump_worker(self, w: _HpcWorker) -> None:
        if w.busy or w.pending or not w.queue or not w.alive:
            return
        rec = w.queue.popleft()
        if rec.settled:
            self._pump_worker(w)
            return
        w.busy = True
        rec.start_ts = self._run.sim.now
        task = _HpcTask(self, w, rec)
        w.current = task
        self.fs.submit(task.arrival_io, task.phase_compute)


class _HpcTask:
    """``hpcsim._TaskExec``'s phase chain against the fast facades, on the
    *real* shared-FS resource and model lock.  An evicted worker's chain
    keeps running to completion (the scalar "phantom" semantics: the
    already-failed CU's phases still consume jitter draws, FS bandwidth
    and lock hold time) — only the final settle is skipped."""

    __slots__ = ("backend", "w", "rec", "arrival_io", "compute_mean",
                 "critical_mean", "write_io")

    def __init__(self, backend: _HpcBackend, w: _HpcWorker,
                 rec: _Invocation) -> None:
        self.backend = backend
        self.w = w
        self.rec = rec
        (self.arrival_io, self.compute_mean, self.critical_mean,
         self.write_io) = coupling_terms(backend.cfg, rec.profile)

    def phase_compute(self) -> None:
        sim = self.backend._run.sim
        sim.schedule_fast(sim.lognormal_jitter(self.compute_mean,
                                               self.backend.cfg["jitter_cv"]),
                          self.phase_model_update)

    def phase_model_update(self) -> None:
        self.backend.model_lock.acquire(self.in_critical_section)

    def in_critical_section(self) -> None:
        sim = self.backend._run.sim
        sim.schedule_fast(sim.lognormal_jitter(self.critical_mean,
                                               self.backend.cfg["jitter_cv"]),
                          self.do_io)

    def do_io(self) -> None:
        self.backend.fs.submit(self.write_io, self.unlock)

    def unlock(self) -> None:
        self.backend.model_lock.release()
        self.finish()

    def finish(self) -> None:
        backend, w, rec = self.backend, self.w, self.rec
        w.busy = False
        w.current = None
        if not rec.settled:
            backend._run.engine.on_final_done(rec)
        backend._pump_worker(w)


class _HpcFastRun:
    """One eligible wrangler/stampede2 cell, replayed event-true: the
    producer is a linked chain of program events feeding the shared
    filesystem (``SharedFsIngest`` couples appends with task I/O, so HPC
    appends cannot be windowed), the backend is the coupled-chain facade
    above, the control plane is real."""

    windowed = False

    def __init__(self, plan: AdaptationPlan) -> None:
        exp = plan.experiment
        self.plan = plan
        self.exp = exp
        self.sim = Simulator(seed=exp.seed)
        self.trace = None

        initial = _initial_partitions(exp)

        cfg = dict(HPC_DEFAULTS)
        cfg.update(MACHINES[exp.machine])
        cfg.update(exp.backend_attrs)

        self.program = rate_program_from_spec(exp.rate)
        self.cap = int(self.program.mean_messages(0.0, exp.horizon_s) * 2
                       + 1000)
        self.wl_bytes = exp.points * POINT_BYTES

        self.broker = _FastBroker(initial)
        self.backend = _HpcBackend(self, cfg, initial, exp.seed)
        self.engine = _FastEngine(self, initial)
        self.metrics = _FastMetrics(self)
        self.profile_for = adaptation_profile_factory(
            exp, lambda: self.sim.now, lambda: self.loop.allocation)

        self.sent = 0
        self.produce_count = 0
        self.producer_appended = 0
        self.production_over = False
        self.producer_done = False

        if exp.faults:
            _plan, events = expand_plan(exp.faults, default_seed=exp.seed,
                                        default_horizon_s=exp.horizon_s)
            self.injector = _FastInjector(self, events)
        else:
            self.injector = None

        self.loop = ControlLoop(
            self.engine, self.broker, "points", _FastPilot(self.backend),
            policy_from_spec(scaling_policy_spec(exp), initial=initial),
            metrics=self.metrics, run_id="fast",
            interval_s=exp.control_interval_s, slo_lag=exp.slo_lag,
            migration_s_per_delta=exp.migration_s_per_delta,
            fault_signal=(self.injector.window_dirty
                          if self.injector is not None else None))

    def produced_count(self) -> int:
        return self.sent

    def after_tick(self, pre_active: int) -> None:
        pass     # the producer is an event chain, nothing to advance

    # -- producer chain: SyntheticProducer._tick_program, event-true ---------
    def _producer_tick(self) -> None:
        now = self.sim.now
        if now >= self.exp.horizon_s or self.sent >= self.cap:
            self._finish_production()
            return
        rate = self.program.rate(now)
        if rate <= 1e-9:
            self.sim.schedule_fast(_IDLE_RESOLUTION_S, self._producer_tick)
            return
        self._emit_one()
        self.sim.schedule_fast(1.0 / rate, self._producer_tick)

    def _emit_one(self) -> None:
        i = self.sent
        self.sent += 1
        partition = i % self.broker.active     # key=None routing, emit-time
        self.produce_count += 1                # the "produce" metric record
        size = float(self.wl_bytes)
        # SharedFsIngest: request latency, then the append bytes ride the
        # same Lustre resource the task I/O uses
        self.sim.schedule_fast(
            _FS_REQUEST_LATENCY,
            lambda: self.backend.fs.submit(size,
                                           lambda: self._append(i, partition)))

    def _append(self, msg: int, partition: int) -> None:
        self.engine.on_append(msg, partition, self.sim.now)
        self.producer_appended += 1
        if self.production_over and self.producer_appended >= self.sent:
            self.producer_done = True

    def _finish_production(self) -> None:
        self.production_over = True
        if self.producer_appended >= self.sent:
            self.producer_done = True

    # -- run -----------------------------------------------------------------
    def run(self) -> AdaptationSummary:
        exp = self.exp
        sim = self.sim
        # scalar assembly order: producer.start() (t=0 program tick), the
        # engine's initial empty drains (no-ops: nothing appended before
        # t > 0 — skipped), injector.start(), loop.start()
        sim.schedule_fast(0.0, self._producer_tick)
        if self.injector is not None:
            self.injector.start()
        self.loop.start()
        max_virtual = exp.horizon_s * 6.0 + 600.0
        sim.run_until(t=sim.now + max_virtual,
                      predicate=self.engine.is_finished)
        drained = self.engine.is_finished()
        self.loop.stop()
        return _build_summary(self, drained)


def _tick_times(interval_s: float, t_max: float) -> frozenset[float]:
    """The exact float timestamps of the tick chain i, 2i, 3i, ... ≤ t_max
    (each produced by repeated ``now + interval`` float sums — NOT k * i,
    which can differ in the last ulp)."""
    if interval_s <= 0.0:
        return frozenset()
    ticks = []
    acc = 0.0
    while True:
        acc += interval_s
        if acc > t_max:
            return frozenset(ticks)
        ticks.append(acc)


def _ineligible(exp: AdaptationExperiment) -> str | None:
    if exp.engine != "sim":
        return f"engine={exp.engine!r} (wall clock is not replayable)"
    if exp.machine == "federated":
        return "federated machine (member routing/breaker state machine)"
    if exp.machine != "serverless" and exp.machine not in MACHINES:
        return f"machine={exp.machine!r} (no fast facade)"
    if exp.batch_max != 1:
        return f"batch_max={exp.batch_max} (replay models 1 msg/invocation)"
    if exp.machine == "serverless":
        cfg = dict(DEFAULTS)
        cfg.update(exp.backend_attrs)
        profile = KMeansStreamWorkload(
            points=exp.points, centroids=exp.centroids,
            policy=exp.effective_policy, n_partitions=1).profile()
        if profile.memory_mb > min(exp.memory_mb, cfg["memory_cap_mb"]):
            return ("working set exceeds container memory "
                    "(failure/retry path)")
    return None


def try_fast_adaptation(
        plan: AdaptationPlan) -> tuple[AdaptationSummary | None, str | None]:
    """Replay ``plan`` on the batched fast path if it qualifies.

    Returns ``(summary, None)`` on success or ``(None, reason)`` when the
    cell is ineligible or leaves the fast regime mid-run; the reason is
    logged and the caller reruns the cell on the scalar DES.  Static
    declines log at DEBUG (expected, one per ineligible cell of a grid);
    mid-run ``_FallbackNeeded`` bails log at INFO (the replay started and
    discovered the cell left the fast regime — worth seeing)."""
    exp = plan.experiment
    reason = _ineligible(exp)
    if reason is None:
        try:
            if exp.machine == "serverless":
                return _FastRun(plan).run(), None
            return _HpcFastRun(plan).run(), None
        except _FallbackNeeded as fb:
            reason = str(fb)
            log.info("fast replay fallback (%s/%s seed %d): %s",
                     exp.machine, exp.scaling_policy, exp.seed, reason)
            return None, reason
    log.debug("fast replay ineligible (%s/%s seed %d): %s",
              exp.machine, exp.scaling_policy, exp.seed, reason)
    return None, reason


# ---------------------------------------------------------------------------
# jax lockstep: S seeds of a static single-partition cell in one vmap
# ---------------------------------------------------------------------------

# float32 agreement bound for the jax path vs the float64 scalar DES.  The
# scan is a few thousand fused multiply/exp/max ops; observed worst-case
# relative error is ~1e-6, the gate leaves an order of magnitude of head
# room.  The lockstep paths are informational (perf rows, tolerance
# tests) — tournament results always come from the bit-exact replay above.
LOCKSTEP_RTOL = 1e-4


def lockstep_eligibility(exp: AdaptationExperiment) -> str | None:
    """The lockstep scan collapses the whole cell to one recurrence
    ``finish[i] = max(append[i], finish[i-1]) + dt[i]`` — valid only when
    nothing can reorder or replicate invocations."""
    base = _ineligible(exp)
    if base is not None:
        return base
    if exp.machine != "serverless":
        return f"machine={exp.machine!r} (lockstep models the container pool)"
    if exp.faults:
        return "fault plan present (per-seed schedules diverge structurally)"
    if exp.scaling_policy != "static":
        return (f"scaling_policy={exp.scaling_policy!r} (lockstep needs a "
                "static allocation: no scale/migration events)")
    static_n = (exp.static_partitions if exp.static_partitions is not None
                else exp.max_partitions)
    if static_n != 1:
        return (f"static_partitions={static_n} (lockstep models one "
                "partition, one container)")
    if exp.drift_t_s is not None:
        return "cost drift present (service time becomes time-dependent)"
    return None


def lockstep_completion_times(exp: AdaptationExperiment, seeds: list[int],
                              with_appends: bool = False) -> np.ndarray:
    """Per-message completion timestamps for S seeds of one qualifying
    cell, advanced in lockstep (jax ``vmap`` over the seed axis when jax is
    importable, a numpy scan otherwise — same arithmetic, float32 both
    ways).

    The jitter draws come from ``Simulator.normals`` — the same 256-block
    stream the scalar DES consumes — so seed s's column sees exactly the
    draws scalar seed s would; only the float width differs.

    ``with_appends=True`` additionally returns the (seed-independent)
    broker-append timestamps — ``finishes - appends`` is the pipeline
    latency the scalar DES reports in ``latency_px``, the quantity the
    ``LOCKSTEP_RTOL`` agreement contract is stated against.
    """
    reason = lockstep_eligibility(exp)
    if reason is not None:
        raise ValueError(f"cell does not qualify for lockstep: {reason}")

    program = rate_program_from_spec(exp.rate)
    cap = int(program.mean_messages(0.0, exp.horizon_s) * 2 + 1000)
    emit_times, _finish_t, _sched = _emission_schedule(
        exp.rate, exp.horizon_s, cap)
    n_msgs = len(emit_times)

    # append times: one shard, no RNG — identical across seeds
    shard = _Shard(_INGEST_BW)
    work = float(exp.points * POINT_BYTES)
    appends = np.empty(n_msgs, dtype=np.float64)
    for i, t in enumerate(emit_times):
        shard.pending.append((t + _REQUEST_LATENCY, i, 0))
    # one unbounded drain: every submit is already queued in time order
    out: list[tuple[float, int]] = []
    pending = shard.pending
    while pending or shard.next_t is not None:
        t_sub = pending[0][0] if pending else _INF
        t_comp = shard.next_t if shard.next_t is not None else _INF
        if t_comp <= t_sub:
            msg, _p = shard.complete(t_comp)
            out.append((t_comp, msg))
        else:
            _ts, msg, _p = pending.popleft()
            shard.submit(t_sub, work, (msg, 0))
    for t, msg in out:
        appends[msg] = t

    # per-message service-time means: first invocation cold, rest warm
    profile = KMeansStreamWorkload(
        points=exp.points, centroids=exp.centroids,
        policy=exp.effective_policy, n_partitions=1).profile()
    cfg = dict(DEFAULTS)
    cfg.update(exp.backend_attrs)
    mean_cold, cv = service_time_mean(cfg, exp.memory_mb, profile, True)
    mean_warm, _cv = service_time_mean(cfg, exp.memory_mb, profile, False)
    means = np.full(n_msgs, mean_warm)
    if n_msgs:
        means[0] = mean_cold

    # the scalar stream's draws, per seed (bit-identical block consumption)
    z = np.stack([Simulator(seed=s).normals(n_msgs) for s in seeds])
    sigma2 = math.log1p(cv * cv)
    a, b = -0.5 * sigma2, math.sqrt(sigma2)

    try:
        import jax
        import jax.numpy as jnp

        def chain(z_row):
            dt = jnp.asarray(means, dtype=jnp.float32) \
                * jnp.exp(a + b * z_row.astype(jnp.float32))
            ap = jnp.asarray(appends, dtype=jnp.float32)

            def step(prev_finish, inputs):
                append_t, dt_i = inputs
                finish = jnp.maximum(append_t, prev_finish) + dt_i
                return finish, finish

            _last, finishes = jax.lax.scan(step, jnp.float32(0.0), (ap, dt))
            return finishes

        finishes = np.asarray(jax.vmap(chain)(jnp.asarray(z)))
        return (finishes, appends) if with_appends else finishes
    except ImportError:     # pragma: no cover - jax is in the image
        dt = means.astype(np.float32)[None, :] \
            * np.exp(a + b * z.astype(np.float32))
        ap = appends.astype(np.float32)
        finishes = np.empty((len(seeds), n_msgs), dtype=np.float32)
        prev = np.zeros(len(seeds), dtype=np.float32)
        for i in range(n_msgs):
            prev = np.maximum(ap[i], prev) + dt[:, i]
            finishes[:, i] = prev
        return (finishes, appends) if with_appends else finishes


# ---------------------------------------------------------------------------
# cross-cell grid lockstep: S seeds of a controller-driven cell in one vmap
# ---------------------------------------------------------------------------

def grid_lockstep_eligibility(exp: AdaptationExperiment) -> str | None:
    """The grid scan freezes the reference seed's dispatch trajectory and
    replays every seed's jitter through it — sound only when the
    trajectory's *structure* (assignment, retries) is not itself
    draw-dependent."""
    base = _ineligible(exp)
    if base is not None:
        return base
    if exp.machine != "serverless":
        return (f"machine={exp.machine!r} (grid lockstep models the "
                "serverless container pool)")
    if exp.faults:
        return "fault plan present (per-seed schedules diverge structurally)"
    return None


def grid_lockstep_completion_times(
        exp: AdaptationExperiment, seeds: list[int],
        with_reference: bool = False) -> np.ndarray:
    """Per-invocation completion timestamps for S seeds of one
    controller-driven cell in a single ``vmap``-ed scan — the cross-cell
    lift of ``lockstep_completion_times``.

    One *reference* replay (``seeds[0]``, the bit-exact ``_FastRun``)
    records the dispatch trajectory in start order: for each invocation
    its exogenous ready floor (append time, migration pauses, stalls),
    its partition, its container, and its service-time mean.  The frozen
    trajectory turns every seed's completion chain into the double
    recurrence

        ``finish[k] = max(floor[k], part_last[p_k], cont_last[c_k]) + dt[k]``

    which one ``jax.vmap`` over the S-seed jitter matrix evaluates in a
    single scan — an 8-seed tournament grid replays as one vmapped call
    rather than 8 sequential replays.  Seed s's draws come from
    ``Simulator(seed=s).normals`` in the reference's start order, so the
    reference column agrees with its own replay to ``LOCKSTEP_RTOL``;
    the other columns are frozen-trajectory approximations (the scalar
    path would reorder starts per seed).  Informational only — tournament
    summaries always come from the bit-exact replay.

    ``with_reference=True`` additionally returns the reference replay's
    exact (float64) completion timestamps in the same start order.
    """
    reason = grid_lockstep_eligibility(exp)
    if reason is not None:
        raise ValueError(f"cell does not qualify for grid lockstep: {reason}")
    if not seeds:
        raise ValueError("grid lockstep needs at least one seed")

    trace: list[tuple[float, int, int, float, float]] = []
    ref = replace(exp, seed=int(seeds[0]))
    _FastRun(AdaptationPlan(experiment=ref), trace=trace).run()
    n = len(trace)
    if n == 0:
        empty = np.zeros((len(seeds), 0), dtype=np.float32)
        return (empty, np.zeros(0)) if with_reference else empty

    floors = np.array([f for f, _p, _c, _m, _fin in trace], dtype=np.float64)
    parts = np.array([p for _f, p, _c, _m, _fin in trace], dtype=np.int32)
    conts = np.array([c for _f, _p, c, _m, _fin in trace], dtype=np.int32)
    means = np.array([m for _f, _p, _c, m, _fin in trace], dtype=np.float64)
    ref_fin = np.array([fin for _f, _p, _c, _m, fin in trace],
                       dtype=np.float64)
    n_parts = int(parts.max()) + 1
    n_conts = int(conts.max()) + 1

    # cv is memory-shaped only (service_time_mean), constant per cell
    cfg = dict(DEFAULTS)
    cfg.update(exp.backend_attrs)
    profile = KMeansStreamWorkload(
        points=exp.points, centroids=exp.centroids,
        policy=exp.effective_policy, n_partitions=1).profile()
    _mean, cv = service_time_mean(cfg, exp.memory_mb, profile, False)
    sigma2 = math.log1p(cv * cv)
    a, b = -0.5 * sigma2, math.sqrt(sigma2)

    z = np.stack([Simulator(seed=s).normals(n) for s in seeds])
    # the per-invocation jitter factors, float32 (as the lockstep contract
    # states) — computed once outside the scan for both backends
    dt = means.astype(np.float32)[None, :] \
        * np.exp(np.float32(a) + np.float32(b) * z.astype(np.float32))
    floors32 = floors.astype(np.float32)

    try:
        fn = _grid_scan_fn(n_parts, n_conts)
        finishes = np.asarray(fn(floors32, parts, conts, dt))
    except ImportError:     # pragma: no cover - jax is in the image
        S = len(seeds)
        finishes = np.empty((S, n), dtype=np.float32)
        part_last = np.zeros((S, n_parts), dtype=np.float32)
        cont_last = np.zeros((S, n_conts), dtype=np.float32)
        for k in range(n):
            p, c = parts[k], conts[k]
            start = np.maximum(floors32[k],
                               np.maximum(part_last[:, p], cont_last[:, c]))
            fin = start + dt[:, k]
            part_last[:, p] = fin
            cont_last[:, c] = fin
            finishes[:, k] = fin
    return (finishes, ref_fin) if with_reference else finishes


@functools.cache
def _grid_scan_fn(n_parts: int, n_conts: int):
    """The jitted S-seed grid scan for a (partition count, container
    count) shape — cached at module level so repeated grids of the same
    shape reuse the compiled executable instead of retracing (retracing
    costs more than the scan itself on small cells)."""
    import jax
    import jax.numpy as jnp

    def chain(floors, parts, conts, dt_row):
        def step(carry, inputs):
            part_last, cont_last = carry
            floor, p, c, dt_i = inputs
            start = jnp.maximum(floor,
                                jnp.maximum(part_last[p], cont_last[c]))
            fin = start + dt_i
            return ((part_last.at[p].set(fin),
                     cont_last.at[c].set(fin)), fin)

        carry0 = (jnp.zeros(n_parts, dtype=jnp.float32),
                  jnp.zeros(n_conts, dtype=jnp.float32))
        _last, fins = jax.lax.scan(step, carry0,
                                   (floors, parts, conts, dt_row))
        return fins

    return jax.jit(jax.vmap(chain, in_axes=(None, None, None, 0)))
