"""Batched fast replay of serverless closed-loop adaptation cells.

The what-if engine (``core.whatif``) sweeps (scenario × policy × seed)
grids whose cells are dominated by DES heap traffic that is *structurally
predictable* on the serverless platform: the producer's emission times are
a pure function of the rate program (no RNG), the Kinesis ingest shards
are processor-sharing queues with no stochastic input, and the only random
draw in the whole cell is the per-invocation lognormal service jitter.
This module exploits that structure: it precomputes the emission schedule
once per (rate spec, horizon) — shared across every seed and policy in a
tournament — steps the ingest shards in columnar windows between control
ticks, and replays only the *irreducible* events (appends, invocation
finishes, control ticks) through a real ``Simulator`` driving the real
``ControlLoop`` / policy / ``OnlineUSLEstimator`` objects.

Bit-agreement with ``run_adaptation`` is a construction invariant, not an
aspiration: the control loop, policy stack, USL estimator and the
service-time model (``serverless.service_time_mean``) are the *same code
objects* the scalar path runs; the replay reproduces the scalar path's
float arithmetic (VFT virtual-time updates, ``now + delay`` timestamp
sums, the 256-block normal stream via ``Simulator.normals``) operation for
operation, and ``tests/test_batched.py`` asserts equality field-by-field
across seeds and policies.

Eligibility (static, checked before anything runs):

* ``engine == "sim"`` — the wall clock cannot be replayed;
* ``machine == "serverless"`` — HPC cells couple through the shared
  filesystem and the model lock, which serializes *across* partitions and
  breaks the per-shard window independence this replay exploits;
* no fault plan — crash/preempt/stall handlers reorder the event stream
  data-dependently;
* ``batch_max == 1`` — the replay models one invocation per message (the
  paper's Lambda mapping);
* the task working set fits the container (the memory-failure path is a
  retry loop, not a replayable fast path).

Runtime fallbacks (the replay *starts*, then discovers the cell leaves the
fast regime): a straggler speculation would fire, or an invocation would
exceed the walltime limit.  Both raise ``_FallbackNeeded``; the caller
reruns the cell on the scalar DES and the reason is logged and recorded on
the summary (``fallback_reason``).

The jax lockstep stepper (``lockstep_completion_times``) batches S seeds
of an even narrower cell class — static policy, one partition, serial
ingest — into one ``vmap``-ed scan, mirroring ``fit_usl_batch``'s stacked
LM.  It runs in float32 on the accelerator path, so its agreement
contract is a documented tolerance (``LOCKSTEP_RTOL``), not bit equality;
it feeds the perf-smoke informational row, never the tournament results.
"""

from __future__ import annotations

import heapq
import json
import logging
import math
import statistics
from collections import deque

import numpy as np

from repro.core.autoscale import ControlLoop, policy_from_spec
from repro.core.metrics import percentile_summary
from repro.core.miniapp import (AdaptationExperiment, AdaptationPlan,
                                AdaptationSummary, KMeansStreamWorkload,
                                POINT_BYTES, adaptation_profile_factory,
                                scaling_policy_spec)
from repro.pilot.backends.serverless import DEFAULTS, service_time_mean
from repro.sim.des import Simulator
from repro.streaming.producer import rate_program_from_spec

__all__ = ["try_fast_adaptation", "lockstep_completion_times",
           "lockstep_eligibility", "LOCKSTEP_RTOL"]

log = logging.getLogger("repro.sim.batched")

# wiring constants of run_adaptation's serverless pipeline (the replay
# must agree with them exactly; they are assembly facts, not knobs)
_REQUEST_LATENCY = 0.01      # PartitionIngest default request_latency
_INGEST_BW = 1e6             # run_adaptation's bw_per_partition (Kinesis)
_IDLE_RESOLUTION_S = 0.25    # SyntheticProducer idle probe spacing

_INF = float("inf")


class _FallbackNeeded(RuntimeError):
    """The cell left the replayable regime mid-run — rerun it scalar."""


# ---------------------------------------------------------------------------
# emission schedule: pure function of (rate spec, horizon), shared per grid
# ---------------------------------------------------------------------------

_EMISSION_CACHE: dict[tuple, tuple[list[float], float, list[float]]] = {}
_EMISSION_CACHE_MAX = 32


def _emission_schedule(rate_spec: dict, horizon_s: float,
                       cap: int) -> tuple[list[float], float, list[float]]:
    """Replay ``SyntheticProducer._tick_program``'s event chain off-line.

    Returns ``(emit_times, finish_t, sched_times)``: the exact float
    timestamps of every emission, the production-over event time, and for
    each emission the timestamp of the *program event that scheduled it*
    (the previous emission or idle probe — needed to resolve heap-order
    ties when an emission lands exactly on a control-tick boundary).
    The chain is RNG-free, so one schedule serves every seed and policy of
    a what-if grid.
    """
    key = (json.dumps(rate_spec, sort_keys=True, default=str),
           horizon_s, cap)
    hit = _EMISSION_CACHE.get(key)
    if hit is not None:
        return hit
    program = rate_program_from_spec(rate_spec)
    emit: list[float] = []
    sched: list[float] = []
    t = 0.0
    prev = 0.0          # ts of the program event that scheduled event at t
    while True:
        if t >= horizon_s or len(emit) >= cap:
            finish_t = t
            finish_sched = prev
            break
        rate = program.rate(t)
        if rate <= 1e-9:
            prev = t
            t = t + _IDLE_RESOLUTION_S
            continue
        emit.append(t)
        sched.append(prev)
        prev = t
        t = t + 1.0 / rate
    out = (emit, finish_t, sched + [finish_sched])
    if len(_EMISSION_CACHE) >= _EMISSION_CACHE_MAX:
        _EMISSION_CACHE.pop(next(iter(_EMISSION_CACHE)))
    _EMISSION_CACHE[key] = out
    return out


def _program_beats_tick(event_t: float, sched_t: float,
                        interval_s: float) -> bool:
    """Heap order of a producer program event vs the control tick at the
    same timestamp ``event_t`` (an exact-float collision, e.g. a 2 Hz
    emission grid meeting 2 s ticks).

    Both are plain ``(ts, seq)`` heap entries, so the earlier *scheduling*
    wins: the program event was pushed at ``sched_t``, the tick at
    ``event_t - interval_s``.  When those collide too, the chains are
    recursively tied; at the root (t=0) the producer starts before the
    loop in ``run_adaptation``'s assembly order, so the producer wins."""
    tick_armed = event_t - interval_s
    while True:
        if sched_t < tick_armed:
            return True
        if sched_t > tick_armed:
            return False
        if sched_t <= 0.0:
            return True          # setup order: producer.start before loop.start
        # both pushed during events at the same earlier timestamp — compare
        # one step further back along each chain
        event_t, tick_armed = sched_t, tick_armed - interval_s
        sched_t = event_t - interval_s  # conservative: unknown exact program
        # spacing this far back only matters on pathological rate programs;
        # equal spacing keeps recursing toward the t=0 base case


# ---------------------------------------------------------------------------
# ingest shards: SharedResource's VFT algebra, windowed
# ---------------------------------------------------------------------------

class _Shard:
    """One Kinesis shard as ``SharedResource``'s virtual-finish-time state,
    advanced in windows instead of per-event heap traffic.  The float
    updates are copied from ``des.SharedResource`` verbatim so completion
    timestamps agree bitwise."""

    __slots__ = ("capacity", "vtime", "last_ts", "heap", "flows",
                 "next_fid", "next_t", "pending")

    def __init__(self, capacity: float) -> None:
        self.capacity = capacity
        self.vtime = 0.0
        self.last_ts = 0.0
        self.heap: list[tuple[float, int]] = []
        self.flows: dict[int, tuple[int, int]] = {}   # fid -> (msg, partition)
        self.next_fid = 0
        self.next_t: float | None = None
        self.pending: deque = deque()    # (submit_ts, msg_idx, partition)

    def submit(self, t: float, work: float, item: tuple[int, int]) -> None:
        n = len(self.flows)
        if n:
            dt = t - self.last_ts
            if dt > 0:
                self.vtime += dt * (self.capacity / n)
        self.last_ts = t
        fid = self.next_fid
        self.next_fid = fid + 1
        self.flows[fid] = item
        heapq.heappush(self.heap, (self.vtime + work, fid))
        delay = max(self.heap[0][0] - self.vtime, 0.0) \
            * (n + 1) / self.capacity
        self.next_t = t + delay

    def complete(self, t: float) -> tuple[int, int]:
        n = len(self.flows)
        dt = t - self.last_ts
        if dt > 0:
            self.vtime += dt * (self.capacity / n)
        self.last_ts = t
        _vtag, fid = heapq.heappop(self.heap)
        item = self.flows.pop(fid)
        if n > 1:
            delay = max(self.heap[0][0] - self.vtime, 0.0) \
                * (n - 1) / self.capacity
            self.next_t = t + delay
        else:
            self.next_t = None
        return item


# ---------------------------------------------------------------------------
# facades: the data plane as plain state, the control plane real
# ---------------------------------------------------------------------------

class _Container:
    __slots__ = ("warm", "busy")

    def __init__(self) -> None:
        self.warm = False
        self.busy = False


class _Invocation:
    __slots__ = ("partition", "msg", "append_ts", "deadline", "start_ts")

    def __init__(self, partition: int, msg: int, append_ts: float,
                 deadline: float) -> None:
        self.partition = partition
        self.msg = msg
        self.append_ts = append_ts
        self.deadline = deadline
        self.start_ts = 0.0


class _Partition:
    __slots__ = ("pending", "inflight")

    def __init__(self) -> None:
        self.pending: deque = deque()    # (msg_idx, append_ts)
        self.inflight = False


class _FastBroker:
    """What the ControlLoop sees of the broker: active/total shard counts."""

    __slots__ = ("active", "total")

    def __init__(self, initial: int) -> None:
        self.active = initial
        self.total = initial

    def repartition(self, topic: str, n: int) -> int:
        if n > self.total:
            self.total = n
        self.active = n
        return n


class _FastBackend:
    """``ServerlessSimBackend``'s container pool for one pilot, minus the
    fault surface.  Queue and free-pool disciplines are replicated exactly
    (FIFO queue, MRU free deque) because they fix the *order* in which
    invocations draw their jitter from the shared normal stream."""

    def __init__(self, run: "_FastRun", cfg: dict, memory_mb: int,
                 walltime_s: float, n_containers: int) -> None:
        self._run = run
        self.cfg = cfg
        self.memory_mb = memory_mb
        self.walltime_s = walltime_s
        self.containers = [_Container() for _ in range(max(1, n_containers))]
        self.free = deque(self.containers)
        self.queue: deque = deque()
        self.target = len(self.containers)
        self._submit_rec: _Invocation | None = None
        # (profile id, cold) -> (mean, cv): profile objects are cached for
        # the run's lifetime by adaptation_profile_factory, so ids are stable
        self._svc_cache: dict[tuple[int, bool], tuple[float, float]] = {}

    # -- ControlLoop's Backend surface (pilot arg unused: one pilot) --------
    def allocation(self, pilot=None) -> int:
        return self.target

    def effective_allocation(self, pilot=None) -> int:
        return len(self.containers)

    def scale_to(self, pilot, n: int) -> int:
        n = max(1, min(int(n), int(self.cfg["max_containers"])))
        self.target = n
        containers, free = self.containers, self.free
        while len(containers) > n and free:
            containers.remove(free.pop())
        while len(containers) < n:
            c = _Container()
            containers.append(c)
            free.append(c)
        self.dispatch()
        return n

    # -- execution ----------------------------------------------------------
    def submit(self, rec: _Invocation) -> None:
        self.queue.append(rec)
        prev = self._submit_rec
        self._submit_rec = rec
        self.dispatch()
        self._submit_rec = prev

    def dispatch(self) -> None:
        queue, free = self.queue, self.free
        while queue:
            if not free:
                return
            self._start(queue.popleft(), free.popleft())

    def _start(self, rec: _Invocation, c: _Container) -> None:
        run = self._run
        sim = run.sim
        profile = run.profile_for(None)
        cold = not c.warm
        c.warm = True
        c.busy = True
        key = (id(profile), cold)
        svc = self._svc_cache.get(key)
        if svc is None:
            svc = self._svc_cache[key] = service_time_mean(
                self.cfg, self.memory_mb, profile, cold)
        t_mean, cv = svc
        dt = sim.lognormal_jitter(t_mean, cv)
        if dt > self.walltime_s:
            raise _FallbackNeeded(
                f"invocation needs {dt:.1f}s > walltime {self.walltime_s}s "
                "(walltime-kill/retry path)")
        finish_ts = sim.now + dt
        # the scalar path's straggler event at `deadline` fires iff the
        # invocation is still in flight when it pops; at an exact-float tie
        # the finish event wins only when it was scheduled first (the
        # invocation started inside the submit call, before the straggler
        # was armed)
        if finish_ts > rec.deadline or (finish_ts == rec.deadline
                                        and rec is not self._submit_rec):
            raise _FallbackNeeded(
                "straggler speculation would fire (duplicate dispatch)")
        rec.start_ts = sim.now
        sim.schedule_fast(dt, lambda: self._finish(rec, c))

    def _finish(self, rec: _Invocation, c: _Container) -> None:
        c.busy = False
        if len(self.containers) > self.target:
            self.containers.remove(c)      # scale-down landed mid-flight
        else:
            self.free.appendleft(c)
        self._run.engine.on_final_done(rec)
        self.dispatch()


class _FastEngine:
    """``SimStreamingEngine``'s partition consumer + the loop's
    EngineControlSurface, over precomputed appends."""

    def __init__(self, run: "_FastRun", initial: int) -> None:
        self._run = run
        self.parts = [_Partition() for _ in range(initial)]
        self.inflight_n = 0
        self.appended_seen = 0
        self.paused_until = 0.0
        self.completed_runtimes: list[float] = []
        self._straggler_cache = (0, _INF)

    # -- EngineControlSurface ------------------------------------------------
    def now(self) -> float:
        return self._run.sim.now

    def call_later(self, delay_s: float, fn) -> None:
        # the only call_later client is the ControlLoop's tick chain; wrap
        # it so each tick is followed by the producer/ingest window advance
        # (emissions in [T, T+interval) see the post-tick partition count,
        # exactly as their heap events would)
        run = self._run

        def tick() -> None:
            pre_active = run.broker.active
            fn()
            run.after_tick(pre_active)

        run.sim.schedule_fast(delay_s, tick)

    def repartition(self, migration_s: float = 0.0) -> None:
        total = self._run.broker.total
        parts = self.parts
        while len(parts) < total:
            parts.append(_Partition())
        if migration_s > 0.0:
            sim = self._run.sim
            resume_at = sim.now + migration_s
            if resume_at > self.paused_until:
                self.paused_until = resume_at
                sim.schedule_fast(migration_s, self._resume)

    def _resume(self) -> None:
        if self._run.sim.now < self.paused_until:
            return     # superseded by a longer, later migration pause
        for p in range(len(self.parts)):
            self.drain(p)

    # -- consumer ------------------------------------------------------------
    def straggler_timeout(self) -> float:
        runtimes = self.completed_runtimes
        n = len(runtimes)
        if n < 3:
            return _INF
        cached_n, cached = self._straggler_cache
        if n != cached_n and (n < 32 or n % 32 == 0 or cached_n < 3):
            cached = max(4.0 * statistics.median(runtimes), 1e-3)
            self._straggler_cache = (n, cached)
        return cached

    def on_append(self, msg: int, partition: int, ts: float) -> None:
        self.appended_seen += 1
        if partition >= len(self.parts):
            self.repartition()
        self.parts[partition].pending.append((msg, ts))
        self.drain(partition)

    def drain(self, partition: int) -> None:
        run = self._run
        if run.sim.now < self.paused_until:
            return     # migrating: the resume sweep re-drains everything
        if partition >= len(self.parts):
            self.repartition()
        ps = self.parts[partition]
        if ps.inflight or not ps.pending:
            return
        msg, append_ts = ps.pending.popleft()
        ps.inflight = True
        self.inflight_n += 1
        timeout = self.straggler_timeout()
        deadline = run.sim.now + timeout if timeout != _INF else _INF
        run.backend.submit(_Invocation(partition, msg, append_ts, deadline))

    def on_final_done(self, rec: _Invocation) -> None:
        run = self._run
        now = run.sim.now
        run.processed += 1
        run.latencies.append(now - rec.append_ts)
        self.completed_runtimes.append(now - rec.start_ts)
        ps = self.parts[rec.partition]
        ps.inflight = False
        self.inflight_n -= 1
        self.drain(rec.partition)

    def is_finished(self) -> bool:
        run = self._run
        if not run.producer_done:
            return False
        if self.inflight_n or run.processed < self.appended_seen:
            return False
        return all(not ps.pending and not ps.inflight for ps in self.parts)


class _FastMetrics:
    """The MetricRegistry surface the ControlLoop consumes, O(1) per call:
    ``produce`` counts walk the shared emission schedule, ``complete``
    counts read the processed counter, trace emission is dropped (the
    summary carries no event columns)."""

    def __init__(self, run: "_FastRun") -> None:
        self._run = run
        self._produce_i = 0

    def kind_count(self, run_id: str, kind: str) -> int:
        run = self._run
        if kind == "produce":
            emit = run.emit_times
            first = run.boundary_first
            now = run.sim.now
            i = self._produce_i
            n = len(emit)
            # an emission exactly at a tick timestamp counts iff its heap
            # event popped before the tick's (precomputed boundary order)
            while i < n and (emit[i] < now or (emit[i] == now and first[i])):
                i += 1
            self._produce_i = i
            return i
        if kind == "complete":
            return run.processed
        return 0

    def observe(self, name: str, ts: float, value: float) -> None:
        pass

    def record(self, *args, **kwargs) -> None:
        pass


class _FastPilot:
    __slots__ = ("backend",)

    def __init__(self, backend: _FastBackend) -> None:
        self.backend = backend


# ---------------------------------------------------------------------------
# the replay driver
# ---------------------------------------------------------------------------

class _FastRun:
    """One eligible cell, replayed: real Simulator + ControlLoop/policy,
    columnar producer/ingest, event-true backend/engine facades."""

    def __init__(self, plan: AdaptationPlan) -> None:
        exp = plan.experiment
        self.plan = plan
        self.exp = exp
        self.sim = Simulator(seed=exp.seed)

        static_n = (exp.static_partitions if exp.static_partitions is not None
                    else exp.max_partitions)
        initial = static_n if exp.scaling_policy == "static" \
            else exp.initial_partitions
        initial = max(1, min(initial, exp.max_partitions))

        cfg = dict(DEFAULTS)
        cfg.update(exp.backend_attrs)
        n_containers = min(initial, int(cfg["max_containers"]))

        program = rate_program_from_spec(exp.rate)
        cap = int(program.mean_messages(0.0, exp.horizon_s) * 2 + 1000)
        self.emit_times, self.finish_t, sched_times = _emission_schedule(
            exp.rate, exp.horizon_s, cap)
        self.sent_total = len(self.emit_times)
        self.wl_work = float(exp.points * POINT_BYTES)

        # exact-float collisions between producer program events and control
        # ticks (a 2 Hz grid meeting 2 s ticks does this every boundary):
        # resolve each once, up front
        interval = exp.control_interval_s
        tick_set = _tick_times(interval, max(self.finish_t,
                                             self.emit_times[-1]
                                             if self.emit_times else 0.0))
        self.boundary_first = [
            t in tick_set
            and _program_beats_tick(t, sched_times[i], interval)
            for i, t in enumerate(self.emit_times)]
        self.finish_at_tick_after = (
            self.finish_t in tick_set
            and not _program_beats_tick(self.finish_t, sched_times[-1],
                                        interval))

        self.broker = _FastBroker(initial)
        self.backend = _FastBackend(self, cfg, exp.memory_mb,
                                    900.0, n_containers)   # PilotDescription default walltime
        self.engine = _FastEngine(self, initial)
        self.metrics = _FastMetrics(self)
        self.profile_for = adaptation_profile_factory(
            exp, lambda: self.sim.now, lambda: self.loop.allocation)
        self.shards = [_Shard(_INGEST_BW) for _ in range(exp.max_partitions)]

        self.processed = 0
        self.appended_total = 0
        self.latencies: list[float] = []
        self.producer_appended = 0
        self.production_over = False
        self.producer_done = False
        self._next_emit = 0

        self.loop = ControlLoop(
            self.engine, self.broker, "points", _FastPilot(self.backend),
            policy_from_spec(scaling_policy_spec(exp), initial=initial),
            metrics=self.metrics, run_id="fast",
            interval_s=exp.control_interval_s, slo_lag=exp.slo_lag,
            migration_s_per_delta=exp.migration_s_per_delta,
            fault_signal=None)

    # -- producer/ingest window machinery -----------------------------------
    def _assign_window(self, window_end: float, pre_active: int) -> None:
        """Assign emissions in [sim.now, window_end) to partitions and step
        each shard's VFT state up to the window's append horizon."""
        emit = self.emit_times
        first = self.boundary_first
        shards = self.shards
        n_shards = len(shards)
        active = self.broker.active
        now = self.sim.now
        i = self._next_emit
        n = len(emit)
        while i < n and emit[i] < window_end:
            t = emit[i]
            # an emission that popped before this tick saw the pre-tick
            # partition count
            p = i % (pre_active if (t == now and first[i]) else active)
            shards[p % n_shards].pending.append(
                (t + _REQUEST_LATENCY, i, p))
            i += 1
        self._next_emit = i
        bound = window_end + _REQUEST_LATENCY
        for sh in shards:
            self._drain_shard(sh, bound)

    def _drain_shard(self, sh: _Shard, bound: float) -> None:
        """Run one shard's submit/complete events with timestamps < bound
        (no later submit can predate ``bound``, so every completion this
        finalizes is final)."""
        pending = sh.pending
        while True:
            t_sub = pending[0][0] if pending else _INF
            t_comp = sh.next_t if sh.next_t is not None else _INF
            if t_comp <= t_sub:
                if t_comp >= bound:
                    return
                msg, part = sh.complete(t_comp)
                self._schedule_append(t_comp, msg, part)
            else:
                if t_sub >= bound:
                    return
                _ts, msg, part = pending.popleft()
                sh.submit(t_sub, self.wl_work, (msg, part))

    def _schedule_append(self, t: float, msg: int, partition: int) -> None:
        def append() -> None:
            self.appended_total += 1
            self.engine.on_append(msg, partition, t)
            self.producer_appended += 1
            if self.production_over \
                    and self.producer_appended >= self.sent_total:
                self.producer_done = True

        self.sim.schedule_at(t, append)

    def _finish_production(self) -> None:
        self.production_over = True
        if self.producer_appended >= self.sent_total:
            self.producer_done = True

    def after_tick(self, pre_active: int) -> None:
        now = self.sim.now
        if self.finish_at_tick_after and not self.production_over \
                and self.finish_t == now:
            self._finish_production()
        self._assign_window(now + self.exp.control_interval_s, pre_active)

    # -- run -----------------------------------------------------------------
    def run(self) -> AdaptationSummary:
        exp = self.exp
        sim = self.sim
        # production-over event (unless it resolves after a colliding tick,
        # which after_tick handles at that exact timestamp)
        if not self.finish_at_tick_after:
            self.sim.schedule_at(self.finish_t, self._finish_production)
        # the pre-first-tick window: assigned at setup, like the producer's
        # t=0 start event
        self._assign_window(exp.control_interval_s, self.broker.active)
        self.loop.start()
        max_virtual = exp.horizon_s * 6.0 + 600.0
        sim.run_until(t=sim.now + max_virtual,
                      predicate=self.engine.is_finished)
        drained = self.engine.is_finished()
        self.loop.stop()
        loop = self.loop
        wall = max(sim.now, 1e-9)
        return AdaptationSummary(
            experiment=self.plan,
            slo_violations=loop.slo_violations,
            ticks=loop.ticks,
            cost_integral=loop.cost_integral,
            scale_events=loop.scale_events,
            produced=self.sent_total,
            processed=self.processed,
            throughput=self.processed / wall,
            latency_px=percentile_summary(
                np.asarray(self.latencies, dtype=np.float64)),
            final_allocation=loop.allocation,
            drained=drained,
            drain_s=max(0.0, sim.now - exp.horizon_s),
            refits=loop.refit_events,
            abandoned=0, dup_delivered=0, faults_injected=0, preemptions=0,
            fault_windows=loop.fault_windows,
            lost=self.appended_total - self.processed,
            member_ledger=[],
            fast_path=True, fallback_reason=None)


def _tick_times(interval_s: float, t_max: float) -> frozenset[float]:
    """The exact float timestamps of the tick chain i, 2i, 3i, ... ≤ t_max
    (each produced by repeated ``now + interval`` float sums — NOT k * i,
    which can differ in the last ulp)."""
    if interval_s <= 0.0:
        return frozenset()
    ticks = []
    acc = 0.0
    while True:
        acc += interval_s
        if acc > t_max:
            return frozenset(ticks)
        ticks.append(acc)


def _ineligible(exp: AdaptationExperiment) -> str | None:
    if exp.engine != "sim":
        return f"engine={exp.engine!r} (wall clock is not replayable)"
    if exp.machine == "federated":
        return "federated machine (member routing/breaker state machine)"
    if exp.machine != "serverless":
        return (f"machine={exp.machine!r} (shared-filesystem coupling "
                "across partitions)")
    if exp.faults:
        return "fault plan present (crash/preempt/stall semantics)"
    if exp.batch_max != 1:
        return f"batch_max={exp.batch_max} (replay models 1 msg/invocation)"
    cfg = dict(DEFAULTS)
    cfg.update(exp.backend_attrs)
    profile = KMeansStreamWorkload(
        points=exp.points, centroids=exp.centroids,
        policy=exp.effective_policy, n_partitions=1).profile()
    if profile.memory_mb > min(exp.memory_mb, cfg["memory_cap_mb"]):
        return "working set exceeds container memory (failure/retry path)"
    return None


def try_fast_adaptation(
        plan: AdaptationPlan) -> tuple[AdaptationSummary | None, str | None]:
    """Replay ``plan`` on the batched fast path if it qualifies.

    Returns ``(summary, None)`` on success or ``(None, reason)`` when the
    cell is ineligible or leaves the fast regime mid-run; the reason is
    logged and the caller reruns the cell on the scalar DES."""
    exp = plan.experiment
    reason = _ineligible(exp)
    if reason is None:
        try:
            return _FastRun(plan).run(), None
        except _FallbackNeeded as fb:
            reason = str(fb)
    log.info("fast replay fallback (%s/%s seed %d): %s",
             exp.machine, exp.scaling_policy, exp.seed, reason)
    return None, reason


# ---------------------------------------------------------------------------
# jax lockstep: S seeds of a static single-partition cell in one vmap
# ---------------------------------------------------------------------------

# float32 agreement bound for the jax path vs the float64 scalar DES.  The
# scan is a few thousand fused multiply/exp/max ops; observed worst-case
# relative error is ~1e-6, the gate leaves an order of magnitude of head
# room.  The lockstep path is informational (perf rows, tolerance tests) —
# tournament results always come from the bit-exact replay above.
LOCKSTEP_RTOL = 1e-4


def lockstep_eligibility(exp: AdaptationExperiment) -> str | None:
    """The lockstep scan collapses the whole cell to one recurrence
    ``finish[i] = max(append[i], finish[i-1]) + dt[i]`` — valid only when
    nothing can reorder or replicate invocations."""
    base = _ineligible(exp)
    if base is not None:
        return base
    if exp.scaling_policy != "static":
        return (f"scaling_policy={exp.scaling_policy!r} (lockstep needs a "
                "static allocation: no scale/migration events)")
    static_n = (exp.static_partitions if exp.static_partitions is not None
                else exp.max_partitions)
    if static_n != 1:
        return (f"static_partitions={static_n} (lockstep models one "
                "partition, one container)")
    if exp.drift_t_s is not None:
        return "cost drift present (service time becomes time-dependent)"
    return None


def lockstep_completion_times(exp: AdaptationExperiment, seeds: list[int],
                              with_appends: bool = False) -> np.ndarray:
    """Per-message completion timestamps for S seeds of one qualifying
    cell, advanced in lockstep (jax ``vmap`` over the seed axis when jax is
    importable, a numpy scan otherwise — same arithmetic, float32 both
    ways).

    The jitter draws come from ``Simulator.normals`` — the same 256-block
    stream the scalar DES consumes — so seed s's column sees exactly the
    draws scalar seed s would; only the float width differs.

    ``with_appends=True`` additionally returns the (seed-independent)
    broker-append timestamps — ``finishes - appends`` is the pipeline
    latency the scalar DES reports in ``latency_px``, the quantity the
    ``LOCKSTEP_RTOL`` agreement contract is stated against.
    """
    reason = lockstep_eligibility(exp)
    if reason is not None:
        raise ValueError(f"cell does not qualify for lockstep: {reason}")

    program = rate_program_from_spec(exp.rate)
    cap = int(program.mean_messages(0.0, exp.horizon_s) * 2 + 1000)
    emit_times, _finish_t, _sched = _emission_schedule(
        exp.rate, exp.horizon_s, cap)
    n_msgs = len(emit_times)

    # append times: one shard, no RNG — identical across seeds
    shard = _Shard(_INGEST_BW)
    work = float(exp.points * POINT_BYTES)
    appends = np.empty(n_msgs, dtype=np.float64)
    for i, t in enumerate(emit_times):
        shard.pending.append((t + _REQUEST_LATENCY, i, 0))
    # one unbounded drain: every submit is already queued in time order
    out: list[tuple[float, int]] = []
    pending = shard.pending
    while pending or shard.next_t is not None:
        t_sub = pending[0][0] if pending else _INF
        t_comp = shard.next_t if shard.next_t is not None else _INF
        if t_comp <= t_sub:
            msg, _p = shard.complete(t_comp)
            out.append((t_comp, msg))
        else:
            _ts, msg, _p = pending.popleft()
            shard.submit(t_sub, work, (msg, 0))
    for t, msg in out:
        appends[msg] = t

    # per-message service-time means: first invocation cold, rest warm
    profile = KMeansStreamWorkload(
        points=exp.points, centroids=exp.centroids,
        policy=exp.effective_policy, n_partitions=1).profile()
    cfg = dict(DEFAULTS)
    cfg.update(exp.backend_attrs)
    mean_cold, cv = service_time_mean(cfg, exp.memory_mb, profile, True)
    mean_warm, _cv = service_time_mean(cfg, exp.memory_mb, profile, False)
    means = np.full(n_msgs, mean_warm)
    if n_msgs:
        means[0] = mean_cold

    # the scalar stream's draws, per seed (bit-identical block consumption)
    z = np.stack([Simulator(seed=s).normals(n_msgs) for s in seeds])
    sigma2 = math.log1p(cv * cv)
    a, b = -0.5 * sigma2, math.sqrt(sigma2)

    try:
        import jax
        import jax.numpy as jnp

        def chain(z_row):
            dt = jnp.asarray(means, dtype=jnp.float32) \
                * jnp.exp(a + b * z_row.astype(jnp.float32))
            ap = jnp.asarray(appends, dtype=jnp.float32)

            def step(prev_finish, inputs):
                append_t, dt_i = inputs
                finish = jnp.maximum(append_t, prev_finish) + dt_i
                return finish, finish

            _last, finishes = jax.lax.scan(step, jnp.float32(0.0), (ap, dt))
            return finishes

        finishes = np.asarray(jax.vmap(chain)(jnp.asarray(z)))
        return (finishes, appends) if with_appends else finishes
    except ImportError:     # pragma: no cover - jax is in the image
        dt = means.astype(np.float32)[None, :] \
            * np.exp(a + b * z.astype(np.float32))
        ap = appends.astype(np.float32)
        finishes = np.empty((len(seeds), n_msgs), dtype=np.float32)
        prev = np.zeros(len(seeds), dtype=np.float32)
        for i in range(n_msgs):
            prev = np.maximum(ap[i], prev) + dt[:, i]
            finishes[:, i] = prev
        return (finishes, appends) if with_appends else finishes
