"""Discrete-event simulation core (virtual clock).

The paper's experiments ran on AWS (Lambda/Kinesis) and XSEDE HPC machines
(Wrangler, Stampede2) — hardware this container cannot reach.  Per DESIGN.md
§2 we reproduce both platforms as *mechanism-level* simulations: backends
model CPU shares, shared-filesystem bandwidth, coherence synchronization and
cold starts; contention (sigma) and coherence (kappa) then *emerge* from the
mechanisms and are recovered by the USL fit, keeping the validation
non-circular.

The simulator is a standard event-queue DES: entities schedule callbacks at
virtual timestamps; ``run_until`` advances the clock.  Deterministic given a
seed (all stochastic service-time jitter flows through ``self.rng``).
``events_processed`` counts executed (non-canceled) events — the cost metric
the perf-smoke benchmark and the push-based streaming engine are judged on.

Hot-path design: heap entries are plain ``(ts, seq, record)`` tuples so
ordering resolves through C-level tuple comparison (floats and ints), never
a Python ``__lt__``; the record is a ``__slots__`` object holding only the
callback and the cancellation flag.  ``SharedResource`` uses the standard
virtual-finish-time (VFT) formulation of processor sharing, so arrivals and
departures cost O(log n) heap work instead of an O(n) rescan of every
active flow's remaining work.
"""

from __future__ import annotations

import heapq
import itertools
import math
from typing import Any, Callable

import numpy as np

__all__ = ["Simulator", "SimProcessError"]


class SimProcessError(RuntimeError):
    """Raised inside a simulated task to signal failure (walltime kill, ...)."""


class _Scheduled:
    """Cancelable handle for one scheduled callback (heap payload only —
    ordering lives in the ``(ts, seq)`` tuple prefix of the heap entry)."""

    __slots__ = ("ts", "fn", "canceled")

    def __init__(self, ts: float, fn: Callable[[], None]) -> None:
        self.ts = ts
        self.fn = fn
        self.canceled = False


class Simulator:
    """Minimal, deterministic discrete-event simulator."""

    def __init__(self, seed: int = 0) -> None:
        self._queue: list[tuple[float, int, _Scheduled]] = []
        self._seq = itertools.count()
        self.now: float = 0.0
        self.rng = np.random.default_rng(seed)
        self.events_processed: int = 0
        self._jitter_params: dict[float, tuple[float, float]] = {}
        self._z_block: np.ndarray | None = None
        self._z_i: int = 0

    def schedule(self, delay: float, fn: Callable[[], None]) -> _Scheduled:
        """Schedule ``fn`` to run ``delay`` seconds from now.  Returns a
        cancelable handle."""
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        ts = self.now + delay
        ev = _Scheduled(ts, fn)
        heapq.heappush(self._queue, (ts, next(self._seq), ev))
        return ev

    def schedule_fast(self, delay: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` with no cancellation handle.

        Most simulation events (producer ticks, service-phase transitions,
        lock handoffs) are never canceled; skipping the ``_Scheduled``
        record halves the allocations per event on those paths.  Ordering
        is identical to ``schedule`` — same ``(ts, seq)`` key space."""
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        heapq.heappush(self._queue, (self.now + delay, next(self._seq), fn))

    def schedule_at(self, ts: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` at the *absolute* virtual timestamp ``ts``.

        Batched-stepping hook: a stepper that precomputes event times as
        exact floats (e.g. the what-if fast replay's ingest completions)
        must not round-trip them through ``now + (ts - now)`` — that float
        detour changes the timestamp in the last ulp and breaks
        bit-agreement with the scalar path.  Same ``(ts, seq)`` key space
        as ``schedule``/``schedule_fast``."""
        if ts < self.now:
            raise ValueError(f"timestamp {ts} is in the past (now={self.now})")
        heapq.heappush(self._queue, (ts, next(self._seq), fn))

    def cancel(self, ev: _Scheduled) -> None:
        ev.canceled = True

    def step(self) -> bool:
        """Run the next event. Returns False when the queue is empty."""
        queue = self._queue
        while queue:
            ts, _seq, obj = heapq.heappop(queue)
            if type(obj) is _Scheduled:
                if obj.canceled:
                    continue
                obj = obj.fn
            self.now = ts
            self.events_processed += 1
            obj()
            return True
        return False

    def run_until(self, t: float | None = None, predicate: Callable[[], bool] | None = None,
                  max_events: int = 50_000_000) -> None:
        """Advance until time ``t``, ``predicate()`` is true, or queue empty."""
        queue = self._queue
        heappop = heapq.heappop
        # events_processed is accumulated locally and flushed on exit (incl.
        # nested run_until calls, which flush their own count): an instance
        # attribute store per event is measurable at this loop's scale
        count = 0
        try:
            for _ in range(max_events):
                if predicate is not None and predicate():
                    return
                if not queue:
                    return
                if t is not None and queue[0][0] > t:
                    self.now = t
                    return
                # inline step(): skip canceled entries without re-checking
                # the predicate (cancellation cannot make it true)
                while True:
                    ts, _seq, obj = heappop(queue)
                    if type(obj) is _Scheduled:
                        if obj.canceled:
                            if not queue:
                                return
                            if t is not None and queue[0][0] > t:
                                self.now = t
                                return
                            continue
                        obj = obj.fn
                    break
                self.now = ts
                count += 1
                obj()
        finally:
            self.events_processed += count
        raise RuntimeError("simulation exceeded max_events — runaway event loop?")

    def run(self) -> None:
        self.run_until()

    # -- convenience: stochastic service times ------------------------------
    def _next_normal(self) -> float:
        """One standard-normal draw from a prefetched block — a scalar
        ``Generator`` method call per event costs more than the draw itself,
        so jitter consumes the stream 256 draws at a time.  Still fully
        deterministic given the seed."""
        i = self._z_i
        block = self._z_block
        if block is None or i >= 256:
            block = self._z_block = self.rng.standard_normal(256)
            i = 0
        self._z_i = i + 1
        return block[i]

    def lognormal_jitter(self, mean: float, cv: float) -> float:
        """Multiplicative lognormal jitter around ``mean`` with coefficient of
        variation ``cv`` (cv=0 → deterministic)."""
        if cv <= 0.0:
            return mean
        params = self._jitter_params.get(cv)
        if params is None:
            sigma2 = math.log1p(cv * cv)
            params = (-0.5 * sigma2, math.sqrt(sigma2))
            self._jitter_params[cv] = params
        return mean * math.exp(params[0] + params[1] * self._next_normal())

    def jitter_coeffs(self, cv: float) -> tuple[float, float]:
        """``(a, b)`` such that ``lognormal_jitter(mean, cv) ==
        mean * exp(a + b * z)`` for the next standard-normal draw ``z``.

        Batched-stepping hook: lets a columnar stepper apply the identical
        jitter transform to a prefetched block of draws.  Uses (and fills)
        the same per-``cv`` coefficient cache as ``lognormal_jitter``."""
        params = self._jitter_params.get(cv)
        if params is None:
            sigma2 = math.log1p(cv * cv)
            params = (-0.5 * sigma2, math.sqrt(sigma2))
            self._jitter_params[cv] = params
        return params

    def normals(self, k: int) -> np.ndarray:
        """The next ``k`` standard-normal draws as one array.

        Batched-stepping hook: consumes the *same* 256-draw prefetched
        block stream as the per-event ``_next_normal``, so a vectorized
        stepper that pre-draws its jitter sees bit-identical values to a
        scalar run making ``k`` sequential ``lognormal_jitter`` calls."""
        out = np.empty(k, dtype=np.float64)
        filled = 0
        while filled < k:
            if self._z_block is None or self._z_i >= 256:
                self._z_block = self.rng.standard_normal(256)
                self._z_i = 0
            take = min(k - filled, 256 - self._z_i)
            out[filled:filled + take] = \
                self._z_block[self._z_i:self._z_i + take]
            self._z_i += take
            filled += take
        return out


class SimLock:
    """FIFO mutex on the virtual clock.

    Models the shared-model read-modify-write critical section the paper's
    HPC runs serialize on ("synchronization of the model updates via the
    shared filesystem"): one holder at a time, waiters queue.
    """

    def __init__(self, sim: Simulator, name: str = "lock") -> None:
        self.sim = sim
        self.name = name
        self._held = False
        self._waiters: list[Callable[[], None]] = []

    def acquire(self, on_acquired: Callable[[], None]) -> None:
        if not self._held:
            # uncontended: run the critical section synchronously — a
            # zero-delay handoff event models no time and only costs heap
            # traffic.  Contended handoffs (release → next waiter) stay
            # event-scheduled to bound recursion depth under lock convoys.
            self._held = True
            on_acquired()
        else:
            self._waiters.append(on_acquired)

    def release(self) -> None:
        if self._waiters:
            # hand off synchronously: like the uncontended acquire, the
            # zero-delay hop models no time.  Recursion depth is bounded by
            # the waiter queue (≤ one per worker): the next holder's
            # continuation schedules its lock-hold work and returns rather
            # than releasing inline.
            self._waiters.pop(0)()
        else:
            self._held = False

    @property
    def queue_len(self) -> int:
        return len(self._waiters)


class SharedResource:
    """Processor-sharing resource: ``capacity`` units/sec split evenly among
    active flows.  Models a shared filesystem / network link.

    Implemented with the standard *virtual-finish-time* formulation: virtual
    time ``V`` advances at the per-flow service rate (``capacity / n``), so a
    flow arriving with ``work`` units finishes exactly when ``V`` reaches
    ``V(arrival) + work`` — independent of later arrivals/departures, which
    only change how fast ``V`` advances.  Completions therefore pop off a
    finish-tag heap in O(log n), instead of rescanning every flow's
    remaining work on each arrival/departure (the O(n) recompute-on-change
    algorithm this replaces).
    """

    def __init__(self, sim: Simulator, capacity: float, name: str = "res") -> None:
        self.sim = sim
        self.capacity = float(capacity)
        self.name = name
        self._flows: dict[int, Callable[[], None]] = {}
        self._finish_heap: list[tuple[float, int]] = []  # (finish vtag, fid)
        self._ids = itertools.count()
        self._vtime = 0.0
        self._last_ts = 0.0
        self._next_completion: _Scheduled | None = None

    @property
    def active_flows(self) -> int:
        return len(self._flows)

    def submit(self, work: float, on_done: Callable[[], None]) -> None:
        """Submit ``work`` units (e.g. bytes); ``on_done`` fires at completion."""
        if work <= 0:
            self.sim.schedule_fast(0.0, on_done)
            return
        flows = self._flows
        n = len(flows)
        if n:   # advance V at the pre-arrival rate (inlined _advance_vtime)
            dt = self.sim.now - self._last_ts
            if dt > 0:
                self._vtime += dt * (self.capacity / n)
        self._last_ts = self.sim.now
        fid = next(self._ids)
        flows[fid] = on_done
        heapq.heappush(self._finish_heap, (self._vtime + float(work), fid))
        if self._next_completion is not None:
            self._next_completion.canceled = True
        delay = max(self._finish_heap[0][0] - self._vtime, 0.0) \
            * (n + 1) / self.capacity
        self._next_completion = self.sim.schedule(delay, self._complete)

    def _complete(self) -> None:
        flows = self._flows
        n = len(flows)
        dt = self.sim.now - self._last_ts
        if dt > 0:
            self._vtime += dt * (self.capacity / n)
        self._last_ts = self.sim.now
        _vtag, fid = heapq.heappop(self._finish_heap)
        on_done = flows.pop(fid)
        if n > 1:
            delay = max(self._finish_heap[0][0] - self._vtime, 0.0) \
                * (n - 1) / self.capacity
            self._next_completion = self.sim.schedule(delay, self._complete)
        else:
            self._next_completion = None
        on_done()
