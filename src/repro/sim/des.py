"""Discrete-event simulation core (virtual clock).

The paper's experiments ran on AWS (Lambda/Kinesis) and XSEDE HPC machines
(Wrangler, Stampede2) — hardware this container cannot reach.  Per DESIGN.md
§2 we reproduce both platforms as *mechanism-level* simulations: backends
model CPU shares, shared-filesystem bandwidth, coherence synchronization and
cold starts; contention (sigma) and coherence (kappa) then *emerge* from the
mechanisms and are recovered by the USL fit, keeping the validation
non-circular.

The simulator is a standard event-queue DES: entities schedule callbacks at
virtual timestamps; ``run_until`` advances the clock.  Deterministic given a
seed (all stochastic service-time jitter flows through ``self.rng``).
``events_processed`` counts executed (non-canceled) events — the cost metric
the perf-smoke benchmark and the push-based streaming engine are judged on.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

__all__ = ["Simulator", "SimProcessError"]


class SimProcessError(RuntimeError):
    """Raised inside a simulated task to signal failure (walltime kill, ...)."""


@dataclass(order=True)
class _Scheduled:
    ts: float
    seq: int
    fn: Callable[[], None] = field(compare=False)
    canceled: bool = field(default=False, compare=False)


class Simulator:
    """Minimal, deterministic discrete-event simulator."""

    def __init__(self, seed: int = 0) -> None:
        self._queue: list[_Scheduled] = []
        self._seq = itertools.count()
        self.now: float = 0.0
        self.rng = np.random.default_rng(seed)
        self.events_processed: int = 0

    def schedule(self, delay: float, fn: Callable[[], None]) -> _Scheduled:
        """Schedule ``fn`` to run ``delay`` seconds from now."""
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        ev = _Scheduled(self.now + delay, next(self._seq), fn)
        heapq.heappush(self._queue, ev)
        return ev

    def cancel(self, ev: _Scheduled) -> None:
        ev.canceled = True

    def step(self) -> bool:
        """Run the next event. Returns False when the queue is empty."""
        while self._queue:
            ev = heapq.heappop(self._queue)
            if ev.canceled:
                continue
            self.now = ev.ts
            self.events_processed += 1
            ev.fn()
            return True
        return False

    def run_until(self, t: float | None = None, predicate: Callable[[], bool] | None = None,
                  max_events: int = 50_000_000) -> None:
        """Advance until time ``t``, ``predicate()`` is true, or queue empty."""
        for _ in range(max_events):
            if predicate is not None and predicate():
                return
            if not self._queue:
                return
            if t is not None and self._queue[0].ts > t:
                self.now = t
                return
            self.step()
        raise RuntimeError("simulation exceeded max_events — runaway event loop?")

    def run(self) -> None:
        self.run_until()

    # -- convenience: stochastic service times ------------------------------
    def lognormal_jitter(self, mean: float, cv: float) -> float:
        """Multiplicative lognormal jitter around ``mean`` with coefficient of
        variation ``cv`` (cv=0 → deterministic)."""
        if cv <= 0.0:
            return mean
        sigma2 = np.log1p(cv * cv)
        mu = -0.5 * sigma2
        return float(mean * self.rng.lognormal(mu, np.sqrt(sigma2)))


class SimLock:
    """FIFO mutex on the virtual clock.

    Models the shared-model read-modify-write critical section the paper's
    HPC runs serialize on ("synchronization of the model updates via the
    shared filesystem"): one holder at a time, waiters queue.
    """

    def __init__(self, sim: Simulator, name: str = "lock") -> None:
        self.sim = sim
        self.name = name
        self._held = False
        self._waiters: list[Callable[[], None]] = []

    def acquire(self, on_acquired: Callable[[], None]) -> None:
        if not self._held:
            self._held = True
            self.sim.schedule(0.0, on_acquired)
        else:
            self._waiters.append(on_acquired)

    def release(self) -> None:
        if self._waiters:
            nxt = self._waiters.pop(0)
            self.sim.schedule(0.0, nxt)
        else:
            self._held = False

    @property
    def queue_len(self) -> int:
        return len(self._waiters)


class SharedResource:
    """Processor-sharing resource: ``capacity`` units/sec split evenly among
    active flows.  Models a shared filesystem / network link.

    Because flow completion times depend on future arrivals, we implement the
    standard PS recompute-on-change algorithm: every arrival/departure
    re-evaluates remaining work and reschedules the next completion.
    """

    def __init__(self, sim: Simulator, capacity: float, name: str = "res") -> None:
        self.sim = sim
        self.capacity = float(capacity)
        self.name = name
        self._flows: dict[int, dict[str, Any]] = {}
        self._ids = itertools.count()
        self._next_completion: _Scheduled | None = None

    @property
    def active_flows(self) -> int:
        return len(self._flows)

    def submit(self, work: float, on_done: Callable[[], None]) -> None:
        """Submit ``work`` units (e.g. bytes); ``on_done`` fires at completion."""
        if work <= 0:
            self.sim.schedule(0.0, on_done)
            return
        self._advance_progress()
        fid = next(self._ids)
        self._flows[fid] = {"remaining": float(work), "on_done": on_done}
        self._reschedule()

    def _rate_per_flow(self) -> float:
        n = len(self._flows)
        return self.capacity / n if n else self.capacity

    def _advance_progress(self) -> None:
        """Account work done since the last event at the current share rate."""
        now = self.sim.now
        last = getattr(self, "_last_ts", now)
        dt = now - last
        if dt > 0 and self._flows:
            rate = self._rate_per_flow()
            for f in self._flows.values():
                f["remaining"] -= rate * dt
        self._last_ts = now

    def _reschedule(self) -> None:
        if self._next_completion is not None:
            self.sim.cancel(self._next_completion)
            self._next_completion = None
        if not self._flows:
            return
        rate = self._rate_per_flow()
        fid, f = min(self._flows.items(), key=lambda kv: kv[1]["remaining"])
        delay = max(f["remaining"], 0.0) / rate
        self._next_completion = self.sim.schedule(delay, lambda: self._complete(fid))

    def _complete(self, fid: int) -> None:
        self._advance_progress()
        f = self._flows.pop(fid, None)
        self._next_completion = None
        self._reschedule()
        if f is not None:
            f["on_done"]()
