from repro.sim.des import SharedResource, Simulator

__all__ = ["Simulator", "SharedResource"]
