"""StreamInsight end-to-end: experimental design → automated runs (process
pool) → USL models → prediction quality → a concrete configuration
recommendation.

Also demonstrates the beyond-paper finding: switching the HPC model-sharing
consistency policy from ``full_fit_locked`` (what the paper's Dask numbers
imply) to ``update_locked`` (stale-read distance phase outside the lock)
moves sigma from ~0.9 to ~0.2 and the predicted optimal partition count from
~2 to >8 — StreamInsight quantifying an optimization before deploying it.
The ablation uses ``policy`` as a first-class grid axis: one design, one
parallel sweep, one model per policy scenario.

The ``__main__`` guard is required: the parallel runner's workers are
started with a non-fork context and re-import this module.

    PYTHONPATH=src python examples/characterize.py
"""

import numpy as np

from repro.core.autoscale import Autoscaler
from repro.core.streaminsight import ExperimentDesign, StreamInsight

PARTITIONS = [1, 2, 4, 8, 12, 16]


def main() -> None:
    print("=== running the experiment grid (virtual clock, process pool)")
    si = StreamInsight()
    si.run(ExperimentDesign(machines=["serverless", "wrangler"],
                            partitions=PARTITIONS, points=[16000],
                            centroids=[1024], n_messages=50), verbose=True,
           parallel=True)
    print()
    print(si.report())

    print("\n=== prediction quality vs training-set size (paper Fig 7)")
    for n_train in [2, 3, 4]:
        agg = si.evaluate(n_train)
        print(f"  {n_train} train configs -> mean rel-RMSE "
              f"{agg['mean_rel_rmse'] * 100:.1f}%")

    print("\n=== recommendation per scenario")
    for m in si.fit_models():
        scaler = Autoscaler(m.fit)
        machine = m.key[0]
        print(f"  {machine:>10}: run N={scaler.usable_peak_n()} partitions "
              f"(peak {scaler.max_sustainable_rate():.2f} msg/s)")

    print("\n=== beyond-paper: consistency-policy ablation on HPC")
    si2 = StreamInsight()
    si2.run(ExperimentDesign(machines=["wrangler"], partitions=PARTITIONS,
                             points=[16000], centroids=[8192], n_messages=40,
                             policy=["full_fit_locked", "update_locked"]),
            parallel=True)
    for m in si2.fit_models():
        policy = m.key[4]
        peak = m.fit.peak_n
        peak_s = f"{peak:.1f}" if peak != float("inf") else "inf"
        print(f"  {policy:>17}: sigma={m.fit.sigma:.3f} kappa={m.fit.kappa:.5f} "
              f"peak_N={peak_s:>5} T(16)={m.fit.predict(16):.2f} msg/s")
    print("characterize OK")


if __name__ == "__main__":
    main()
