"""End-to-end training example: a ~100M-param LM for a few hundred steps,
with async checkpointing and restart, through the production launcher.

On this CPU container the default invocation trains a width-reduced variant
of the same family so a full run finishes in minutes; pass ``--full-100m``
on real hardware for the 100M-class config — identical code path.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import sys

from repro.configs.base import ModelConfig, register


def lm100m() -> ModelConfig:
    """~100M-class dense LM (qwen2-family blocks)."""
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=10, d_model=640, n_heads=10,
        n_kv_heads=2, d_ff=2560, vocab_size=50304, d_head=64, qkv_bias=True,
        source="example config (qwen2-family blocks)")


def lm_small() -> ModelConfig:
    """CPU-friendly variant (same family, narrower)."""
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=6, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=1024, vocab_size=8192, d_head=64, qkv_bias=True,
        attn_chunk=64)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    register(lm100m(), lm_small())
    name = "lm-100m"
    from repro.configs.base import get_config
    cfg = get_config(name, reduced=not args.full_100m)
    print(f"training {name} ({'full' if args.full_100m else 'cpu-reduced'}): "
          f"~{cfg.param_count() / 1e6:.0f}M params")

    from repro.launch import train as train_mod
    sys.argv = ["train", "--arch", name, "--steps", str(args.steps),
                "--batch", str(args.batch), "--seq", str(args.seq),
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
                "--lr", "1e-3"] + ([] if args.full_100m else ["--reduced"])
    train_mod.main()


if __name__ == "__main__":
    main()
