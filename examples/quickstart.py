"""Quickstart: the paper's stack in ~50 lines of user code.

A pilot (local backend), a 2-partition broker topic, a producer, and the
streaming engine running REAL JAX MiniBatch K-Means on every message —
the paper's Streaming Mini-App end to end, with run-id-traced metrics.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import MetricRegistry, new_run_id, percentile_summary
from repro.models import kmeans
from repro.pilot.api import PilotComputeService, PilotDescription
from repro.streaming.broker import Broker
from repro.streaming.engine import ThreadedStreamingEngine, Workload

N_MESSAGES, POINTS, DIM, CENTROIDS = 24, 512, 9, 32

# 1. resources: a pilot on the local backend (swap the URL for
#    serverless://aws-sim, hpc://wrangler-sim, or jax://mesh)
pcs = PilotComputeService()
pilot = pcs.submit_pilot(PilotDescription(resource="local://", concurrency=2))

# 2. a broker topic with 2 partitions (Kinesis shards / Kafka partitions)
broker = Broker()
broker.create_topic("points", 2)

# 3. the workload: MiniBatch K-Means model update per message (real JAX).
#    The model is shared across partitions -> guard the read-modify-write
#    (exactly the paper's consistency concern; on Lambda/S3 it would be
#    lock-free last-writer-wins instead).
import threading

state = kmeans.init_state(jax.random.PRNGKey(0), CENTROIDS, DIM)
inertias = []
model_lock = threading.Lock()


def process(msgs):
    global state
    for m in msgs:
        pts = jnp.asarray(m.value)
        with model_lock:
            state = kmeans.minibatch_step(state, pts)
            inertias.append(float(kmeans.inertia(pts, state.centroids)))


# 4. the engine binds the workload to the topic on the pilot
metrics = MetricRegistry()
run_id = new_run_id("quickstart")
engine = ThreadedStreamingEngine(broker, "points", pilot,
                                 Workload(fn=process, name="kmeans"),
                                 metrics, run_id, batch_max=2)
engine.start()

# 5. produce a clustered stream and let the engine drain it
rng = np.random.default_rng(0)
centers = rng.normal(size=(4, DIM)) * 3
for i in range(N_MESSAGES):
    pts = centers[rng.integers(0, 4, POINTS)] + rng.normal(size=(POINTS, DIM))
    broker.append("points", pts.astype(np.float32), ts=time.perf_counter(),
                  run_id=run_id, msg_id=f"{run_id}/{i}",
                  size_bytes=POINTS * DIM * 4)
    metrics.record(run_id, "broker", "append", time.perf_counter(),
                   msg_id=f"{run_id}/{i}")
engine.drain(N_MESSAGES, timeout=120)
engine.stop()
pcs.close()

lat = metrics.latencies(run_id, "append", "complete")
print(f"processed {engine.core.processed}/{N_MESSAGES} messages")
print(f"L^px p50={percentile_summary(lat)['p50'] * 1e3:.1f} ms")
print(f"inertia first->last: {inertias[0]:.3f} -> {inertias[-1]:.3f} "
      f"(model converged: {inertias[-1] < inertias[0]})")
assert inertias[-1] < inertias[0]
print("quickstart OK")
