"""Streaming LM inference + USL-driven predictive autoscaling.

Part 1 — real serving: requests flow broker → engine → pilot, each
micro-batch runs prefill + greedy decode of a reduced LM (real JAX compute).

Part 2 — the paper's technique closing the loop: StreamInsight measures
serving throughput vs partitions on the serverless simulation (profile
derived from the SAME model's analytic FLOPs), fits the USL, and the
autoscaler answers "how many partitions for an offered rate, and when must
the source be throttled?" — the paper's §V future work, implemented.

    PYTHONPATH=src python examples/serve_stream.py
"""

import sys

import numpy as np

from repro.configs.base import get_config
from repro.core.autoscale import Autoscaler, AutoscalePolicy
from repro.core.metrics import MetricRegistry
from repro.core.usl import fit_usl
from repro.pilot.api import (ComputeUnitDescription, PilotComputeService,
                             PilotDescription, TaskProfile)

ARCH = "qwen2-0.5b"

# ---- part 1: real serving through the production launcher -----------------
print("=== part 1: streaming LM serving (real compute, local pilot)")
from repro.launch import serve as serve_mod

sys.argv = ["serve", "--arch", ARCH, "--reduced", "--requests", "12",
            "--partitions", "2", "--prompt-len", "16", "--new-tokens", "4"]
serve_mod.main()

# ---- part 2: characterize + predict + autoscale ----------------------------
print("\n=== part 2: USL characterization of serving scale-out (sim)")
cfg = get_config(ARCH)   # full config for the cost model
flops_per_req = 2.0 * cfg.active_param_count() * (16 + 4)   # prefill+decode

ns, ts = [], []
for n in [1, 2, 4, 8, 12, 16, 24]:
    pcs = PilotComputeService(seed=0)
    pilot = pcs.submit_pilot(PilotDescription(
        resource="serverless://aws-sim", memory_mb=3008, partitions=n))
    prof = TaskProfile(flops=flops_per_req / 1e3, msg_bytes=16 * 4,
                       read_bytes=1e6, write_bytes=0)
    cus = [pilot.submit_compute_unit(ComputeUnitDescription(profile=prof))
           for _ in range(30 * n)]
    pilot.wait_all()
    done = [c for c in cus if c.state.name == "DONE"]
    span = max(c.end_ts for c in done) - min(c.start_ts for c in done)
    ns.append(n)
    ts.append(len(done) / span)
    pcs.close()

fit = fit_usl(np.array(ns, float), np.array(ts, float))
print("USL fit:", fit.summary())

scaler = Autoscaler(fit, AutoscalePolicy(headroom=0.15, max_partitions=30))
print(f"max sustainable rate: {scaler.max_sustainable_rate():.1f} req/s")
for target in [5, 20, 60, 200]:
    n = scaler.partitions_for(target)
    print(f"  target {target:4d} req/s -> partitions: "
          f"{n if n is not None else f'UNSUSTAINABLE (throttle to {scaler.throttle_rate(target):.0f} req/s)'}")

rates = [3, 8, 25, 60, 25, 8, 3]
plan = scaler.plan(rates)
print(f"autoscale plan for rate series {rates}: {plan}")
print("serve_stream OK")
