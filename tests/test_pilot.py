"""Tests for the pilot abstraction and its backend plugins."""

import numpy as np
import pytest

from repro.pilot.api import (ComputeUnitDescription, PilotComputeService,
                             PilotDescription, State, TaskProfile)


def make_service(**kw):
    return PilotComputeService(**kw)


# -- local backend (real execution) ------------------------------------------

def test_local_backend_executes_real_function():
    pcs = make_service()
    pilot = pcs.submit_pilot(PilotDescription(resource="local://", concurrency=2))
    cu = pilot.submit_compute_unit(func=lambda a, b: a + b, args=(2, 3))
    assert cu.result(timeout=10) == 5
    assert cu.state == State.DONE
    pcs.close()


def test_local_backend_failure_propagates():
    pcs = make_service()
    pilot = pcs.submit_pilot(PilotDescription(resource="local://"))

    def boom():
        raise ValueError("boom")

    cu = pilot.submit_compute_unit(func=boom)
    with pytest.raises(ValueError, match="boom"):
        cu.result(timeout=10)
    assert cu.state == State.FAILED
    pcs.close()


def test_local_backend_parallel_tasks():
    pcs = make_service()
    pilot = pcs.submit_pilot(PilotDescription(resource="local://", concurrency=4))
    cus = [pilot.submit_compute_unit(func=lambda i=i: i * i) for i in range(8)]
    assert [cu.result(timeout=10) for cu in cus] == [i * i for i in range(8)]
    pcs.close()


# -- serverless sim backend ---------------------------------------------------

PROFILE = TaskProfile(flops=2e9, read_bytes=4e4, write_bytes=4e4, msg_bytes=3e5)


def run_one(memory_mb, profile=PROFILE, **pilot_kw):
    pcs = make_service(seed=1)
    pilot = pcs.submit_pilot(PilotDescription(
        resource="serverless://aws-sim", memory_mb=memory_mb, partitions=1, **pilot_kw))
    cu = pilot.submit_compute_unit(ComputeUnitDescription(profile=profile))
    cu.wait()
    return cu


def test_lambda_memory_scales_cpu():
    """Paper Fig 3: larger containers -> shorter runtimes (CPU prop. to mem)."""
    runtimes = [run_one(m).runtime for m in [256, 512, 1024, 2048, 3008]]
    assert all(np.diff(runtimes) < 0), runtimes
    # scaling is roughly 1/memory for the compute-bound part
    assert runtimes[0] / runtimes[-1] > 5


def test_lambda_memory_cap_3008():
    """Memory above the 2019 cap gives no extra CPU."""
    r1 = run_one(3008).runtime
    r2 = run_one(10000).runtime
    assert r2 == pytest.approx(r1, rel=0.15)


def test_lambda_walltime_kill():
    cu = run_one(128, profile=TaskProfile(flops=1e13))  # hours at 128MB
    assert cu.state == State.FAILED
    assert isinstance(cu.exception, TimeoutError)


def test_lambda_oom():
    cu = run_one(512, profile=TaskProfile(flops=1.0, memory_mb=4096))
    assert cu.state == State.FAILED
    assert isinstance(cu.exception, MemoryError)


def test_lambda_concurrency_cap_30():
    """Paper: at most 30 concurrent containers even with more partitions."""
    pcs = make_service(seed=0)
    pilot = pcs.submit_pilot(PilotDescription(
        resource="serverless://aws-sim", memory_mb=3008, partitions=64))
    backend = pilot.backend
    assert len(backend._pilots[pilot.uid]["containers"]) == 30


def test_lambda_cold_start_once_per_container():
    pcs = make_service(seed=2)
    pilot = pcs.submit_pilot(PilotDescription(
        resource="serverless://aws-sim", memory_mb=3008, partitions=1,
        attrs={"jitter_cv_ref": 0.0}))
    p = TaskProfile(flops=1e9)
    cu1 = pilot.submit_compute_unit(ComputeUnitDescription(profile=p))
    cu1.wait()
    cu2 = pilot.submit_compute_unit(ComputeUnitDescription(profile=p))
    cu2.wait()
    assert cu1.attrs["cold"] and not cu2.attrs["cold"]
    assert cu1.runtime > cu2.runtime  # cold start penalty


def test_serverless_executes_real_function_too():
    pcs = make_service(seed=0)
    pilot = pcs.submit_pilot(PilotDescription(resource="serverless://aws-sim"))
    cu = pilot.submit_compute_unit(ComputeUnitDescription(
        func=lambda: 42, profile=TaskProfile(flops=1e6)))
    assert cu.result() == 42


# -- hpc sim backend ----------------------------------------------------------

def test_hpc_lock_serializes_serial_flops():
    """Tasks whose work is all serial_flops cannot run concurrently."""
    pcs = make_service(seed=0)
    pilot = pcs.submit_pilot(PilotDescription(
        resource="hpc://wrangler-sim", partitions=4, attrs={"jitter_cv": 0.0}))
    prof = TaskProfile(serial_flops=5.2e9)  # exactly 1s of locked work
    cus = [pilot.submit_compute_unit(ComputeUnitDescription(profile=prof))
           for _ in range(4)]
    pilot.wait_all()
    end_times = sorted(cu.end_ts for cu in cus)
    # lock forces ~1s spacing despite 4 workers
    gaps = np.diff(end_times)
    assert np.all(gaps > 0.9), gaps


def test_hpc_parallel_flops_scale():
    """Pure-parallel tasks finish ~concurrently on distinct workers."""
    pcs = make_service(seed=0)
    pilot = pcs.submit_pilot(PilotDescription(
        resource="hpc://wrangler-sim", partitions=4, attrs={"jitter_cv": 0.0}))
    prof = TaskProfile(flops=3.6e9)
    cus = [pilot.submit_compute_unit(ComputeUnitDescription(profile=prof))
           for _ in range(4)]
    pilot.wait_all()
    end_times = [cu.end_ts for cu in cus]
    assert max(end_times) - min(end_times) < 0.2, end_times


def test_hpc_stampede2_slower_cores():
    def run(machine):
        pcs = make_service(seed=0)
        pilot = pcs.submit_pilot(PilotDescription(
            resource=f"hpc://{machine}-sim", partitions=1, attrs={"jitter_cv": 0.0}))
        cu = pilot.submit_compute_unit(ComputeUnitDescription(
            profile=TaskProfile(flops=1e10)))
        cu.wait()
        return cu.runtime

    assert run("stampede2") > run("wrangler")


def test_hpc_kill_worker_fails_running_cu():
    pcs = make_service(seed=0)
    pilot = pcs.submit_pilot(PilotDescription(
        resource="hpc://wrangler-sim", partitions=2, attrs={"jitter_cv": 0.0}))
    backend = pilot.backend
    prof = TaskProfile(flops=3.6e10)  # 10s
    cu = pilot.submit_compute_unit(ComputeUnitDescription(profile=prof, partition=0))
    backend.sim.run_until(t=1.0)
    assert cu.state == State.RUNNING
    backend.kill_worker(pilot, cu.attrs["worker"])
    assert cu.state == State.FAILED
    assert isinstance(cu.exception, ConnectionError)


def test_unknown_machine_rejected():
    pcs = make_service()
    with pytest.raises(ValueError, match="unknown HPC machine"):
        pcs.submit_pilot(PilotDescription(resource="hpc://frontier-sim"))


def test_shared_resource_public_accessor():
    """backend.shared_resource(pilot, name) replaces reaching into
    backend._pilots[...]: HPC exposes the Lustre resource and the model
    lock; isolated backends raise LookupError."""
    from repro.sim.des import SharedResource, SimLock

    pcs = make_service()
    hpc = pcs.submit_pilot(PilotDescription(resource="hpc://wrangler-sim",
                                            partitions=2))
    assert isinstance(hpc.backend.shared_resource(hpc, "fs"), SharedResource)
    assert isinstance(hpc.backend.shared_resource(hpc, "model_lock"), SimLock)
    with pytest.raises(LookupError):
        hpc.backend.shared_resource(hpc, "gpfs")

    sls = pcs.submit_pilot(PilotDescription(resource="serverless://aws-sim",
                                            partitions=2))
    with pytest.raises(LookupError):
        sls.backend.shared_resource(sls, "fs")


# -- jaxmesh backend -------------------------------------------------------------

def test_jaxmesh_pilot_runs_under_mesh():
    import jax
    import jax.numpy as jnp

    pcs = make_service()
    pilot = pcs.submit_pilot(PilotDescription(
        resource="jax://mesh", attrs={"mesh_shape": (1,), "mesh_axes": ("data",)}))
    assert pilot.mesh.shape == {"data": 1}

    def fn():
        return float(jnp.sum(jnp.ones((4, 4))))

    cu = pilot.submit_compute_unit(func=fn)
    assert cu.result(timeout=30) == 16.0


def test_jaxmesh_overallocation_rejected():
    pcs = make_service()
    with pytest.raises(RuntimeError, match="devices"):
        pcs.submit_pilot(PilotDescription(
            resource="jax://mesh", attrs={"mesh_shape": (1000,), "mesh_axes": ("data",)}))
