"""Fallback property-testing shim for environments without ``hypothesis``.

The tier-1 suite uses a small slice of the hypothesis API (``given``,
``settings``, ``strategies.{floats,integers,lists,tuples,sampled_from}``).
This container cannot install hypothesis, so ``install()`` — called from
``conftest.py`` before test modules are imported — registers a minimal
stand-in under ``sys.modules['hypothesis']`` when the real package is
absent.  Test modules keep their idiomatic ``from hypothesis import ...``
imports and work in both worlds.

The stand-in degrades gracefully: each ``@given`` test runs a small, fixed,
deterministic set of examples (boundary values first, then seeded-random
draws) instead of hypothesis's adaptive search.  That is deliberately a
smoke-strength property check, not a replacement for real hypothesis.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types

# Fixed example budget for the fallback: boundaries + a few random draws.
_MAX_EXAMPLES = 8
_SEED = 0x5EED_CAFE


class _Strategy:
    """One value generator.  ``draw(rng, i)`` yields example ``i``: index 0
    and 1 are the strategy's boundary values, the rest are random."""

    def draw(self, rng: random.Random, i: int):
        raise NotImplementedError

    def map(self, fn):
        outer = self

        class _Mapped(_Strategy):
            def draw(self, rng, i):
                return fn(outer.draw(rng, i))

        return _Mapped()


class _Floats(_Strategy):
    def __init__(self, lo: float, hi: float) -> None:
        self.lo, self.hi = float(lo), float(hi)

    def draw(self, rng, i):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return rng.uniform(self.lo, self.hi)


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int) -> None:
        self.lo, self.hi = int(lo), int(hi)

    def draw(self, rng, i):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _SampledFrom(_Strategy):
    def __init__(self, elements) -> None:
        self.elements = list(elements)

    def draw(self, rng, i):
        if i < len(self.elements):
            return self.elements[i]
        return rng.choice(self.elements)


class _Lists(_Strategy):
    def __init__(self, elem: _Strategy, min_size: int = 0,
                 max_size: int | None = None) -> None:
        self.elem = elem
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10

    def draw(self, rng, i):
        if i == 0:
            size = self.min_size
        elif i == 1:
            size = min(self.max_size, max(self.min_size, 3))
        else:
            size = rng.randint(self.min_size, min(self.max_size, 16))
        return [self.elem.draw(rng, 2 + rng.randint(0, 10)) for _ in range(size)]


class _Tuples(_Strategy):
    def __init__(self, *elems: _Strategy) -> None:
        self.elems = elems

    def draw(self, rng, i):
        return tuple(e.draw(rng, i) for e in self.elems)


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Floats(min_value, max_value)


def integers(min_value: int, max_value: int, **_kw) -> _Strategy:
    return _Integers(min_value, max_value)


def sampled_from(elements) -> _Strategy:
    return _SampledFrom(elements)


def lists(elements: _Strategy, *, min_size: int = 0, max_size: int | None = None,
          **_kw) -> _Strategy:
    return _Lists(elements, min_size=min_size, max_size=max_size)


def tuples(*elements: _Strategy) -> _Strategy:
    return _Tuples(*elements)


def given(*garg_strategies: _Strategy, **gkw_strategies: _Strategy):
    def deco(fn):
        # Like real hypothesis, positional strategies bind to the RIGHTMOST
        # unbound parameters (leading params stay free, e.g. for fixtures).
        params = list(inspect.signature(fn).parameters.values())
        free = [p.name for p in params if p.name not in gkw_strategies]
        pos_names = free[len(free) - len(garg_strategies):] \
            if garg_strategies else []

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = min(getattr(wrapper, "_compat_max_examples",
                            getattr(fn, "_compat_max_examples", _MAX_EXAMPLES)),
                    _MAX_EXAMPLES)
            rng = random.Random(_SEED)
            for i in range(n):
                kws = {name: s.draw(rng, i)
                       for name, s in zip(pos_names, garg_strategies)}
                kws.update({k: s.draw(rng, i)
                            for k, s in gkw_strategies.items()})
                fn(*args, **kws, **kwargs)

        # Hide the strategy-bound parameters from pytest's fixture resolver:
        # expose only the params given() does NOT fill in.
        bound = set(pos_names) | set(gkw_strategies)
        residual = [p for p in params if p.name not in bound]
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature(residual)
        wrapper.hypothesis_compat_fallback = True
        return wrapper

    return deco


def settings(**kwargs):
    def deco(fn):
        fn._compat_max_examples = min(kwargs.get("max_examples", _MAX_EXAMPLES),
                                      _MAX_EXAMPLES)
        return fn

    return deco


def install() -> None:
    """Register the shim as ``hypothesis`` iff the real package is missing."""
    try:
        import hypothesis  # noqa: F401 — real package wins
        return
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("floats", "integers", "lists", "tuples", "sampled_from"):
        setattr(st_mod, name, globals()[name])
    mod.strategies = st_mod
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
