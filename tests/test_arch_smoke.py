"""Per-architecture smoke tests: reduced config, one forward + train-grad +
prefill/decode consistency on CPU.  Asserts shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs
from repro.models import model as M

ARCHS = list_configs()
B, S = 2, 32


def make_batch(cfg, key, batch=B, seq=S):
    kt, ke = jax.random.split(key)
    tokens = jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size, jnp.int32)
    batch_d = {"tokens": tokens}
    if cfg.frontend is not None:
        batch_d["embeds"] = 0.02 * jax.random.normal(
            ke, (batch, cfg.n_prefix, cfg.d_model), jnp.float32)
    return batch_d


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch, rng):
    cfg = get_config(arch, reduced=True)
    params = M.init_params(rng, cfg)
    batch = make_batch(cfg, rng)
    logits = M.forward(params, cfg, batch["tokens"], batch.get("embeds"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_loss_and_grads_finite(arch, rng):
    cfg = get_config(arch, reduced=True)
    params = M.init_params(rng, cfg)
    batch = make_batch(cfg, rng)
    loss, grads = jax.value_and_grad(M.loss_fn)(params, cfg, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss {loss}"
    # a random model over V tokens should sit near log(V)
    assert 0.1 * np.log(cfg.vocab_size) < float(loss) < 3.0 * np.log(cfg.vocab_size)
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert bool(jnp.isfinite(g).all()), f"{arch}: non-finite grad"
    nonzero = sum(float(jnp.sum(jnp.abs(g))) > 0 for g in leaves)
    assert nonzero > len(leaves) // 2, f"{arch}: too many zero grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, rng):
    """Prefill+decode logits must match full-sequence forward (the KV-cache /
    recurrent-state path is exact, not an approximation)."""
    cfg = get_config(arch, reduced=True)
    params = M.init_params(rng, cfg)
    batch = make_batch(cfg, rng)
    tokens = batch["tokens"]
    full = M.forward(params, cfg, tokens, batch.get("embeds"))

    n_prompt = S // 2
    logits_p, caches = M.prefill(params, cfg, tokens[:, :n_prompt], cache_len=S,
                                 embeds=batch.get("embeds"))
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full[:, n_prompt - 1]),
                               rtol=2e-2, atol=2e-2)
    # decode the next tokens one by one, teacher-forced
    logits_d = logits_p
    for i in range(n_prompt, min(n_prompt + 4, S)):
        logits_d, caches = M.decode_step(params, cfg, tokens[:, i], caches, i)
        np.testing.assert_allclose(np.asarray(logits_d), np.asarray(full[:, i]),
                                   rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "recurrentgemma-2b",
                                  "mamba2-130m", "granite-moe-3b-a800m"])
def test_greedy_generate_runs(arch, rng):
    cfg = get_config(arch, reduced=True)
    params = M.init_params(rng, cfg)
    prompt = jax.random.randint(rng, (1, 8), 0, cfg.vocab_size, jnp.int32)
    out = M.greedy_generate(params, cfg, prompt, n_new=4)
    assert out.shape == (1, 4)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_structure_matches(arch, rng):
    """The sharding-spec tree must mirror the param tree exactly."""
    cfg = get_config(arch, reduced=True)
    params = M.init_params(rng, cfg)
    specs = M.param_specs(cfg)
    pstruct = jax.tree.structure(params)
    sstruct = jax.tree.structure(specs, is_leaf=lambda s: isinstance(s, tuple))
    assert pstruct == sstruct, f"{arch}:\n{pstruct}\nvs\n{sstruct}"
    # every spec entry has the right rank
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, tuple))
    for a, s in zip(flat_p, flat_s):
        assert a.ndim == len(s), f"{arch}: param rank {a.shape} vs spec {s}"


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_specs_structure_matches(arch, rng):
    cfg = get_config(arch, reduced=True)
    caches = M.cache_init(cfg, B, 16)
    specs = M.cache_specs(cfg)
    cstruct = jax.tree.structure(caches)
    sstruct = jax.tree.structure(specs, is_leaf=lambda s: isinstance(s, tuple))
    assert cstruct == sstruct
    for a, s in zip(jax.tree.leaves(caches),
                    jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, tuple))):
        assert a.ndim == len(s), f"{arch}: cache rank {a.shape} vs spec {s}"
