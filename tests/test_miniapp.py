"""Mini-app + StreamInsight + autoscaler: the paper's claims as tests."""

import numpy as np
import pytest

from repro.core.autoscale import Autoscaler, AutoscalePolicy
from repro.core.miniapp import (KMeansStreamWorkload, StreamExperiment,
                                run_experiment)
from repro.core.streaminsight import ExperimentDesign, StreamInsight
from repro.core.usl import USLFit, fit_usl


def throughputs(machine, partitions, policy=None, **kw):
    out = []
    for n in partitions:
        res = run_experiment(StreamExperiment(
            machine=machine, partitions=n, n_messages=40, policy=policy, **kw))
        out.append(res.throughput)
    return np.array(out)


def test_workload_profile_scaling():
    small = KMeansStreamWorkload(points=8000, centroids=128).profile()
    big_c = KMeansStreamWorkload(points=8000, centroids=8192).profile()
    big_p = KMeansStreamWorkload(points=26000, centroids=128).profile()
    assert big_c.serial_flops > 10 * small.serial_flops
    assert big_p.msg_bytes > 3 * small.msg_bytes
    # paper: 8,000 points ≈ 296 KB
    assert small.msg_bytes == pytest.approx(296_000, rel=0.01)


def test_reference_cell_event_budget():
    """Push-based engine acceptance: the reference cell (N=8, 200 messages)
    must stay >= 5x below the seed polling engine's 6,189 DES events."""
    res = run_experiment(StreamExperiment(
        machine="serverless", partitions=8, n_messages=200, seed=0))
    assert res.processed == 200
    assert res.des_events > 0
    assert res.des_events <= 6189 / 5, res.des_events


def test_serverless_scales_linearly():
    ns = [1, 2, 4, 8]
    t = throughputs("serverless", ns)
    fit = fit_usl(np.array(ns, float), t)
    assert fit.sigma < 0.1 and fit.kappa < 1e-3
    assert t[-1] / t[0] > 6.0


def test_hpc_sigma_in_paper_band():
    ns = [1, 2, 4, 8, 16]
    t = throughputs("wrangler", ns)
    fit = fit_usl(np.array(ns, float), t)
    assert 0.6 <= fit.sigma <= 1.0, fit.summary()
    assert fit.kappa > 1e-4
    assert fit.peak_n < 6


def test_hpc_absolute_beats_lambda_at_n1():
    """Paper: HPC provides better absolute performance (at small N)."""
    t_hpc = throughputs("wrangler", [1], centroids=8192)[0]
    t_lam = throughputs("serverless", [1], centroids=8192)[0]
    assert t_hpc > t_lam


def test_update_locked_policy_restores_scaling():
    """Beyond-paper: moving the distance phase out of the critical section."""
    ns = [1, 2, 4, 8]
    t_locked = throughputs("wrangler", ns, policy="full_fit_locked")
    t_update = throughputs("wrangler", ns, policy="update_locked")
    assert t_update[-1] / t_update[0] > 3.0
    assert t_locked[-1] / t_locked[0] < 1.5


def test_streaminsight_r2_band():
    si = StreamInsight()
    si.run(ExperimentDesign(machines=["serverless", "wrangler"],
                            partitions=[1, 2, 4, 8, 12], n_messages=40))
    for m in si.fit_models():
        assert m.fit.r2 > 0.85, str(m)


def test_streaminsight_eval_small_training_sets():
    si = StreamInsight()
    si.run(ExperimentDesign(machines=["serverless"],
                            partitions=[1, 2, 3, 4, 6, 8, 12, 16],
                            n_messages=60))
    agg = si.evaluate(3)
    # paper claim is qualitative ("well-performing with 2-3 configs");
    # 60-message windows carry sampling noise -> generous band
    assert agg["mean_rel_rmse"] < 0.2


# -- autoscaler ------------------------------------------------------------

def test_autoscaler_partition_choice():
    fit = USLFit(sigma=0.05, kappa=0.001, gamma=2.0, r2=1, rmse=0, n_obs=8)
    sc = Autoscaler(fit, AutoscalePolicy(headroom=0.1, max_partitions=64))
    n = sc.partitions_for(10.0)
    assert n is not None
    assert fit.predict(n) >= 10.0 * 1.1
    assert fit.predict(n - 1) < 10.0 * 1.1 or n == 1


def test_autoscaler_never_scales_into_retrograde():
    fit = USLFit(sigma=0.3, kappa=0.02, gamma=1.0, r2=1, rmse=0, n_obs=8)
    sc = Autoscaler(fit)
    assert sc.usable_peak_n() <= int(fit.peak_n)
    assert sc.partitions_for(1e9) is None       # impossible rate
    assert sc.throttle_rate(1e9) <= sc.max_sustainable_rate()


def test_autoscaler_hysteresis():
    fit = USLFit(sigma=0.0, kappa=0.0, gamma=1.0, r2=1, rmse=0, n_obs=8)
    sc = Autoscaler(fit, AutoscalePolicy(headroom=0.0, max_partitions=64,
                                         scale_down_hysteresis=0.3))
    plan = sc.plan([10, 11, 10, 9.5, 3, 10])
    assert plan[0] == 10
    assert plan[1] == 11                        # scale up promptly
    assert plan[3] == 11                        # small dip: no flap down
    assert plan[4] < plan[1]                    # big drop: scale down
