"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import mha_ref
from repro.kernels.kmeans_distance import ops as kd_ops
from repro.kernels.kmeans_distance.ref import assign_ref, pairwise_sq_dists_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan.ref import ssd_ref

KEY = jax.random.PRNGKey(0)


# -- kmeans_distance ----------------------------------------------------------

@pytest.mark.parametrize("n,k,d", [(64, 16, 9), (256, 128, 9), (128, 300, 32),
                                   (512, 64, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_dists_matches_ref(n, k, d, dtype):
    kx, kc = jax.random.split(KEY)
    x = jax.random.normal(kx, (n, d), dtype)
    c = jax.random.normal(kc, (k, d), dtype)
    got = kd_ops.pairwise_sq_dists(x, c, use_pallas=True, interpret=True)
    want = pairwise_sq_dists_ref(x.astype(jnp.float32), c.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * d)


@pytest.mark.parametrize("n,k,d", [(64, 16, 9), (256, 100, 17)])
def test_kmeans_assign_matches_ref(n, k, d):
    kx, kc = jax.random.split(KEY)
    x = jax.random.normal(kx, (n, d), jnp.float32)
    c = jax.random.normal(kc, (k, d), jnp.float32)
    labels, best = kd_ops.assign(x, c, use_pallas=True, interpret=True)
    ref_labels, ref_best = assign_ref(x, c)
    np.testing.assert_allclose(np.asarray(best), np.asarray(ref_best),
                               rtol=1e-5, atol=1e-5)
    # ties can flip labels; verify via distance equality instead of identity
    d2 = pairwise_sq_dists_ref(x, c)
    np.testing.assert_allclose(
        np.asarray(d2[np.arange(n), np.asarray(labels)]), np.asarray(ref_best),
        rtol=1e-5, atol=1e-5)


# -- flash_attention -----------------------------------------------------------

@pytest.mark.parametrize("bh,bkv,s,dh", [(4, 4, 128, 64), (8, 2, 256, 64),
                                         (2, 1, 64, 128), (6, 3, 96, 40)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(bh, bkv, s, dh, dtype):
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (bh, s, dh), dtype)
    k = jax.random.normal(kk, (bkv, s, dh), dtype)
    v = jax.random.normal(kv, (bkv, s, dh), dtype)
    got = fa_ops.flash_attention(q, k, v, use_pallas=True, interpret=True,
                                 block_q=32, block_k=32)
    want = mha_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                   v.astype(jnp.float32))
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=tol, atol=tol)


def test_flash_attention_long_context_blocks():
    """Bigger-than-block sequences exercise the multi-block online softmax."""
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (2, 512, 64), jnp.float32)
    k = jax.random.normal(kk, (1, 512, 64), jnp.float32)
    v = jax.random.normal(kv, (1, 512, 64), jnp.float32)
    got = fa_ops.flash_attention(q, k, v, use_pallas=True, interpret=True)
    want = mha_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# -- ssd_scan -------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,n,chunk", [(2, 64, 3, 16, 8, 16),
                                             (1, 128, 2, 32, 16, 32),
                                             (2, 96, 4, 8, 4, 32)])
def test_ssd_scan_matches_naive_recurrence(b, s, h, p, n, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    Cm = jax.random.normal(ks[4], (b, s, n), jnp.float32)
    y, hT = ssd_ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                             use_pallas=True, interpret=True)
    y_ref, h_ref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_jax_matches_naive():
    """The pure-JAX chunked SSD (model path) against the recurrence."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(KEY, 5)
    b, s, h, p, n = 2, 64, 3, 16, 8
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    Cm = jax.random.normal(ks[4], (b, s, n), jnp.float32)
    y, hT = ssd_chunked(x, dt, A, Bm, Cm, 16)
    y_ref, h_ref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_initial_state_threading():
    """Chunked SSD with h0 equals running the recurrence over a longer seq."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(KEY, 5)
    b, s, h, p, n = 1, 64, 2, 8, 4
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    Cm = jax.random.normal(ks[4], (b, s, n), jnp.float32)
    half = s // 2
    y1, h1 = ssd_chunked(x[:, :half], dt[:, :half], A, Bm[:, :half],
                         Cm[:, :half], 16)
    y2, h2 = ssd_chunked(x[:, half:], dt[:, half:], A, Bm[:, half:],
                         Cm[:, half:], 16, h0=h1)
    y_full, h_full = ssd_chunked(x, dt, A, Bm, Cm, 16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)
