"""Unit + property tests for the USL model (core of StreamInsight)."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.usl import USLFit, fit_usl, r_squared, rmse, usl_throughput

NS = np.array([1, 2, 4, 8, 16, 32, 64], dtype=np.float64)


def test_usl_identity_at_n1():
    assert usl_throughput(1.0, 0.3, 0.05, 7.0) == pytest.approx(7.0)


def test_linear_scaling_when_coeffs_zero():
    t = usl_throughput(NS, 0.0, 0.0, 2.0)
    np.testing.assert_allclose(t, 2.0 * NS)


def test_amdahl_special_case():
    """kappa=0 reduces USL to Amdahl: T(N) = N / (1 + sigma (N-1))."""
    sigma = 0.2
    t = usl_throughput(NS, sigma, 0.0, 1.0)
    amdahl = NS / (1 + sigma * (NS - 1))
    np.testing.assert_allclose(t, amdahl)
    # asymptote 1/sigma
    assert usl_throughput(1e9, sigma, 0.0, 1.0) == pytest.approx(1 / sigma, rel=1e-5)


def test_retrograde_peak_formula():
    sigma, kappa = 0.1, 0.01
    fit = USLFit(sigma=sigma, kappa=kappa, gamma=1.0, r2=1, rmse=0, n_obs=0)
    n_star = fit.peak_n
    assert n_star == pytest.approx(math.sqrt((1 - sigma) / kappa))
    # T at peak >= T at peak +- 1
    assert fit.predict(n_star) >= fit.predict(n_star + 1.0)
    assert fit.predict(n_star) >= fit.predict(max(n_star - 1.0, 1.0))


@given(sigma=st.floats(0.0, 0.9), kappa=st.floats(0.0, 0.05),
       gamma=st.floats(0.1, 100.0))
@settings(max_examples=60, deadline=None)
def test_fit_recovers_exact_data(sigma, kappa, gamma):
    t = usl_throughput(NS, sigma, kappa, gamma)
    fit = fit_usl(NS, t)
    pred = fit.predict(NS)
    # parameters may trade off slightly, but the fitted curve must match
    np.testing.assert_allclose(pred, t, rtol=5e-3, atol=1e-9)
    assert fit.r2 > 0.999


@given(sigma=st.floats(0.01, 0.8), kappa=st.floats(1e-5, 0.02))
@settings(max_examples=30, deadline=None)
def test_fit_parameter_recovery_clean(sigma, kappa):
    t = usl_throughput(NS, sigma, kappa, 5.0)
    fit = fit_usl(NS, t)
    assert fit.sigma == pytest.approx(sigma, abs=2e-2)
    assert fit.kappa == pytest.approx(kappa, abs=2e-3)


def test_fit_robust_to_noise():
    rng = np.random.default_rng(0)
    t = usl_throughput(NS, 0.25, 0.005, 10.0) * rng.lognormal(0, 0.05, NS.shape)
    fit = fit_usl(NS, t)
    assert fit.r2 > 0.9
    assert 0.1 < fit.sigma < 0.45
    assert fit.kappa < 0.02


def test_fit_fix_gamma():
    t = usl_throughput(NS, 0.3, 0.002, 4.0)
    fit = fit_usl(NS, t, fix_gamma=True)
    assert fit.gamma == pytest.approx(4.0, rel=1e-6)
    assert fit.sigma == pytest.approx(0.3, abs=1e-3)


def test_fit_monotone_nondecreasing_prediction_before_peak():
    t = usl_throughput(NS, 0.2, 0.01, 1.0)
    fit = fit_usl(NS, t)
    grid = np.linspace(1, fit.peak_n, 50)
    pred = fit.predict(grid)
    assert np.all(np.diff(pred) >= -1e-9)


def test_fit_input_validation():
    with pytest.raises(ValueError):
        fit_usl([1.0], [1.0])
    with pytest.raises(ValueError):
        fit_usl([0.5, 2.0], [1.0, 1.0])
    with pytest.raises(ValueError):
        fit_usl([1.0, 2.0], [-1.0, 1.0])


def test_r2_rmse_basics():
    y = np.array([1.0, 2.0, 3.0])
    assert r_squared(y, y) == 1.0
    assert rmse(y, y) == 0.0
    assert rmse(y, y + 1.0) == pytest.approx(1.0)
