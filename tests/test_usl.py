"""Unit + property tests for the USL model (core of StreamInsight)."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.usl import (USLFit, fit_usl, fit_usl_batch, fit_usl_ragged,
                            r_squared, rmse, usl_throughput)

NS = np.array([1, 2, 4, 8, 16, 32, 64], dtype=np.float64)


def test_usl_identity_at_n1():
    assert usl_throughput(1.0, 0.3, 0.05, 7.0) == pytest.approx(7.0)


def test_linear_scaling_when_coeffs_zero():
    t = usl_throughput(NS, 0.0, 0.0, 2.0)
    np.testing.assert_allclose(t, 2.0 * NS)


def test_amdahl_special_case():
    """kappa=0 reduces USL to Amdahl: T(N) = N / (1 + sigma (N-1))."""
    sigma = 0.2
    t = usl_throughput(NS, sigma, 0.0, 1.0)
    amdahl = NS / (1 + sigma * (NS - 1))
    np.testing.assert_allclose(t, amdahl)
    # asymptote 1/sigma
    assert usl_throughput(1e9, sigma, 0.0, 1.0) == pytest.approx(1 / sigma, rel=1e-5)


def test_retrograde_peak_formula():
    sigma, kappa = 0.1, 0.01
    fit = USLFit(sigma=sigma, kappa=kappa, gamma=1.0, r2=1, rmse=0, n_obs=0)
    n_star = fit.peak_n
    assert n_star == pytest.approx(math.sqrt((1 - sigma) / kappa))
    # T at peak >= T at peak +- 1
    assert fit.predict(n_star) >= fit.predict(n_star + 1.0)
    assert fit.predict(n_star) >= fit.predict(max(n_star - 1.0, 1.0))


@given(sigma=st.floats(0.0, 0.9), kappa=st.floats(0.0, 0.05),
       gamma=st.floats(0.1, 100.0))
@settings(max_examples=60, deadline=None)
def test_fit_recovers_exact_data(sigma, kappa, gamma):
    t = usl_throughput(NS, sigma, kappa, gamma)
    fit = fit_usl(NS, t)
    pred = fit.predict(NS)
    # parameters may trade off slightly, but the fitted curve must match
    np.testing.assert_allclose(pred, t, rtol=5e-3, atol=1e-9)
    assert fit.r2 > 0.999


@given(sigma=st.floats(0.01, 0.8), kappa=st.floats(1e-5, 0.02))
@settings(max_examples=30, deadline=None)
def test_fit_parameter_recovery_clean(sigma, kappa):
    t = usl_throughput(NS, sigma, kappa, 5.0)
    fit = fit_usl(NS, t)
    assert fit.sigma == pytest.approx(sigma, abs=2e-2)
    assert fit.kappa == pytest.approx(kappa, abs=2e-3)


def test_fit_robust_to_noise():
    rng = np.random.default_rng(0)
    t = usl_throughput(NS, 0.25, 0.005, 10.0) * rng.lognormal(0, 0.05, NS.shape)
    fit = fit_usl(NS, t)
    assert fit.r2 > 0.9
    assert 0.1 < fit.sigma < 0.45
    assert fit.kappa < 0.02


def test_fit_fix_gamma():
    t = usl_throughput(NS, 0.3, 0.002, 4.0)
    fit = fit_usl(NS, t, fix_gamma=True)
    assert fit.gamma == pytest.approx(4.0, rel=1e-6)
    assert fit.sigma == pytest.approx(0.3, abs=1e-3)


def test_fit_monotone_nondecreasing_prediction_before_peak():
    t = usl_throughput(NS, 0.2, 0.01, 1.0)
    fit = fit_usl(NS, t)
    grid = np.linspace(1, fit.peak_n, 50)
    pred = fit.predict(grid)
    assert np.all(np.diff(pred) >= -1e-9)


def test_fit_input_validation():
    with pytest.raises(ValueError):
        fit_usl([1.0], [1.0])
    with pytest.raises(ValueError):
        fit_usl([0.5, 2.0], [1.0, 1.0])
    with pytest.raises(ValueError):
        fit_usl([1.0, 2.0], [-1.0, 1.0])


def test_r2_rmse_basics():
    y = np.array([1.0, 2.0, 3.0])
    assert r_squared(y, y) == 1.0
    assert rmse(y, y) == 0.0
    assert rmse(y, y + 1.0) == pytest.approx(1.0)


# -- batched engine -----------------------------------------------------------

def _synth_batch(seed, s=5, noise=0.05):
    rng = np.random.default_rng(seed)
    sigma = rng.uniform(0.0, 0.7, s)
    kappa = rng.uniform(0.0, 0.02, s)
    gamma = rng.uniform(0.2, 30.0, s)
    t = usl_throughput(NS[None, :], sigma[:, None], kappa[:, None],
                       gamma[:, None])
    t = t * rng.lognormal(0.0, noise, t.shape)
    return np.broadcast_to(NS, (s, NS.size)), t


@given(seed=st.integers(0, 2**16))
@settings(max_examples=8, deadline=None)
def test_batch_matches_scalar_fits(seed):
    """Property: the batched engine and the scalar wrapper agree scenario
    by scenario on random (sigma, kappa, gamma, noise) draws — same code
    path, so within 1e-6 SSE-relative tolerance."""
    n, t = _synth_batch(seed)
    batch = fit_usl_batch(n, t)
    for i, bf in enumerate(batch):
        sf = fit_usl(NS, t[i])
        r_s = sf.predict(NS) - t[i]
        r_b = bf.predict(NS) - t[i]
        sse_s = float(r_s @ r_s)
        sse_b = float(r_b @ r_b)
        assert sse_b <= sse_s + 1e-6 * max(sse_s, 1e-30)
        assert bf.sigma == pytest.approx(sf.sigma, abs=1e-9)
        assert bf.kappa == pytest.approx(sf.kappa, abs=1e-9)
        assert bf.gamma == pytest.approx(sf.gamma, rel=1e-9)
        assert bf.r2 == pytest.approx(sf.r2, abs=1e-12)
        assert bf.rmse == pytest.approx(sf.rmse, rel=1e-9)


def test_batch_fix_gamma_matches_scalar():
    n, t = _synth_batch(3, s=4)
    batch = fit_usl_batch(n, t, fix_gamma=True)
    for i, bf in enumerate(batch):
        sf = fit_usl(NS, t[i], fix_gamma=True)
        assert bf.fixed_gamma and sf.fixed_gamma
        assert bf.gamma == pytest.approx(sf.gamma, rel=1e-12)
        assert bf.sigma == pytest.approx(sf.sigma, abs=1e-9)
        assert bf.kappa == pytest.approx(sf.kappa, abs=1e-9)


def test_batch_shared_vs_per_scenario_n():
    _, t = _synth_batch(9, s=3)
    shared = fit_usl_batch(NS, t)
    stacked = fit_usl_batch(np.broadcast_to(NS, t.shape), t)
    for a, b in zip(shared, stacked):
        assert (a.sigma, a.kappa, a.gamma) == (b.sigma, b.kappa, b.gamma)


def test_ragged_weights_equal_subset_fits():
    """A zero-weight-padded batch row must fit exactly like the scalar fit
    of its unpadded observations."""
    ns = [NS, NS[:4], NS[2:]]
    rng = np.random.default_rng(5)
    ts = [usl_throughput(a, 0.2, 0.004, 3.0) * rng.lognormal(0, 0.04, a.shape)
          for a in ns]
    batch = fit_usl_ragged(ns, ts)
    for a, b, fit in zip(ns, ts, batch):
        ref = fit_usl(a, b)
        assert fit.n_obs == a.size
        assert fit.sigma == pytest.approx(ref.sigma, abs=1e-7)
        assert fit.kappa == pytest.approx(ref.kappa, abs=1e-7)
        assert fit.gamma == pytest.approx(ref.gamma, rel=1e-7)
        assert fit.rmse == pytest.approx(ref.rmse, rel=1e-6, abs=1e-12)


def test_history_is_opt_in():
    t = usl_throughput(NS, 0.2, 0.003, 2.0)
    assert fit_usl(NS, t).history == []
    hist = fit_usl(NS, t, keep_history=True).history
    assert len(hist) >= 1
    params0, sse0 = hist[0]
    assert params0.shape == (3,) and sse0 >= 0.0
    # batch: every scenario gets its own trace
    fits = fit_usl_batch(np.broadcast_to(NS, (2, NS.size)),
                         np.stack([t, t * 2.0]), keep_history=True)
    assert all(len(f.history) >= 1 for f in fits)


def test_bootstrap_ci_shapes_and_containment():
    rng = np.random.default_rng(8)
    t = usl_throughput(NS, 0.25, 0.005, 10.0) * rng.lognormal(0, 0.03, NS.shape)
    fit = fit_usl(NS, t, bootstrap=64, bootstrap_seed=1)
    assert fit.n_bootstrap == 64
    for ci in (fit.sigma_ci, fit.kappa_ci, fit.peak_n_ci):
        assert isinstance(ci, tuple) and len(ci) == 2
        assert ci[0] <= ci[1]
    # with mild noise the point estimate sits inside its own 95% interval
    assert fit.sigma_ci[0] <= fit.sigma <= fit.sigma_ci[1]
    assert fit.kappa_ci[0] <= fit.kappa <= fit.kappa_ci[1]
    assert fit.peak_n_ci[0] <= fit.peak_n <= fit.peak_n_ci[1]
    assert "CI95" in fit.summary()
    # no bootstrap: fields stay empty and summary stays compact
    plain = fit_usl(NS, t)
    assert plain.sigma_ci is None and plain.n_bootstrap == 0
    assert "CI95" not in plain.summary()


def test_bootstrap_ci_handles_infinite_peak():
    """kappa ~ 0 scenarios have peak_N = inf; the percentile CI must carry
    inf through without crashing or producing NaNs."""
    t = usl_throughput(NS, 0.1, 0.0, 4.0)
    fit = fit_usl(NS, t, bootstrap=32, bootstrap_seed=2)
    lo, hi = fit.peak_n_ci
    assert not math.isnan(lo) and not math.isnan(hi)
    assert hi == math.inf


def test_batch_input_validation():
    with pytest.raises(ValueError):
        fit_usl_batch(NS, np.ones((2, 3)))                 # n/t mismatch
    with pytest.raises(ValueError):
        fit_usl_batch(NS, np.ones(NS.size))                # t not 2-D
    with pytest.raises(ValueError):
        fit_usl_batch(NS, np.ones((1, NS.size)),
                      weights=-np.ones((1, NS.size)))      # negative weights
    with pytest.raises(ValueError):
        fit_usl_batch(NS, np.ones((1, NS.size)),
                      weights=np.eye(1, NS.size))          # < 2 effective obs
    with pytest.raises(ValueError):
        fit_usl_batch(NS, np.ones((1, NS.size)), backend="torch")
    assert fit_usl_batch(NS, np.zeros((0, NS.size))) == []


def test_jax_backend_matches_numpy():
    pytest.importorskip("jax")
    n, t = _synth_batch(21, s=6)
    ref = fit_usl_batch(n, t)
    jax_fits = fit_usl_batch(n, t, backend="jax")
    for a, b in zip(jax_fits, ref):
        # float32 LM: same basin, looser tolerance than the numpy path
        np.testing.assert_allclose(a.predict(NS), b.predict(NS),
                                   rtol=2e-2, atol=1e-3)
        assert a.sigma == pytest.approx(b.sigma, abs=5e-3)
        assert a.kappa == pytest.approx(b.kappa, abs=5e-4)
