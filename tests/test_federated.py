"""Federated multi-backend: membership, cost-aware placement, the circuit
breaker, drain-and-migrate failover and the control loop's tick-error ring.

What must hold:

* Federation specs are validated loudly (missing members, unknown knobs,
  nesting) and the greedy split is a pure function of the clock + member
  state: same state, same split; equal-price members spread.
* A full member outage mid-run is a degradation, not a failure: the
  survivors absorb the failed member's partitions, ``lost == 0``, the run
  is bit-identical under its seed, and the breaker walks open ->
  half_open -> closed once the member recovers (re-admission is visible in
  the member ledger).
* Fault-poisoned estimator windows contribute ZERO samples
  (``dirty_windows`` counts them, ``dirty_samples`` stays 0).
* Failover re-subscription keeps the broker contract: sealed partitions
  drain, commits are monotone per partition, on the sim engine (federated
  members) and on the threaded engine (local backend, consumers torn down
  by crash + shrink) alike.
* ``ControlLoop.tick_error_log`` is a bounded ring of the last 16
  ``(sim_ts, repr(exc))`` — a flapping controller is diagnosable from the
  report card.
"""

from collections import defaultdict
from types import SimpleNamespace

import pytest
from hypothesis import given, settings
from hypothesis import strategies as hyp_st

from repro.core.autoscale import ControlLoop, StaticPolicy
from repro.core.metrics import MetricRegistry, new_run_id
from repro.core.miniapp import AdaptationExperiment, run_adaptation
from repro.pilot.api import (PilotComputeService, PilotDescription,
                             TaskProfile)
from repro.streaming.broker import Broker
from repro.streaming.engine import SimStreamingEngine, Workload

MEMBERS = [
    dict(name="aws", machine="serverless", price=1.0,
         usl=(0.05, 1e-3, 2.0)),
    dict(name="wrangler", machine="wrangler", price=0.6,
         usl=(0.1, 5e-4, 1.9), grant_latency_s=10.0),
]


def _members():
    return [dict(m) for m in MEMBERS]


def _fed_cell(**kw) -> AdaptationExperiment:
    kw.setdefault("federation", dict(members=_members()))
    kw.setdefault("machine", "federated")
    return AdaptationExperiment(
        scaling_policy="usl", policy="update_locked",
        usl_sigma=0.05, usl_kappa=1e-3, usl_gamma=2.0,
        rate=dict(kind="step", base_hz=2.0, high_hz=8.0, t_step=20.0),
        horizon_s=90.0, control_interval_s=2.0, initial_partitions=2,
        max_partitions=8, points=2000, centroids=256, seed=0,
        max_retries=5, retry_backoff_s=0.1, **kw)


OUTAGE = dict(events=[dict(t=30.0, kind="backend_outage", target=1,
                           duration_s=15.0)])


def _fingerprint(res) -> tuple:
    return (res.processed, res.produced, res.abandoned, res.dup_delivered,
            res.faults_injected, res.preemptions, res.lost,
            res.slo_violations, round(res.cost_integral, 9),
            tuple(map(tuple, res.alloc_trace)),
            tuple(tuple(sorted(m.items())) for m in res.member_ledger))


# -- membership / spec validation ---------------------------------------------

def _fed_pilot(pcs, partitions=4, members=None, **fed_kw):
    return pcs.submit_pilot(PilotDescription(
        resource="federated://mix", partitions=partitions,
        concurrency=partitions,
        attrs=dict(federation=dict(members=members or _members(), **fed_kw))))


def test_federation_requires_members():
    pcs = PilotComputeService(seed=0)
    try:
        with pytest.raises(ValueError, match="members"):
            pcs.submit_pilot(PilotDescription(resource="federated://mix"))
    finally:
        pcs.close()


def test_unknown_federation_knob_rejected():
    pcs = PilotComputeService(seed=0)
    try:
        with pytest.raises(ValueError, match="unknown federation keys"):
            _fed_pilot(pcs, open_cooldwn_s=5.0)        # typo'd knob
    finally:
        pcs.close()


def test_nested_federation_rejected():
    pcs = PilotComputeService(seed=0)
    try:
        with pytest.raises(ValueError, match="do not nest"):
            _fed_pilot(pcs, members=[dict(resource="federated://mix")])
    finally:
        pcs.close()


def test_split_is_deterministic_and_spreads():
    """Equal-price, equal-prior members share the target evenly, and the
    split is a pure function of member state (identical across reads)."""
    pcs = PilotComputeService(seed=0)
    try:
        twins = [dict(machine="serverless", name="a"),
                 dict(machine="serverless", name="b")]
        pilot = _fed_pilot(pcs, partitions=4, members=twins)
        backend = pilot.backend
        assert backend.scale_to(pilot, 8) == 8
        units = [m["units"] for m in backend.member_ledger(pilot)]
        assert sorted(units) == [4, 4]
        assert backend.scale_to(pilot, 8) == 8         # idempotent re-split
        assert [m["units"] for m in backend.member_ledger(pilot)] == units
        assert backend.allocation(pilot) == 8
    finally:
        pcs.close()


def test_cheaper_member_wins_placement():
    """With one member priced below the other (similar capacity priors),
    the greedy score concentrates units on the cheap one."""
    pcs = PilotComputeService(seed=0)
    try:
        pilot = _fed_pilot(pcs, partitions=2, members=[
            dict(machine="serverless", name="dear", price=1.0),
            dict(machine="serverless", name="cheap", price=0.5)])
        backend = pilot.backend
        backend.scale_to(pilot, 6)
        ledger = {m["name"]: m for m in backend.member_ledger(pilot)}
        assert ledger["cheap"]["units"] > ledger["dear"]["units"]
    finally:
        pcs.close()


def test_member_ledger_shape_and_states():
    pcs = PilotComputeService(seed=0)
    try:
        pilot = _fed_pilot(pcs)
        ledger = pilot.backend.member_ledger(pilot)
        assert [m["name"] for m in ledger] == ["aws", "wrangler"]
        for m in ledger:
            assert m["state"] == "closed" and m["opens"] == 0
            assert m["dirty_samples"] == 0
            assert {"price", "units", "submitted", "completed", "failures",
                    "err_ewma", "glat_ewma", "cost_integral", "est_samples",
                    "dirty_windows", "refits"} <= set(m)
    finally:
        pcs.close()


# -- failover: outage, at-least-once, determinism, re-admission ---------------

@pytest.mark.parametrize("target", [0, 1])
def test_member_outage_is_lossless_and_readmitted(target):
    """A full member outage mid-run: survivors absorb its partitions
    (lost == 0), the breaker opens and then re-admits the member (final
    state closed), and fault-dirtied estimator windows contribute zero
    samples."""
    faults = dict(events=[dict(t=30.0, kind="backend_outage",
                               target=target, duration_s=15.0)])
    res = run_adaptation(_fed_cell(faults=faults))
    assert res.drained and res.lost == 0
    assert res.abandoned == 0
    ledger = res.member_ledger
    assert len(ledger) == 2
    assert ledger[target]["opens"] >= 1                # breaker tripped
    assert ledger[target]["state"] == "closed"         # ... and re-admitted
    assert ledger[target]["dirty_windows"] > 0
    assert all(m["dirty_samples"] == 0 for m in ledger)
    survivor = ledger[1 - target]
    assert survivor["completed"] > 0                   # absorbed the work


def test_outage_run_is_bit_identical():
    a = run_adaptation(_fed_cell(faults=OUTAGE))
    b = run_adaptation(_fed_cell(faults=OUTAGE))
    assert _fingerprint(a) == _fingerprint(b)
    assert a.tick_error_log == [] == b.tick_error_log  # no silent crashes


def test_fault_free_federated_run_feeds_estimators():
    res = run_adaptation(_fed_cell())
    assert res.drained and res.lost == 0
    assert res.faults_injected == 0
    assert sum(m["est_samples"] for m in res.member_ledger) > 0
    assert all(m["opens"] == 0 for m in res.member_ledger)
    assert res.cost_integral > 0.0


def test_grant_starvation_steers_the_burst():
    """Starving the HPC member of grants through the load step makes the
    scale-up land on the serverless member."""
    faults = dict(events=[dict(t=15.0, kind="grant_starvation", target=1,
                               duration_s=60.0)])
    res = run_adaptation(_fed_cell(faults=faults))
    assert res.drained and res.lost == 0
    ledger = {m["name"]: m for m in res.member_ledger}
    assert ledger["aws"]["units"] > ledger["wrangler"]["units"]
    assert ledger["wrangler"]["dirty_windows"] > 0


def test_outage_event_skips_on_backend_without_the_hook():
    """backend_outage against a plain (non-federated) backend is a no-op
    skip, never a crash — fault plans stay portable across machines."""
    res = run_adaptation(_fed_cell(
        machine="serverless", federation=None, faults=OUTAGE))
    assert res.drained and res.lost == 0
    assert res.faults_injected == 1                    # fired...
    assert res.preemptions == 0                        # ... but acted on
    assert res.member_ledger == []                     # nothing, gracefully


def test_worker_faults_fan_out_across_members():
    pcs = PilotComputeService(seed=0)
    try:
        pilot = _fed_pilot(pcs)
        backend = pilot.backend
        backend.scale_to(pilot, 4)
        backend.drive_until(
            lambda: backend.effective_allocation(pilot) >= 4, timeout=300.0)
        assert backend.preempt(pilot, 2) == 2
        assert backend.effective_allocation(pilot) < 4
    finally:
        pcs.close()


# -- failover re-subscription: seal semantics + monotone acks -----------------

class _FedHarness:
    """A federated pilot driving the sim engine directly, with every
    broker commit recorded so ack monotonicity is assertable."""

    def __init__(self, partitions=4, members=None, batch_max=2,
                 max_retries=5):
        self.pcs = PilotComputeService(seed=0)
        self.pilot = _fed_pilot(self.pcs, partitions=partitions,
                                members=members)
        self.backend = self.pilot.backend
        self.broker = Broker()
        self.topic = "t"
        self.broker.create_topic(self.topic, partitions)
        self.commits = defaultdict(list)
        inner = self.broker.commit

        def recording_commit(group, topic, partition, offset):
            self.commits[partition].append(offset)
            inner(group, topic, partition, offset)

        self.broker.commit = recording_commit
        self.metrics = MetricRegistry()
        self.run_id = new_run_id("fed-conform")
        self.produced = 0
        self._input_done = False
        profile = TaskProfile(flops=1e7)
        self.engine = SimStreamingEngine(
            self.backend.sim, self.broker, self.topic, self.pilot,
            Workload(profile_for=lambda msgs: profile, name="fed-conform"),
            self.metrics, self.run_id, batch_max=batch_max,
            max_retries=max_retries,
            is_input_complete=lambda: self._input_done)
        self.engine.start()

    def produce(self, values, partition=None):
        for v in values:
            self.broker.append(self.topic, v, ts=self.engine.now(),
                               partition=partition, run_id=self.run_id)
            self.produced += 1

    def finish(self):
        self._input_done = True
        self.engine.run_to_completion()

    def assert_acks_monotone_and_sealed_drained(self):
        core = self.engine.core
        assert core.processed + core.abandoned == self.produced
        for p, end in enumerate(self.broker.end_offsets(self.topic)):
            assert self.broker.committed("engine", self.topic, p) == end
        for p, seq in self.commits.items():
            assert seq == sorted(seq), f"partition {p} acks rolled back"

    def close(self):
        self.pcs.close()


def test_sim_failover_resubscription_monotone_acks():
    """Mid-batch outage of the member owning half the partitions, then a
    shrink: the survivor re-adopts the failed member's partitions, sealed
    partitions drain, and no partition's committed offset ever rolls
    back."""
    twins = [dict(machine="serverless", name="a"),
             dict(machine="serverless", name="b")]
    h = _FedHarness(partitions=4, members=twins)
    try:
        for p in range(4):
            h.produce(range(8), partition=p)
        # run a slice so batches are genuinely in flight on both members
        h.backend.sim.run_until(t=h.backend.sim.now + 0.5)
        assert h.backend.inject_outage(h.pilot, member=0,
                                       duration_s=5.0) >= 1
        # control-plane shrink while member 0 is dark: Kinesis reshard
        # seals the tail, survivors own the active prefix
        h.broker.repartition(h.topic, 2)
        h.engine.repartition()
        h.produce(range(6))                  # keyless -> active prefix only
        h.finish()
        h.assert_acks_monotone_and_sealed_drained()
        assert h.engine.core.processed == h.produced   # nothing abandoned
        ledger = h.backend.member_ledger(h.pilot)
        assert ledger[0]["opens"] >= 1                 # breaker saw the outage
        assert ledger[1]["completed"] > 0              # survivor absorbed
    finally:
        h.close()


@settings(max_examples=8, deadline=None)
@given(member=hyp_st.integers(min_value=0, max_value=1),
       run_s=hyp_st.floats(min_value=0.1, max_value=2.0),
       shrink_to=hyp_st.integers(min_value=1, max_value=4))
def test_failover_resubscription_property(member, run_s, shrink_to):
    """Whatever member dies, whenever, and wherever the shrink lands:
    every message settles, commits reach the end offsets and acks stay
    monotone."""
    twins = [dict(machine="serverless", name="a"),
             dict(machine="serverless", name="b")]
    h = _FedHarness(partitions=4, members=twins)
    try:
        for p in range(4):
            h.produce(range(6), partition=p)
        h.backend.sim.run_until(t=h.backend.sim.now + run_s)
        h.backend.inject_outage(h.pilot, member=member, duration_s=3.0)
        h.broker.repartition(h.topic, shrink_to)
        h.engine.repartition()
        h.produce(range(4))
        h.finish()
        h.assert_acks_monotone_and_sealed_drained()
        assert h.engine.core.processed == h.produced
    finally:
        h.close()


# -- threaded engine: teardown + re-adoption under the wall clock -------------

def test_threaded_teardown_and_readoption_monotone_acks():
    """The wall-clock twin of the failover path: consumers torn down by a
    worker crash while a shrink seals half the partitions — the survivors
    re-adopt, sealed backlogs drain, acks stay monotone."""
    from repro.streaming.engine import ThreadedStreamingEngine

    pcs = PilotComputeService(seed=0)
    broker = Broker()
    topic = "t"
    broker.create_topic(topic, 4)
    commits = defaultdict(list)
    inner = broker.commit

    def recording_commit(group, tpc, partition, offset):
        commits[partition].append(offset)
        inner(group, tpc, partition, offset)

    broker.commit = recording_commit
    metrics = MetricRegistry()
    run_id = new_run_id("fed-threaded")
    pilot = pcs.submit_pilot(PilotDescription(resource="local://",
                                              concurrency=8))
    profile = TaskProfile(flops=1e7)
    engine = ThreadedStreamingEngine(
        broker, topic, pilot,
        Workload(profile_for=lambda msgs: profile, fn=lambda msgs: None,
                 name="fed-threaded"),
        metrics, run_id, batch_max=2, max_retries=3, poll_interval=0.005)
    engine.start()
    produced = 0
    try:
        assert pilot.backend.inject_crash(pilot, 1) == 1
        for p in range(4):
            for v in range(6):
                broker.append(topic, v, ts=engine.now(), partition=p,
                              run_id=run_id)
                produced += 1
        broker.repartition(topic, 2)                   # seal the tail
        engine.repartition()
        for v in range(4):                             # active prefix only
            broker.append(topic, v, ts=engine.now(), run_id=run_id)
            produced += 1
        engine.drain(produced, timeout=30.0)
        core = engine.core
        assert core.processed == produced and core.abandoned == 0
        assert core.retried >= 1                       # the crash cost a retry
        for p, end in enumerate(broker.end_offsets(topic)):
            assert broker.committed("engine", topic, p) == end
        for p, seq in commits.items():
            assert seq == sorted(seq), f"partition {p} acks rolled back"
    finally:
        engine.stop(timeout=2.0)
        pcs.close()


# -- the control loop's tick-error ring ---------------------------------------

class _RingEngine:
    """Minimal EngineControlSurface with a drainable ticker-error
    history, as the threaded engine now exposes."""

    def __init__(self):
        self.t = 0.0
        self.errors = []

    def now(self):
        return self.t

    def call_later(self, delay_s, fn):
        pass

    def repartition(self, migration_s=0.0):
        pass

    def drain_ticker_errors(self):
        errs, self.errors = self.errors, []
        return errs


class _RingBackend:
    def allocation(self, pilot):
        return 2

    def effective_allocation(self, pilot):
        return 2

    def scale_to(self, pilot, n):
        return n


def test_tick_error_ring_is_bounded_and_stamped():
    eng = _RingEngine()
    loop = ControlLoop(
        eng, Broker(), "t", SimpleNamespace(backend=_RingBackend()),
        StaticPolicy(2), metrics=MetricRegistry(),
        run_id=new_run_id("ring"), interval_s=1.0)
    for i in range(20):
        eng.errors.append(ValueError(f"boom {i}"))
        eng.t += 1.0
        loop._tick()
    assert loop.tick_errors == 20                      # total survives
    log = list(loop.tick_error_log)
    assert len(log) == 16                              # ring is bounded
    assert log[0] == (5.0, "ValueError('boom 4')")     # oldest 4 evicted
    assert log[-1] == (20.0, "ValueError('boom 19')")
    assert all(isinstance(t, float) and isinstance(r, str) for t, r in log)


def test_tick_error_ring_drains_in_batches():
    """Several callback failures between two ticks all land in the ring —
    the pre-ring latch surfaced only the first."""
    eng = _RingEngine()
    loop = ControlLoop(
        eng, Broker(), "t", SimpleNamespace(backend=_RingBackend()),
        StaticPolicy(2), metrics=MetricRegistry(),
        run_id=new_run_id("ring"), interval_s=1.0)
    eng.errors.extend(RuntimeError(f"e{i}") for i in range(3))
    eng.t = 1.0
    loop._tick()
    assert loop.tick_errors == 3
    assert [r for _, r in loop.tick_error_log] == \
        ["RuntimeError('e0')", "RuntimeError('e1')", "RuntimeError('e2')"]
