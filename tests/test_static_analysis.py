"""simlint gate + rule corpus + lock-order shim.

Three layers:

1. **the tier-1 gate** — the analyzer runs over the real ``src/`` and
   ``tests/`` trees and must report zero findings (within the pragma
   budget).  A violation introduced anywhere in the repo fails here;
2. **the rule corpus** — every ``tests/simlint_fixtures/bad_*`` module
   must trip exactly the rules its header names, and the ``clean_*``
   modules must trip none (no false positives);
3. **the runtime shim** — ``LockWatch`` unit tests (ABBA cycle detection,
   reentrancy, wait-while-holding), plus a slow subprocess run of the
   engine-conformance suite under the shim asserting the production lock
   acquisition graph is acyclic with no cross-component waits.
"""

import json
import os
import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.analysis import (DEFAULT_MANIFEST, LockSite, LockWatch, Manifest,
                            analyze_file, run_analysis)
from repro.analysis.lockwatch import ENV_OUT

REPO_ROOT = str(Path(__file__).resolve().parent.parent)
FIXTURES = Path(__file__).resolve().parent / "simlint_fixtures"

# Classifies the corpus the way the default manifest classifies the repo:
# everything sim, two hot modules, three "test files" (two wall, one sim),
# and an empty lock registry so every constructor is unregistered.
FIXTURE_MANIFEST = Manifest(
    sim_modules=("*/simlint_fixtures/*.py",),
    hot_modules=("*/simlint_fixtures/bad_missing_slots.py",
                 "*/simlint_fixtures/clean_sim.py"),
    test_globs=("*/simlint_fixtures/bad_slow_sleep.py",
                "*/simlint_fixtures/bad_sim_testfile.py",
                "*/simlint_fixtures/clean_testfile.py"),
    wall_test_files=("*/simlint_fixtures/bad_slow_sleep.py",
                     "*/simlint_fixtures/clean_testfile.py"),
)


def lint_fixture(name: str, manifest: Manifest = FIXTURE_MANIFEST):
    path = FIXTURES / name
    rel = f"tests/simlint_fixtures/{name}"
    return analyze_file(str(path), rel, manifest).findings


def rules_of(findings) -> set:
    return {f.rule for f in findings}


# -- 1. the repo gate ---------------------------------------------------------

def test_repo_has_zero_findings():
    """The tier-1 gate: src/ + tests/ are clean under the default manifest."""
    report = run_analysis(REPO_ROOT)
    assert report.ok, "\n" + report.render()
    assert report.files_scanned > 50     # the walk actually found the tree
    assert report.pragma_count <= DEFAULT_MANIFEST.max_pragmas


def test_cli_exits_zero_on_clean_repo():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--root", REPO_ROOT],
        capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": os.path.join(REPO_ROOT, "src")})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 finding(s)" in proc.stdout


# -- 2. the rule corpus: every bad fixture fires its rule ---------------------

def test_bad_wallclock_fires():
    findings = lint_fixture("bad_wallclock.py")
    assert rules_of(findings) == {"wall-clock"}
    # read, from-import sleep, datetime.now, and the stored reference
    assert len(findings) >= 4


def test_bad_global_random_fires():
    findings = lint_fixture("bad_global_random.py")
    assert rules_of(findings) == {"global-random"}
    assert len(findings) >= 3        # random.random, np.random.seed/rand


def test_bad_hash_routing_fires():
    findings = lint_fixture("bad_hash_routing.py")
    assert rules_of(findings) == {"salted-hash"}


def test_bad_negative_delay_fires():
    findings = lint_fixture("bad_negative_delay.py")
    assert rules_of(findings) == {"negative-delay"}
    assert len(findings) == 2        # schedule and schedule_fast


def test_bad_missing_slots_fires():
    findings = lint_fixture("bad_missing_slots.py")
    assert rules_of(findings) == {"slots"}
    names = {f.message.split("'")[1] for f in findings}
    assert names == {"LagRecord", "QueueMessage"}


def test_bad_lock_site_fires():
    findings = lint_fixture("bad_lock_site.py")
    assert rules_of(findings) == {"lock-site"}
    assert len(findings) == 3        # Lock, RLock, Condition


def test_registered_lock_site_is_quiet():
    manifest = Manifest(
        sim_modules=FIXTURE_MANIFEST.sim_modules,
        known_locks=tuple(
            LockSite("*/simlint_fixtures/bad_lock_site.py", q, k,
                     "corpus: registered on purpose")
            for q, k in (("", "Lock"), ("SneakyQueue.__init__", "RLock"),
                         ("SneakyQueue.__init__", "Condition"))))
    assert lint_fixture("bad_lock_site.py", manifest) == []


def test_bad_slow_sleep_fires():
    findings = lint_fixture("bad_slow_sleep.py")
    assert rules_of(findings) == {"test-slow-wait", "test-sleep"}
    by_rule = {r: [f for f in findings if f.rule == r]
               for r in rules_of(findings)}
    assert len(by_rule["test-slow-wait"]) == 2   # sleep + perf_counter
    assert len(by_rule["test-sleep"]) == 1


def test_bad_sim_test_fires():
    findings = lint_fixture("bad_sim_testfile.py")
    assert rules_of(findings) == {"test-wall"}


def test_bad_pragma_fires():
    findings = lint_fixture("bad_pragma.py")
    pragma_findings = [f for f in findings if f.rule == "pragma"]
    msgs = " | ".join(f.message for f in pragma_findings)
    assert len(pragma_findings) == 3
    assert "reason is empty" in msgs
    assert "unknown rule" in msgs
    assert "malformed" in msgs


def test_valid_pragma_suppresses_scope():
    src = (
        "import time\n"
        "def snap():  # simlint: allow[wall-clock] — corpus: scope pragma\n"
        "    return time.time()\n")
    ctx = analyze_file("x.py", "tests/simlint_fixtures/x.py",
                       FIXTURE_MANIFEST, source=src)
    assert ctx.findings == []
    assert any(p.used for p in ctx.pragmas.values())


def test_pragma_budget_enforced(tmp_path):
    src_dir = tmp_path / "src"
    src_dir.mkdir()
    (src_dir / "mod.py").write_text(
        "import time\n"
        "def f():  # simlint: allow[wall-clock] — budget corpus\n"
        "    return time.time()\n")
    tight = Manifest(sim_modules=("*mod.py",), max_pragmas=0)
    report = run_analysis(str(tmp_path), tight)
    assert [f.rule for f in report.findings] == ["pragma"]
    assert "budget exceeded" in report.findings[0].message


# -- 3. no false positives ----------------------------------------------------

def test_clean_sim_fixture_is_quiet():
    assert lint_fixture("clean_sim.py") == []


def test_clean_test_fixture_is_quiet():
    assert lint_fixture("clean_testfile.py") == []


def test_fixtures_are_excluded_from_the_repo_gate():
    assert DEFAULT_MANIFEST.is_excluded(
        "tests/simlint_fixtures/bad_wallclock.py")


# -- 4. the lock-order shim ---------------------------------------------------

def test_lockwatch_detects_abba_cycle():
    import simlint_fixtures.bad_lock_cycle as fixture

    watch = LockWatch().install()
    try:
        fixture.provoke()
    finally:
        watch.uninstall()
    cycles = watch.cycles()
    assert cycles, "ABBA inversion must produce a cycle"
    assert all("bad_lock_cycle.py" in site
               for cyc in cycles for site in cyc)


def test_lockwatch_ordered_nesting_is_acyclic():
    watch = LockWatch().install()
    try:
        outer = threading.Lock()
        inner = threading.Lock()
        for _ in range(3):
            with outer:
                with inner:
                    pass
    finally:
        watch.uninstall()
    assert watch.cycles() == []
    # 3 rounds x 2 acquires — a hard count so accounting regressions
    # surface loudly
    assert watch.acquisitions == 6
    assert watch.edges[next(iter(watch.edges))]   # outer->inner edge exists


def test_lockwatch_reentrant_rlock_no_self_edge():
    watch = LockWatch().install()
    try:
        lk = threading.RLock()
        with lk:
            with lk:
                pass
    finally:
        watch.uninstall()
    assert watch.cycles() == []
    assert watch.edges == {}


def test_lockwatch_records_wait_while_holding():
    watch = LockWatch().install()
    try:
        held = threading.Lock()
        cond = threading.Condition()
        with held:
            with cond:
                cond.wait(timeout=0.01)
    finally:
        watch.uninstall()
    assert watch.waits, "Condition.wait while holding a lock must register"
    assert any(w["held"] for w in watch.waits)


def test_lockwatch_event_roundtrip_under_shim():
    """threading.Event is Condition-over-Lock internally: the proxy's
    plain-lock fallback protocol must keep it fully functional."""
    watch = LockWatch().install()
    try:
        ev = threading.Event()
        hits = []

        def setter():
            hits.append(1)
            ev.set()

        t = threading.Thread(target=setter)
        t.start()
        assert ev.wait(timeout=5.0)
        t.join(timeout=5.0)
    finally:
        watch.uninstall()
    assert hits == [1]
    assert watch.cycles() == []


@pytest.mark.slow
def test_conformance_suite_lock_graph_is_acyclic(tmp_path):
    """Run the full cross-engine conformance suite in a subprocess with the
    lockwatch shim installed (via the conftest env hook) and assert the
    production acquisition graph has no cycles and no cross-component
    waits-while-holding — the machine-checked form of the ordering notes
    in the manifest's known_locks."""
    out = tmp_path / "lockgraph.json"
    env = {**os.environ, ENV_OUT: str(out),
           "PYTHONPATH": os.path.join(REPO_ROOT, "src")}
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.join(REPO_ROOT, "tests", "test_engine_conformance.py")],
        capture_output=True, text=True, cwd=REPO_ROOT, env=env,
        timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(out.read_text())
    assert data["acquisitions"] > 0, "shim saw no lock traffic at all"
    assert data["cycles"] == [], json.dumps(data["cycles"], indent=1)
    assert data["cross_component_waits"] == [], \
        json.dumps(data["cross_component_waits"], indent=1)
