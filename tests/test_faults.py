"""Fault injection as a scenario axis: plan expansion, injector wiring,
control-loop awareness, and the backends' fault surfaces.

What must hold:

* ``FaultPlan`` specs are validated (unknown keys / kinds fail loudly) and
  rate expansion is a pure function of the seed — same seed, same schedule.
* A faulted adaptation run is deterministic end to end on the sim clock,
  loses no messages, and reports its fault epochs (``fault_windows``) so
  the online estimator's exclusion of poisoned windows is observable.
* The hpcsim batch-queue wait honours the configured log-normal quantiles
  (seeded, per-pilot) and degenerates to the flat ``grant_delay_s`` when
  unconfigured — the fig8 calibration path is bit-preserved.
"""

import json
import math
import statistics

import pytest

from repro.core.metrics import MetricRegistry, new_run_id
from repro.core.miniapp import AdaptationExperiment, run_adaptation
from repro.pilot.api import PilotComputeService, PilotDescription
from repro.streaming.broker import Broker
from repro.streaming.engine import Workload, _EngineCore
from repro.streaming.faults import FAULT_KINDS, FaultEvent, FaultPlan

FAULT_SPEC = dict(crash_rate_hz=0.08, duplicate_rate_hz=0.05,
                  stall_rate_hz=0.02, stall_s=3.0,
                  preempt_times=[35.0, 70.0], preempt_count=2)


# -- plan validation and expansion --------------------------------------------

def test_unknown_plan_key_rejected():
    with pytest.raises(ValueError, match="unknown FaultPlan keys"):
        FaultPlan.from_spec(dict(crash_rate=0.1))     # typo'd key


def test_unknown_event_kind_rejected():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.from_spec(dict(events=[dict(t=1.0, kind="meteor")]))


def test_event_defaults_from_spec():
    ev = FaultEvent.from_spec(dict(t=2.5, kind="stall"))
    assert ev.t == 2.5 and ev.kind == "stall"
    assert ev.target is None and ev.count == 1 and ev.duration_s == 5.0


def test_events_for_is_deterministic_and_bounded():
    plan = FaultPlan.from_spec(dict(FAULT_SPEC, seed=7), default_horizon_s=90.0)
    a = plan.events_for()
    b = FaultPlan.from_spec(dict(FAULT_SPEC, seed=7),
                            default_horizon_s=90.0).events_for()
    assert a == b                                      # pure function of seed
    assert a == sorted(a, key=lambda e: (e.t, e.kind, e.count))
    assert all(e.kind in FAULT_KINDS for e in a)
    # rate events respect the horizon; explicit preempts land verbatim
    assert all(e.t < 90.0 for e in a if e.kind != "preempt")
    assert [e.t for e in a if e.kind == "preempt"] == [35.0, 70.0]
    assert all(e.count == 2 for e in a if e.kind == "preempt")
    other = FaultPlan.from_spec(dict(FAULT_SPEC, seed=8),
                                default_horizon_s=90.0).events_for()
    assert other != a                                  # the seed matters


def test_seed_defaults_to_experiment_seed():
    plan = FaultPlan.from_spec(dict(crash_rate_hz=0.1), default_seed=13,
                               default_horizon_s=60.0)
    assert plan.seed == 13 and plan.horizon_s == 60.0


# -- faulted adaptation runs (sim clock) --------------------------------------

def _fault_cell(machine: str, **kw) -> AdaptationExperiment:
    kw.setdefault("faults", dict(FAULT_SPEC, seed=3))
    return AdaptationExperiment(
        machine=machine, scaling_policy="reactive",
        rate=dict(kind="step", base_hz=2.0, high_hz=8.0, t_step=20.0),
        horizon_s=60.0, control_interval_s=2.0, initial_partitions=2,
        max_partitions=8, points=2000, centroids=256, seed=3,
        max_retries=5, retry_backoff_s=0.1, **kw)


def _fingerprint(res) -> tuple:
    return (res.processed, res.produced, res.abandoned, res.dup_delivered,
            res.faults_injected, res.preemptions, res.fault_windows,
            res.lost, res.slo_violations, round(res.cost_integral, 9),
            tuple(map(tuple, res.alloc_trace)))


@pytest.mark.parametrize("machine", ["serverless", "wrangler"])
def test_faulted_run_is_deterministic_and_lossless(machine):
    a = run_adaptation(_fault_cell(machine))
    b = run_adaptation(_fault_cell(machine))
    assert _fingerprint(a) == _fingerprint(b)          # bit-identical rerun
    assert a.faults_injected > 0 and a.preemptions > 0
    assert a.dup_delivered > 0                          # redelivery exercised
    assert a.lost == 0                                  # at-least-once held
    assert a.drained
    assert a.fault_windows > 0                          # loop saw the faults


def test_fault_free_run_reports_clean_card():
    res = run_adaptation(_fault_cell("serverless", faults=None))
    assert res.faults_injected == 0 and res.preemptions == 0
    assert res.dup_delivered == 0 and res.fault_windows == 0
    assert res.lost == 0


def test_faults_change_the_run():
    faulted = run_adaptation(_fault_cell("serverless"))
    clean = run_adaptation(_fault_cell("serverless", faults=None))
    # the injected duplicates alone force a different settled count
    assert faulted.dup_delivered != clean.dup_delivered


def test_fault_seed_changes_the_schedule_not_the_accounting():
    a = run_adaptation(_fault_cell("serverless"))
    b = run_adaptation(_fault_cell("serverless",
                                   faults=dict(FAULT_SPEC, seed=4)))
    assert a.lost == 0 and b.lost == 0                 # invariant under seed
    assert _fingerprint(a) != _fingerprint(b)          # schedule differs


# -- hpcsim batch-queue wait distribution -------------------------------------

def _hpc_pilot(pcs: PilotComputeService, attrs: dict):
    return pcs.submit_pilot(PilotDescription(
        resource="hpc://wrangler-sim", number_of_nodes=4, cores_per_node=4,
        attrs=attrs))


def test_queue_wait_defaults_to_flat_grant_delay():
    pcs = PilotComputeService(seed=0)
    try:
        pilot = _hpc_pilot(pcs, {})
        backend = pilot.backend
        st = backend._pilots[pilot.uid]
        waits = {backend._queue_wait(st) for _ in range(16)}
        assert waits == {st["cfg"]["grant_delay_s"]}   # degenerate, no draw
    finally:
        pcs.close()


def test_queue_wait_matches_configured_quantiles():
    pcs = PilotComputeService(seed=0)
    try:
        pilot = _hpc_pilot(pcs, dict(queue_wait_p50_s=5.0,
                                     queue_wait_p95_s=40.0))
        backend = pilot.backend
        st = backend._pilots[pilot.uid]
        waits = sorted(backend._queue_wait(st) for _ in range(4000))
        assert all(w > 0.0 for w in waits)
        p50 = statistics.median(waits)
        p95 = waits[int(0.95 * len(waits))]
        assert math.isclose(p50, 5.0, rel_tol=0.15)
        assert math.isclose(p95, 40.0, rel_tol=0.25)   # heavy tail, wide band
    finally:
        pcs.close()


def test_queue_wait_stream_is_seeded_per_pilot():
    def sample(seed: int) -> list[float]:
        pcs = PilotComputeService(seed=seed)
        try:
            pilot = _hpc_pilot(pcs, dict(queue_wait_p50_s=5.0,
                                         queue_wait_p95_s=40.0))
            st = pilot.backend._pilots[pilot.uid]
            return [pilot.backend._queue_wait(st) for _ in range(32)]
        finally:
            pcs.close()

    assert sample(0) == sample(0)                      # same seed, same draws
    assert sample(0) != sample(1)


def test_degenerate_quantiles_fall_back_to_p50():
    """p95 <= p50 (or p50 <= 0) cannot shape a log-normal: the wait
    degenerates to the p50 value instead of producing NaNs."""
    pcs = PilotComputeService(seed=0)
    try:
        pilot = _hpc_pilot(pcs, dict(queue_wait_p50_s=5.0,
                                     queue_wait_p95_s=5.0))
        st = pilot.backend._pilots[pilot.uid]
        assert {pilot.backend._queue_wait(st) for _ in range(8)} == {5.0}
    finally:
        pcs.close()


# -- spec round-trips ---------------------------------------------------------

def test_event_to_spec_roundtrips_every_kind():
    """to_spec is the lossless inverse of from_spec for every kind —
    including the federation-level backend_outage / grant_starvation."""
    for kind in FAULT_KINDS:
        for target in (None, 1):
            ev = FaultEvent(t=3.5, kind=kind, target=target,
                            duration_s=7.5, count=2)
            assert FaultEvent.from_spec(ev.to_spec()) == ev
    # an unset target stays unset, not null-with-a-key
    assert "target" not in FaultEvent(t=1.0, kind="crash").to_spec()


def test_plan_to_spec_roundtrips_and_is_jsonable():
    plan = FaultPlan.from_spec(
        dict(FAULT_SPEC, seed=7, events=[
            dict(t=30.0, kind="backend_outage", target=1, duration_s=15.0),
            dict(t=50.0, kind="grant_starvation", target=0),
        ]), default_horizon_s=90.0)
    spec = plan.to_spec()
    json.dumps(spec)                                   # JSON-able, no repr leaks
    clone = FaultPlan.from_spec(spec)
    assert clone == plan                               # lossless round-trip
    assert clone.events_for() == plan.events_for()     # same expanded schedule


# -- seeded retry jitter ------------------------------------------------------

def _bare_core(seed: int) -> _EngineCore:
    broker = Broker()
    broker.create_topic("t", 1)
    return _EngineCore(broker, "t", None, Workload(name="rng"),
                       MetricRegistry(), new_run_id("rng"),
                       retry_backoff_s=0.1, seed=seed)


def test_retry_jitter_defaults_to_seed_derived_stream():
    """With no explicit rng the backoff jitter stream derives from the
    experiment seed: reruns of a faulted, retrying experiment are
    bit-identical by default (never unseeded, never jitter-free)."""
    def seq(seed: int) -> list[float]:
        core = _bare_core(seed)
        return [core.retry_delay(a) for a in range(1, 9)]

    a = seq(5)
    assert a == seq(5)                                 # same seed, same delays
    assert a != seq(6)                                 # the seed matters
    for attempt, d in enumerate(a, start=1):
        nominal = 0.1 * 2.0 ** (attempt - 1)
        assert 0.5 * nominal <= d <= min(1.5 * nominal, 30.0)
