"""Distributed execution tests — run in a subprocess with 8 forced host
devices so the main test process keeps its single-device view."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
def test_train_step_runs_sharded_all_families():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.configs.base import get_config, ShapeSpec
        from repro.launch.steps import build_cell
        from repro.models import model as M
        from repro.training.optimizer import init_opt_state
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for arch in ["qwen2-0.5b", "qwen3-moe-235b-a22b", "recurrentgemma-2b",
                     "mamba2-130m"]:
            cfg = get_config(arch, reduced=True)
            with mesh:
                jitted, sds, rules = build_cell(cfg, ShapeSpec("t", 64, 8, "train"), mesh)
                params = M.init_params(jax.random.PRNGKey(0), cfg)
                opt = init_opt_state(params)
                batch = {"tokens": jnp.zeros((8, 64), jnp.int32)}
                if cfg.frontend:
                    batch["embeds"] = jnp.zeros((8, cfg.n_prefix, cfg.d_model), jnp.float32)
                p2, o2, m = jitted(params, opt, batch)
                assert jnp.isfinite(m["loss"]), arch
                print(arch, float(m["loss"]))
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.slow
def test_moe_sharded_matches_local():
    """Expert-parallel shard_map output == single-device oracle."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs.base import get_config, pad_for_mesh
        from repro.distributed.sharding import make_default_rules, use_rules
        from repro.models import moe as moe_mod
        cfg = pad_for_mesh(get_config("qwen3-moe-235b-a22b", reduced=True), 4)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = make_default_rules(False); rules.mesh = mesh
        p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        with mesh:
            def f(p, x):
                with use_rules(rules):
                    return moe_mod.apply_moe(p, cfg, x)
            sharded = np.asarray(jax.jit(f)(p, x))
        local = np.asarray(moe_mod.apply_moe_local(p, cfg, x))
        np.testing.assert_allclose(sharded, local, rtol=2e-4, atol=2e-4)
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.slow
def test_forward_sharded_matches_single_device():
    """Logits from the (2,4) mesh == single-device logits (same params)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config, pad_for_mesh
        from repro.distributed.sharding import make_default_rules, use_rules
        from repro.models import model as M
        for arch in ["qwen2-0.5b", "recurrentgemma-2b"]:
            cfg0 = get_config(arch, reduced=True)
            cfg = pad_for_mesh(cfg0, 4)
            params = M.init_params(jax.random.PRNGKey(0), cfg)
            tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                        cfg.vocab_size, jnp.int32)
            plain = np.asarray(M.forward(params, cfg, tokens))[:, :, :cfg.vocab_size]
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            rules = make_default_rules(False); rules.mesh = mesh
            with mesh:
                def f(p, t):
                    with use_rules(rules):
                        return M.forward(p, cfg, t)
                sharded = np.asarray(jax.jit(f)(params, tokens))[:, :, :cfg.vocab_size]
            np.testing.assert_allclose(sharded, plain, rtol=3e-2, atol=3e-2)
            print(arch, "ok")
        print("PASS")
    """)
    assert "PASS" in out


def test_shard_map_compat_resolves_both_api_spellings(monkeypatch):
    """The compat helper must work on BOTH jax API generations: new
    (``jax.shard_map``, ``check_vma``) and legacy
    (``jax.experimental.shard_map.shard_map``, ``check_rep``) — the exact
    version skew that kept three sharding tests red at the seed."""
    import jax

    from repro.distributed import sharding

    calls = {}

    def fake_new_api(fn, *, mesh, in_specs, out_specs, **kw):
        calls.update(kw)
        return lambda *a: "new-api"

    # new-API spelling: jax.shard_map present -> helper forwards check_vma
    monkeypatch.setattr(jax, "shard_map", fake_new_api, raising=False)
    fn = sharding.shard_map(lambda x: x, mesh=None, in_specs=(),
                            out_specs=(), check_vma=False)
    assert fn() == "new-api" and calls == {"check_vma": False}

    # legacy spelling: no jax.shard_map -> experimental path, check_rep.
    # jax's deprecation module raises AttributeError for absent names, so
    # deleting the injected attribute restores the legacy environment.
    monkeypatch.delattr(jax, "shard_map")
    import jax.numpy as jnp
    mesh = jax.make_mesh((1,), ("model",))
    from jax.sharding import PartitionSpec as P
    doubled = sharding.shard_map(
        lambda x: 2.0 * x, mesh=mesh, in_specs=P(None), out_specs=P(None),
        check_vma=False)(jnp.ones(4))
    assert float(doubled.sum()) == 8.0


@pytest.mark.slow
def test_elastic_mesh_reslice():
    """Pilot-level elasticity: re-slice devices into different mesh shapes."""
    out = run_with_devices("""
        import jax
        from repro.pilot.api import PilotComputeService, PilotDescription
        pcs = PilotComputeService()
        p1 = pcs.submit_pilot(PilotDescription(resource="jax://mesh",
            attrs={"mesh_shape": (2, 2), "mesh_axes": ("data", "model")}))
        p2 = pcs.submit_pilot(PilotDescription(resource="jax://mesh",
            attrs={"mesh_shape": (4,), "mesh_axes": ("data",)}))
        assert p1.mesh.shape == {"data": 2, "model": 2}
        assert p2.mesh.shape == {"data": 4}
        p1.cancel()   # elastic: release and re-slice bigger
        p3 = pcs.submit_pilot(PilotDescription(resource="jax://mesh",
            attrs={"mesh_shape": (2, 2), "mesh_axes": ("data", "model")}))
        assert p3.mesh.shape == {"data": 2, "model": 2}
        print("PASS")
    """)
    assert "PASS" in out
