"""The what-if engine: design expansion, dedupe, reducers, tournaments.

The load-bearing claims: a ``Tournament`` simulates each *unique* cell
exactly once however many coordinates and comparison questions read it,
and the summaries it files are bit-identical to serial
``run_adaptation`` on the same experiments.  The reducers
(``sign_test``, ``pareto_frontier``, win matrices) are checked against
hand-computed values.
"""

from __future__ import annotations

from types import SimpleNamespace

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.miniapp import run_adaptation, summarize_adaptation
from repro.core.streaminsight import cache_key
from repro.core.whatif import (Tournament, WhatIfDesign, pareto_frontier,
                               sign_test)

# a cheap qualifying serverless drift cell — seconds per seed, fast path
BASE = dict(
    machine="serverless", usl_sigma=0.0, usl_kappa=3.0e-4, usl_gamma=1.94,
    horizon_s=60.0, max_partitions=8, slo_lag=32, control_interval_s=2.0,
    stabilization_s=0.0, scale_down_hysteresis=0.08, headroom=0.0,
    catchup_horizon_s=8.0, refit_interval_s=5.0, max_step_up=2,
    rate=dict(kind="step", base_hz=2.0, high_hz=8.0, t_step=15.0,
              t_end=45.0))

DRIFT = dict(name="drift", drift_t_s=20.0, drift_factor=1.8,
             refit_half_life_s=25.0)


# -- expansion ----------------------------------------------------------------

def test_policy_hypergrid_expansion():
    d = WhatIfDesign(policies=[dict(name="usl", headroom=[0.0, 0.1],
                                    max_step_up=[1, 2])])
    variants = d.policy_variants()
    assert [n for n, _ in variants] == [
        "usl[headroom=0,max_step_up=1]", "usl[headroom=0,max_step_up=2]",
        "usl[headroom=0.1,max_step_up=1]", "usl[headroom=0.1,max_step_up=2]"]
    for _name, spec in variants:
        assert spec["scaling_policy"] == "usl"
        assert not any(isinstance(v, (list, tuple)) for v in spec.values())


def test_plans_cross_product_and_precedence():
    d = WhatIfDesign(base=dict(BASE, headroom=0.3),
                     scenarios=[dict(DRIFT), dict(name="calm")],
                     policies=["usl", dict(name="tuned",
                                           scaling_policy="usl",
                                           headroom=0.1)],
                     seeds=[0, 1, 2])
    plans = d.plans()
    assert len(plans) == 2 * 2 * 3
    byc = dict(plans)
    # scenario overrides land only in its cells
    assert byc[("drift", "usl", 0)].experiment.drift_t_s == 20.0
    assert byc[("calm", "usl", 0)].experiment.drift_t_s is None
    # policy overrides beat base
    assert byc[("calm", "tuned", 1)].experiment.headroom == 0.1
    assert byc[("calm", "usl", 1)].experiment.headroom == 0.3
    assert byc[("drift", "tuned", 2)].experiment.seed == 2


def test_naive_question_cells_shape():
    d = WhatIfDesign(base=dict(BASE), scenarios=[dict(DRIFT)],
                     policies=["usl", "usl_online"], seeds=list(range(8)))
    blocks = dict(d.naive_question_cells())
    assert len(blocks["violations"]) == 16
    assert len(blocks["cost"]) == 16
    assert len(blocks["drain"]) == 16
    # refit-activity reads only online-policy coords
    assert len(blocks["refit-activity"]) == 8
    assert all("usl_online" in c[1] for c in blocks["refit-activity"])
    assert len(blocks["pareto:drift"]) == 16
    assert len(blocks["win:usl>usl_online"]) == 16
    assert len(blocks["win:usl_online>usl"]) == 16
    # total naive cell-runs vs 16 unique plans: the dedupe headroom
    assert sum(len(v) for v in blocks.values()) == 104


# -- reducers -----------------------------------------------------------------

def test_sign_test_exact_values():
    assert sign_test(0, 0) == 1.0
    assert sign_test(2, 2) == 1.0
    assert sign_test(8, 0) == 2.0 / 256.0          # 0.0078125
    assert sign_test(0, 8) == sign_test(8, 0)
    assert sign_test(5, 1) == 0.21875
    assert abs(sign_test(1, 1) - 1.0) < 1e-12


def test_pareto_frontier_flags():
    #      frontier      dominated       frontier      dominated (tie+worse)
    pts = [(0.0, 10.0), (1.0, 11.0), (2.0, 1.0), (2.0, 2.0)]
    assert pareto_frontier(pts) == [True, False, True, False]
    assert pareto_frontier([]) == []
    # exact duplicates don't dominate each other
    assert pareto_frontier([(1.0, 1.0), (1.0, 1.0)]) == [True, True]


# -- tournament ---------------------------------------------------------------

def _design(seeds=(0, 1)):
    return WhatIfDesign(base=dict(BASE), scenarios=[dict(DRIFT)],
                        policies=["usl", "usl_online"], seeds=list(seeds))


def test_tournament_dedupes_shared_cells():
    d = _design()
    # the same scenario listed twice under two names: every cell is shared
    d.scenarios = [dict(DRIFT), dict(DRIFT, name="drift-again")]
    t = Tournament(d, parallel=False).run()
    assert t.total_cells == 8
    assert t.unique_cells == 4
    assert t.fast_cells == 4
    assert not t.fallbacks
    # the two coordinates share one summary object — that IS the dedupe
    assert t.summaries[("drift", "usl", 0)] is \
        t.summaries[("drift-again", "usl", 0)]


def test_cache_key_ignores_fast_flag():
    d = _design(seeds=(0,))
    fast_keys = [cache_key(p) for _c, p in d.plans()]
    d.fast = False
    slow_keys = [cache_key(p) for _c, p in d.plans()]
    assert fast_keys == slow_keys


def test_tournament_bit_identical_to_serial_run_adaptation():
    t = Tournament(_design(), parallel=False).run()
    for (sc, pol, seed), plan in _design().plans():
        ref = summarize_adaptation(run_adaptation(plan.experiment),
                                   plan=plan)
        assert t.summaries[(sc, pol, seed)].record() == ref.record(), \
            f"({sc},{pol},{seed}) diverged from serial run_adaptation"


def test_tournament_reducers_and_rows():
    t = Tournament(_design(), parallel=False).run()
    rows = t.pareto["drift"]
    assert [r["policy"] for r in rows] == ["usl", "usl_online"]
    assert all(r["seeds"] == 2 for r in rows)
    assert any(r["frontier"] for r in rows)
    w = t.wins[("usl_online", "usl")]
    assert w["wins"] + w["losses"] + w["ties"] == 2
    assert 0.0 < w["p_value"] <= 1.0
    flat = t.summary_rows()
    assert len(flat) == 4
    assert {r["scenario"] for r in flat} == {"drift"}
    assert {(r["policy_name"], r["seed"]) for r in flat} == \
        {("usl", 0), ("usl", 1), ("usl_online", 0), ("usl_online", 1)}
    assert all("slo_violations" in r and "cost_integral" in r for r in flat)


def test_tournament_records_fallbacks_per_coordinate():
    d = WhatIfDesign(
        base=dict(BASE, engine="threaded", threaded_service_s=0.02,
                  horizon_s=30.0),
        scenarios=[dict(name="thr")], policies=["usl"], seeds=[0])
    t = Tournament(d, parallel=False).run()
    assert t.fast_cells == 0
    assert set(t.fallbacks) == {("thr", "usl", 0)}
    assert "threaded" in t.fallbacks[("thr", "usl", 0)]


def test_pareto_annotates_duplicate_policy_rows():
    """Two policy names that dedupe to the same physical cells must not
    occupy two frontier slots: the later name is annotated `duplicate_of`
    its representative, inherits the representative's flag, and only the
    representative enters the frontier computation."""
    d = _design()
    d.policies = ["usl", dict(name="usl-again", scaling_policy="usl"),
                  "usl_online"]
    t = Tournament(d, parallel=False).run()
    rows = {r["policy"]: r for r in t.pareto["drift"]}
    assert t.summaries[("drift", "usl", 0)] is \
        t.summaries[("drift", "usl-again", 0)]
    assert "duplicate_of" not in rows["usl"]
    assert "duplicate_of" not in rows["usl_online"]
    assert rows["usl-again"]["duplicate_of"] == "usl"
    assert rows["usl-again"]["frontier"] == rows["usl"]["frontier"]
    originals = [r for r in t.pareto["drift"] if "duplicate_of" not in r]
    flags = pareto_frontier(
        [(r["mean_violations"], r["mean_cost"]) for r in originals])
    assert [r["frontier"] for r in originals] == flags


@given(outcomes=st.lists(st.sampled_from(["win", "loss", "tie"]),
                         min_size=1, max_size=12))
@settings(max_examples=25, deadline=None)
def test_wins_matrix_excludes_ties_from_sign_test(outcomes):
    """Property at the `_wins` call site: the reported p-value is the
    exact sign test over wins/losses only — ties are counted but never
    enter the binomial."""
    d = WhatIfDesign(base=dict(BASE), scenarios=[dict(name="s")],
                     policies=[dict(name="a", scaling_policy="usl"),
                               dict(name="b", scaling_policy="usl")],
                     seeds=list(range(len(outcomes))))
    summaries = {}
    for seed, o in enumerate(outcomes):
        ka = (0, 1.0) if o == "win" else (0, 3.0) if o == "loss" else (0, 2.0)
        summaries[("s", "a", seed)] = SimpleNamespace(
            slo_violations=ka[0], cost_integral=ka[1])
        summaries[("s", "b", seed)] = SimpleNamespace(
            slo_violations=0, cost_integral=2.0)
    w = Tournament(d, parallel=False)._wins(summaries)[("a", "b")]
    assert w["wins"] == outcomes.count("win")
    assert w["losses"] == outcomes.count("loss")
    assert w["ties"] == outcomes.count("tie")
    assert w["wins"] + w["losses"] + w["ties"] == len(outcomes)
    assert w["p_value"] == sign_test(w["wins"], w["losses"])
    # ties excluded: the p-value is invariant to how many ties occurred
    assert w["p_value"] == sign_test(outcomes.count("win"),
                                     outcomes.count("loss"))
