"""The vectorized fast replay (`sim.batched`) vs the scalar DES.

The fast path's contract is *bit identity*: ``try_fast_adaptation`` must
reproduce ``run_adaptation``'s summary exactly (every count, every float)
on qualifying serverless cells, and must decline — with a log-visible
reason — on anything it cannot replay (federation, fault plans, threaded
engine, HPC machines).  The jax lockstep stepper has the weaker documented
contract: float32 agreement within ``LOCKSTEP_RTOL`` on per-message
pipeline latency.
"""

from __future__ import annotations

import dataclasses
import logging

import numpy as np
import pytest

from repro.core.metrics import percentile_summary
from repro.core.miniapp import (AdaptationExperiment, AdaptationPlan,
                                run_adaptation, run_plan,
                                summarize_adaptation)
from repro.sim.batched import (LOCKSTEP_RTOL, lockstep_completion_times,
                               lockstep_eligibility, try_fast_adaptation)

# fig8's serverless drift-cell shape at a reduced horizon: drift bites at
# t=25, the online policy re-fits, the controller scales both ways — the
# scenario exercises cold starts, jitter draws, catch-up bursts, refit
# ticks and drain, everything the replay must reproduce event-for-event
DRIFT_CELL = dict(
    machine="serverless", usl_sigma=0.0, usl_kappa=3.0e-4, usl_gamma=1.94,
    horizon_s=90.0, max_partitions=16, slo_lag=32, control_interval_s=2.0,
    stabilization_s=0.0, scale_down_hysteresis=0.08, headroom=0.0,
    catchup_horizon_s=8.0, refit_interval_s=5.0, max_step_up=2,
    drift_t_s=25.0, drift_factor=1.8, refit_half_life_s=25.0,
    rate=dict(kind="step", base_hz=2.0, high_hz=10.0, t_step=15.0,
              t_end=70.0))

SEEDS = tuple(range(8))

SUMMARY_FIELDS = ("slo_violations", "ticks", "cost_integral", "scale_events",
                  "produced", "processed", "throughput", "latency_px",
                  "final_allocation", "drained", "drain_s", "refits",
                  "abandoned", "dup_delivered", "lost")


def _cell(scaling_policy: str, seed: int, **over) -> AdaptationExperiment:
    return AdaptationExperiment(scaling_policy=scaling_policy, seed=seed,
                                **{**DRIFT_CELL, **over})


@pytest.mark.parametrize("scaling_policy", ["usl", "usl_online"])
def test_fast_replay_bit_identical_across_seeds(scaling_policy):
    """8 seeds × both predictive policies: the fast replay's summary must
    equal the scalar DES field-for-field — including every float."""
    for seed in SEEDS:
        exp = _cell(scaling_policy, seed)
        fast, reason = try_fast_adaptation(AdaptationPlan(experiment=exp))
        assert reason is None, f"seed {seed} unexpectedly fell back: {reason}"
        assert fast.fast_path
        scalar = summarize_adaptation(run_adaptation(exp))
        for f in SUMMARY_FIELDS:
            assert getattr(fast, f) == getattr(scalar, f), \
                f"{scaling_policy} seed {seed}: {f} diverged " \
                f"({getattr(fast, f)!r} != {getattr(scalar, f)!r})"


def test_record_rows_identical_and_telemetry_excluded():
    exp = _cell("usl", 3)
    fast, _ = try_fast_adaptation(AdaptationPlan(experiment=exp))
    scalar = summarize_adaptation(run_adaptation(exp))
    assert fast.record() == scalar.record()
    assert "fast_path" not in fast.record()


@pytest.mark.parametrize("label,overrides,fragment", [
    ("federated", dict(machine="federated",
                       federation=dict(members=[dict(machine="serverless")])),
     "federated"),
    ("faulted", dict(faults=dict(stall_rate_hz=0.2, stall_s=5.0)),
     "fault plan"),
    ("threaded", dict(engine="threaded", threaded_service_s=0.02),
     "threaded"),
    ("hpc", dict(machine="wrangler", policy="update_locked"), "wrangler"),
])
def test_non_qualifying_cells_decline_with_reason(label, overrides, fragment):
    exp = _cell("usl", 0, **overrides)
    summary, reason = try_fast_adaptation(AdaptationPlan(experiment=exp))
    assert summary is None
    assert reason and fragment in reason


def test_run_plan_falls_back_and_logs(caplog):
    """`run_plan` on a non-qualifying cell must produce the scalar result,
    stamp the fallback reason, and log it at INFO on the batched logger."""
    exp = _cell("usl", 0, machine="wrangler", policy="update_locked",
                horizon_s=40.0,
                rate=dict(kind="step", base_hz=1.0, high_hz=3.0, t_step=20.0))
    with caplog.at_level(logging.INFO, logger="repro.sim.batched"):
        summary = run_plan(AdaptationPlan(experiment=exp, fast=True))
    assert not summary.fast_path
    assert summary.fallback_reason and "wrangler" in summary.fallback_reason
    assert any("fast replay fallback" in r.message for r in caplog.records)
    scalar = summarize_adaptation(run_adaptation(exp))
    assert summary.record() == scalar.record()


def test_fast_false_plan_skips_fast_path():
    exp = _cell("usl", 0)
    summary = run_plan(AdaptationPlan(experiment=exp, fast=False))
    assert not summary.fast_path and summary.fallback_reason is None
    assert summary.record() == \
        summarize_adaptation(run_adaptation(exp)).record()


# -- lockstep stepper ---------------------------------------------------------

LOCK_CELL = dict(machine="serverless", scaling_policy="static",
                 static_partitions=1, horizon_s=60.0,
                 rate=dict(kind="step", base_hz=2.0, high_hz=4.0,
                           t_step=30.0))


def test_lockstep_eligibility_rules():
    ok = AdaptationExperiment(seed=0, **LOCK_CELL)
    assert lockstep_eligibility(ok) is None
    scaled = dataclasses.replace(ok, scaling_policy="usl")
    assert "static" in lockstep_eligibility(scaled)
    wide = dataclasses.replace(ok, static_partitions=2)
    assert "partition" in lockstep_eligibility(wide)
    drifted = dataclasses.replace(ok, drift_t_s=20.0, drift_factor=2.0)
    assert "drift" in lockstep_eligibility(drifted)
    with pytest.raises(ValueError):
        lockstep_completion_times(scaled, [0])


def test_lockstep_matches_scalar_latency_within_rtol():
    """S seeds in one vmap/scan vs S scalar DES runs: per-message pipeline
    latency (finish - append) must agree on p50/p95 within the documented
    float32 tolerance, for every seed."""
    exp = AdaptationExperiment(seed=0, **LOCK_CELL)
    finishes, appends = lockstep_completion_times(exp, list(SEEDS),
                                                  with_appends=True)
    assert finishes.shape == (len(SEEDS), len(appends))
    # completion times are nondecreasing per seed (a FIFO chain)
    assert np.all(np.diff(finishes, axis=1) >= 0)
    for i, seed in enumerate(SEEDS):
        res = run_adaptation(dataclasses.replace(exp, seed=seed))
        lat = percentile_summary(list(finishes[i] - appends))
        for q in ("p50", "p95"):
            ref = res.latency_px[q]
            assert abs(lat[q] - ref) <= LOCKSTEP_RTOL * ref, \
                f"seed {seed} {q}: lockstep {lat[q]} vs scalar {ref}"


def test_lockstep_seeds_match_scalar_jitter_stream():
    """Seed s's column must consume exactly scalar seed s's normal draws:
    distinct seeds give distinct chains, equal seeds identical ones."""
    exp = AdaptationExperiment(seed=0, **LOCK_CELL)
    a = lockstep_completion_times(exp, [0, 1, 0])
    assert np.array_equal(a[0], a[2])
    assert not np.array_equal(a[0], a[1])
