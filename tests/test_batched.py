"""The vectorized fast replay (`sim.batched`) vs the scalar DES.

The fast path's contract is *bit identity*: ``try_fast_adaptation`` must
reproduce ``run_adaptation``'s summary exactly (every count, every float)
on qualifying cells — serverless pools with or without fault plans,
wrangler/stampede2 coupling chains — and must decline, with a log-visible
reason, on anything it cannot replay (federation, threaded engine).  The
jax lockstep steppers have the weaker documented contract: float32
agreement within ``LOCKSTEP_RTOL``.
"""

from __future__ import annotations

import dataclasses
import logging

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.metrics import percentile_summary
from repro.core.miniapp import (AdaptationExperiment, AdaptationPlan,
                                run_adaptation, run_plan,
                                summarize_adaptation)
from repro.sim.batched import (LOCKSTEP_RTOL, grid_lockstep_completion_times,
                               grid_lockstep_eligibility,
                               lockstep_completion_times,
                               lockstep_eligibility, try_fast_adaptation)

# fig8's serverless drift-cell shape at a reduced horizon: drift bites at
# t=25, the online policy re-fits, the controller scales both ways — the
# scenario exercises cold starts, jitter draws, catch-up bursts, refit
# ticks and drain, everything the replay must reproduce event-for-event
DRIFT_CELL = dict(
    machine="serverless", usl_sigma=0.0, usl_kappa=3.0e-4, usl_gamma=1.94,
    horizon_s=90.0, max_partitions=16, slo_lag=32, control_interval_s=2.0,
    stabilization_s=0.0, scale_down_hysteresis=0.08, headroom=0.0,
    catchup_horizon_s=8.0, refit_interval_s=5.0, max_step_up=2,
    drift_t_s=25.0, drift_factor=1.8, refit_half_life_s=25.0,
    rate=dict(kind="step", base_hz=2.0, high_hz=10.0, t_step=15.0,
              t_end=70.0))

SEEDS = tuple(range(8))

SUMMARY_FIELDS = ("slo_violations", "ticks", "cost_integral", "scale_events",
                  "produced", "processed", "throughput", "latency_px",
                  "final_allocation", "drained", "drain_s", "refits",
                  "abandoned", "dup_delivered", "lost", "faults_injected",
                  "preemptions", "fault_windows")

# fig8's fault-grid shape (crash + duplicate + preempt bursts) and its
# wrangler coupling-chain shape, both at the test horizon
FAULT_OVER = dict(max_retries=5, retry_backoff_s=0.1,
                  faults=dict(crash_rate_hz=0.03, duplicate_rate_hz=0.015,
                              preempt_times=[35.0, 60.0], preempt_count=3))
WRANGLER_OVER = dict(machine="wrangler", policy="update_locked",
                     drift_t_s=40.0, drift_factor=0.25,
                     refit_half_life_s=30.0)


def _cell(scaling_policy: str, seed: int, **over) -> AdaptationExperiment:
    return AdaptationExperiment(scaling_policy=scaling_policy, seed=seed,
                                **{**DRIFT_CELL, **over})


@pytest.mark.parametrize("scaling_policy", ["usl", "usl_online"])
def test_fast_replay_bit_identical_across_seeds(scaling_policy):
    """8 seeds × both predictive policies: the fast replay's summary must
    equal the scalar DES field-for-field — including every float."""
    for seed in SEEDS:
        exp = _cell(scaling_policy, seed)
        fast, reason = try_fast_adaptation(AdaptationPlan(experiment=exp))
        assert reason is None, f"seed {seed} unexpectedly fell back: {reason}"
        assert fast.fast_path
        scalar = summarize_adaptation(run_adaptation(exp))
        for f in SUMMARY_FIELDS:
            assert getattr(fast, f) == getattr(scalar, f), \
                f"{scaling_policy} seed {seed}: {f} diverged " \
                f"({getattr(fast, f)!r} != {getattr(scalar, f)!r})"


def test_record_rows_identical_and_telemetry_excluded():
    exp = _cell("usl", 3)
    fast, _ = try_fast_adaptation(AdaptationPlan(experiment=exp))
    scalar = summarize_adaptation(run_adaptation(exp))
    assert fast.record() == scalar.record()
    assert "fast_path" not in fast.record()


@pytest.mark.parametrize("scaling_policy", ["usl", "usl_online"])
def test_fault_cells_bit_identical_across_seeds(scaling_policy):
    """Fault-plan splicing: crash + duplicate + preempt bursts replay
    bit-identically — the full settled ledger, not just the headline counts."""
    for seed in SEEDS:
        exp = _cell(scaling_policy, seed, **FAULT_OVER)
        fast, reason = try_fast_adaptation(AdaptationPlan(experiment=exp))
        assert reason is None, f"seed {seed} unexpectedly fell back: {reason}"
        scalar = summarize_adaptation(run_adaptation(exp))
        for f in SUMMARY_FIELDS:
            assert getattr(fast, f) == getattr(scalar, f), \
                f"{scaling_policy} seed {seed}: {f} diverged " \
                f"({getattr(fast, f)!r} != {getattr(scalar, f)!r})"


@pytest.mark.parametrize("scaling_policy", ["usl", "usl_online"])
def test_wrangler_cells_bit_identical_across_seeds(scaling_policy):
    """HPC coupling chains: wrangler's shared-filesystem + model-lock phase
    chain (update_locked policy, Lustre drift) replays bit-identically."""
    for seed in SEEDS:
        exp = _cell(scaling_policy, seed, **WRANGLER_OVER)
        fast, reason = try_fast_adaptation(AdaptationPlan(experiment=exp))
        assert reason is None, f"seed {seed} unexpectedly fell back: {reason}"
        scalar = summarize_adaptation(run_adaptation(exp))
        for f in SUMMARY_FIELDS:
            assert getattr(fast, f) == getattr(scalar, f), \
                f"{scaling_policy} seed {seed}: {f} diverged " \
                f"({getattr(fast, f)!r} != {getattr(scalar, f)!r})"


def test_undrained_cell_reports_lost_bit_identically():
    """A cell cut off mid-backlog: ``lost`` must come from the settled
    ledger (appended − processed − abandoned − dup_delivered), not from a
    produced-side guess, and must match the scalar DES exactly."""
    exp = AdaptationExperiment(
        machine="serverless", scaling_policy="static", static_partitions=1,
        seed=0, horizon_s=30.0, max_partitions=4, control_interval_s=2.0,
        points=60000, backend_attrs=dict(flops_per_vcpu=2.4e7),
        faults=dict(duplicate_rate_hz=0.2),
        rate=dict(kind="constant", rate_hz=5.0))
    fast, reason = try_fast_adaptation(AdaptationPlan(experiment=exp))
    assert reason is None, f"unexpected fallback: {reason}"
    scalar = summarize_adaptation(run_adaptation(exp))
    assert not fast.drained
    assert fast.lost > 0
    assert fast.record() == scalar.record()


@pytest.mark.parametrize("label,overrides,fragment", [
    ("federated", dict(machine="federated",
                       federation=dict(members=[dict(machine="serverless")])),
     "federated"),
    ("threaded", dict(engine="threaded", threaded_service_s=0.02),
     "threaded"),
])
def test_non_qualifying_cells_decline_with_reason(label, overrides, fragment):
    exp = _cell("usl", 0, **overrides)
    summary, reason = try_fast_adaptation(AdaptationPlan(experiment=exp))
    assert summary is None
    assert reason and fragment in reason


def test_static_decline_logs_at_debug_not_info(caplog):
    """Statically ineligible cells (structural, expected) log at DEBUG so
    tournament sweeps with intentional scalar cells stay quiet at INFO."""
    exp = _cell("usl", 0, engine="threaded", threaded_service_s=0.02)
    with caplog.at_level(logging.DEBUG, logger="repro.sim.batched"):
        run_plan(AdaptationPlan(experiment=exp, fast=True))
    ineligible = [r for r in caplog.records
                  if "fast replay ineligible" in r.message]
    assert ineligible and all(r.levelno == logging.DEBUG for r in ineligible)
    assert not any("fast replay fallback" in r.message
                   for r in caplog.records)


def test_run_plan_falls_back_mid_run_and_logs(caplog):
    """A mid-run surprise (an invocation that would exceed the serverless
    walltime and take the kill/retry path) must abandon the replay, produce
    the scalar result, stamp the reason, and log at INFO."""
    exp = _cell("usl", 0, points=60000,
                backend_attrs=dict(flops_per_vcpu=6e6))
    with caplog.at_level(logging.INFO, logger="repro.sim.batched"):
        summary = run_plan(AdaptationPlan(experiment=exp, fast=True))
    assert not summary.fast_path
    assert summary.fallback_reason and "walltime" in summary.fallback_reason
    assert any("fast replay fallback" in r.message for r in caplog.records)
    scalar = summarize_adaptation(run_adaptation(exp))
    got, ref = summary.record(), scalar.record()
    assert got.keys() == ref.keys()
    for k in got:     # nothing completes here, so latency quantiles are NaN
        assert got[k] == ref[k] or (got[k] != got[k] and ref[k] != ref[k]), k


@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       crash=st.sampled_from([0.0, 0.02, 0.05]),
       dup=st.sampled_from([0.0, 0.05, 0.15]))
@settings(max_examples=8, deadline=None)
def test_fault_spliced_ledger_invariants(seed, crash, dup):
    """Property: under any spliced fault plan the settled ledger balances —
    every appended message is processed, abandoned, or a settled duplicate,
    and a drained run loses nothing."""
    exp = _cell("usl", seed, horizon_s=60.0,
                faults=dict(crash_rate_hz=crash, duplicate_rate_hz=dup),
                rate=dict(kind="step", base_hz=2.0, high_hz=6.0,
                          t_step=15.0, t_end=45.0))
    fast, reason = try_fast_adaptation(AdaptationPlan(experiment=exp))
    assert reason is None, f"unexpected fallback: {reason}"
    assert fast.processed <= fast.produced
    assert fast.lost >= 0
    if fast.drained:
        assert fast.lost == 0


def test_fast_false_plan_skips_fast_path():
    exp = _cell("usl", 0)
    summary = run_plan(AdaptationPlan(experiment=exp, fast=False))
    assert not summary.fast_path and summary.fallback_reason is None
    assert summary.record() == \
        summarize_adaptation(run_adaptation(exp)).record()


# -- lockstep stepper ---------------------------------------------------------

LOCK_CELL = dict(machine="serverless", scaling_policy="static",
                 static_partitions=1, horizon_s=60.0,
                 rate=dict(kind="step", base_hz=2.0, high_hz=4.0,
                           t_step=30.0))


def test_lockstep_eligibility_rules():
    ok = AdaptationExperiment(seed=0, **LOCK_CELL)
    assert lockstep_eligibility(ok) is None
    scaled = dataclasses.replace(ok, scaling_policy="usl")
    assert "static" in lockstep_eligibility(scaled)
    wide = dataclasses.replace(ok, static_partitions=2)
    assert "partition" in lockstep_eligibility(wide)
    drifted = dataclasses.replace(ok, drift_t_s=20.0, drift_factor=2.0)
    assert "drift" in lockstep_eligibility(drifted)
    with pytest.raises(ValueError):
        lockstep_completion_times(scaled, [0])


def test_lockstep_matches_scalar_latency_within_rtol():
    """S seeds in one vmap/scan vs S scalar DES runs: per-message pipeline
    latency (finish - append) must agree on p50/p95 within the documented
    float32 tolerance, for every seed."""
    exp = AdaptationExperiment(seed=0, **LOCK_CELL)
    finishes, appends = lockstep_completion_times(exp, list(SEEDS),
                                                  with_appends=True)
    assert finishes.shape == (len(SEEDS), len(appends))
    # completion times are nondecreasing per seed (a FIFO chain)
    assert np.all(np.diff(finishes, axis=1) >= 0)
    for i, seed in enumerate(SEEDS):
        res = run_adaptation(dataclasses.replace(exp, seed=seed))
        lat = percentile_summary(list(finishes[i] - appends))
        for q in ("p50", "p95"):
            ref = res.latency_px[q]
            assert abs(lat[q] - ref) <= LOCKSTEP_RTOL * ref, \
                f"seed {seed} {q}: lockstep {lat[q]} vs scalar {ref}"


def test_lockstep_seeds_match_scalar_jitter_stream():
    """Seed s's column must consume exactly scalar seed s's normal draws:
    distinct seeds give distinct chains, equal seeds identical ones."""
    exp = AdaptationExperiment(seed=0, **LOCK_CELL)
    a = lockstep_completion_times(exp, [0, 1, 0])
    assert np.array_equal(a[0], a[2])
    assert not np.array_equal(a[0], a[1])


# -- cross-cell grid lockstep -------------------------------------------------


def test_grid_lockstep_eligibility_rules():
    ok = _cell("usl", 0)
    assert grid_lockstep_eligibility(ok) is None
    hpc = _cell("usl", 0, **WRANGLER_OVER)
    assert "serverless" in grid_lockstep_eligibility(hpc)
    faulted = _cell("usl", 0, **FAULT_OVER)
    assert "fault plan" in grid_lockstep_eligibility(faulted)
    threaded = _cell("usl", 0, engine="threaded", threaded_service_s=0.02)
    assert "threaded" in grid_lockstep_eligibility(threaded)
    with pytest.raises(ValueError):
        grid_lockstep_completion_times(hpc, [0])
    with pytest.raises(ValueError):
        grid_lockstep_completion_times(ok, [])


def test_grid_lockstep_reference_column_within_rtol():
    """The reference seed's column in the vmapped grid must agree with the
    exact float64 replay timestamps within the documented float32 rtol."""
    exp = _cell("usl", 0)
    fins, ref = grid_lockstep_completion_times(exp, list(SEEDS),
                                               with_reference=True)
    assert fins.shape == (len(SEEDS), len(ref))
    assert len(ref) > 0
    err = np.abs(fins[0].astype(np.float64) - ref) / np.maximum(ref, 1e-9)
    assert float(err.max()) <= LOCKSTEP_RTOL


def test_grid_lockstep_seed_columns_distinct():
    exp = _cell("usl", 1)
    fins = grid_lockstep_completion_times(exp, [1, 4, 1])
    assert np.array_equal(fins[0], fins[2])
    assert not np.array_equal(fins[0], fins[1])
