"""EILC adaptation loop: rate programs, elastic backends, live control loop.

Covers the closed-loop subsystem end to end: composable time-varying rate
programs (production matches the trace integral), elastic ``scale_to``
semantics on both simulated platforms (cold starts on serverless growth,
queue/grant delay on HPC growth), broker live resharding, the state-
migration pause in the engine, control-loop convergence on a step trace,
determinism of whole adaptation cells, the online USL estimator
(properties: stationary convergence, recency weighting, saturation
gating), the drifting-cost frozen-vs-online claims, and the wall-clock
(threaded-engine) adaptation path.

Flake hygiene: every sim-path test runs purely on the virtual clock (no
wall-time assertions); the threaded-path tests (marked ``slow``) wait on
*conditions with deadlines* via ``conftest.wait_until`` — never bare
sleeps — and assert only clock-independent facts (message accounting,
policy orderings), not absolute wall timings.
"""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.autoscale import (Autoscaler, AutoscalePolicy,
                                  ControlObservation, OnlineUSLEstimator,
                                  ReactiveLagPolicy, StaticPolicy,
                                  USLPredictivePolicy)
from repro.core.metrics import MetricRegistry
from repro.core.miniapp import AdaptationExperiment, run_adaptation
from repro.core.usl import USLFit, usl_throughput
from repro.pilot.api import (ComputeUnitDescription, PilotComputeService,
                             PilotDescription, TaskProfile)
from repro.sim.des import Simulator
from repro.streaming.broker import Broker
from repro.streaming.producer import (BurstyRate, ConstantRate, DiurnalRate,
                                      RampRate, RateProgram, StepRate,
                                      SyntheticProducer,
                                      rate_program_from_spec)

# fitted serverless scenario model (pts=8000, c=1024; see fig8's
# characterization pass) — constants so these tests stay sweep-free
USL_SERVERLESS = dict(usl_sigma=0.0, usl_kappa=3.0e-4, usl_gamma=1.94)

STEP = dict(kind="step", base_hz=2.0, high_hz=12.0, t_step=40.0)


# -- rate programs -----------------------------------------------------------

def test_rate_program_exact_integrals():
    assert ConstantRate(5.0).mean_messages(10, 30) == pytest.approx(100.0)
    step = StepRate(2.0, 20.0, t_step=30.0)
    assert step.mean_messages(0, 60) == pytest.approx(2 * 30 + 20 * 30)
    ramp = RampRate(2.0, 10.0, t0=10.0, t1=50.0)
    assert ramp.mean_messages(0, 60) == pytest.approx(2 * 10 + 6 * 40 + 10 * 10)
    diurnal = DiurnalRate(10.0, 0.5, period_s=60.0)
    assert diurnal.mean_messages(0, 60) == pytest.approx(600.0)   # full period
    # every exact integral agrees with the generic numeric fallback
    for prog in (step, ramp, diurnal, BurstyRate(2.0, 25.0, 8.0, 30.0, seed=1)):
        exact = prog.mean_messages(3.0, 97.0)
        numeric = RateProgram.mean_messages(prog, 3.0, 97.0)
        assert exact == pytest.approx(numeric, rel=0.05)


def test_rate_program_composition_and_specs():
    a = rate_program_from_spec({"kind": "constant", "rate_hz": 3.0})
    b = rate_program_from_spec(STEP)
    combo = a + 2.0 * b
    assert combo.rate(50.0) == pytest.approx(3.0 + 2 * 12.0)
    assert combo.mean_messages(0, 60) == pytest.approx(
        a.mean_messages(0, 60) + 2 * b.mean_messages(0, 60))
    via_spec = rate_program_from_spec(
        {"kind": "sum", "parts": [
            {"kind": "constant", "rate_hz": 3.0},
            {"kind": "scale", "factor": 2.0, "part": dict(STEP)}]})
    for t in (0.0, 35.0, 45.0, 59.0):
        assert via_spec.rate(t) == pytest.approx(combo.rate(t))
    with pytest.raises(ValueError):
        rate_program_from_spec({"kind": "nope"})
    with pytest.raises(ValueError):
        rate_program_from_spec("not a spec")


def test_bursty_rate_deterministic_from_seed():
    a = BurstyRate(2.0, 10.0, 10.0, 25.0, seed=7)
    b = BurstyRate(2.0, 10.0, 10.0, 25.0, seed=7)
    ts = np.linspace(0.0, 300.0, 600)
    assert [a.rate(float(t)) for t in ts] == [b.rate(float(t)) for t in ts]
    assert a.mean_messages(0, 300) == pytest.approx(b.mean_messages(0, 300))


def test_open_loop_producer_matches_trace_integral():
    """Produced message count over the horizon tracks ∫ r dt."""
    sim = Simulator(seed=0)
    broker = Broker()
    broker.create_topic("t", 4)
    program = rate_program_from_spec(STEP)
    horizon = 120.0
    producer = SyntheticProducer(
        sim, broker, "t", msg_factory=lambda i: (None, i, 100),
        n_messages=10_000, run_id="r", metrics=MetricRegistry(),
        rate_program=program, horizon_s=horizon)
    producer.start()
    sim.run()
    expected = program.mean_messages(0.0, horizon)
    assert producer.sent == pytest.approx(expected, rel=0.05)
    assert producer.done and producer.appended == producer.sent


# -- elastic scale_to ---------------------------------------------------------

def _pilot(resource, partitions):
    pcs = PilotComputeService(seed=0)
    pilot = pcs.submit_pilot(PilotDescription(
        resource=resource, partitions=partitions, concurrency=partitions))
    return pcs, pilot


def test_serverless_scale_up_pays_cold_starts():
    pcs, pilot = _pilot("serverless://aws-sim", 2)
    backend = pilot.backend
    prof = TaskProfile(flops=1e9)
    cus = [pilot.submit_compute_unit(ComputeUnitDescription(profile=prof))
           for _ in range(2)]
    pilot.wait_all(None)
    assert all(cu.attrs["cold"] for cu in cus)          # first round: all cold
    assert backend.scale_to(pilot, 4) == 4
    cus2 = [pilot.submit_compute_unit(ComputeUnitDescription(profile=prof))
            for _ in range(4)]
    pilot.wait_all(None)
    colds = sorted((cu.attrs["container"], cu.attrs["cold"]) for cu in cus2)
    # surviving containers are warm; the two grown ones pay a cold start
    assert colds == [(0, False), (1, False), (2, True), (3, True)]
    # cold containers really are slower on first use
    cold_rt = [cu.runtime for cu in cus2 if cu.attrs["cold"]]
    warm_rt = [cu.runtime for cu in cus2 if not cu.attrs["cold"]]
    assert min(cold_rt) > max(warm_rt)


def test_serverless_scale_down_retires_containers():
    pcs, pilot = _pilot("serverless://aws-sim", 4)
    backend = pilot.backend
    prof = TaskProfile(flops=1e8)
    for _ in range(4):
        pilot.submit_compute_unit(ComputeUnitDescription(profile=prof))
    pilot.wait_all(None)
    backend.scale_to(pilot, 1)
    assert backend.allocation(pilot) == 1
    cus = [pilot.submit_compute_unit(ComputeUnitDescription(profile=prof))
           for _ in range(3)]
    pilot.wait_all(None)
    assert len({cu.attrs["container"] for cu in cus}) == 1   # pool of one


def test_hpc_scale_up_waits_out_grant_delay():
    pcs, pilot = _pilot("hpc://wrangler-sim", 1)
    backend = pilot.backend
    prof = TaskProfile(flops=1e9)
    pilot.submit_compute_unit(ComputeUnitDescription(profile=prof)).wait(None)
    t0 = backend.sim.now
    backend.scale_to(pilot, 2)
    cu = pilot.submit_compute_unit(
        ComputeUnitDescription(profile=prof, partition=1))
    cu.wait(None)
    grant = backend._pilots[pilot.uid]["cfg"]["grant_delay_s"]
    assert cu.start_ts >= t0 + grant       # queued until the scheduler grant


def test_hpc_scale_down_requeues_orphans():
    pcs, pilot = _pilot("hpc://wrangler-sim", 4)
    backend = pilot.backend
    prof = TaskProfile(flops=2e9)
    cus = [pilot.submit_compute_unit(
        ComputeUnitDescription(profile=prof, partition=p)) for p in range(8)]
    backend.scale_to(pilot, 2)
    pilot.wait_all(None)
    assert all(cu.state.name == "DONE" for cu in cus)    # nothing lost
    assert backend.allocation(pilot) == 2


# -- broker resharding + engine migration ------------------------------------

def test_broker_repartition_grow_and_seal():
    broker = Broker()
    broker.create_topic("t", 2)
    broker.repartition("t", 4)
    assert broker.num_partitions("t") == 4
    assert broker.total_partitions("t") == 4
    # shrink seals: routing covers only the active prefix, logs survive
    broker.append("t", "x", ts=0.0, partition=3)
    broker.repartition("t", 2)
    assert broker.num_partitions("t") == 2
    assert broker.total_partitions("t") == 4
    assert {broker.partition_for("t", None) for _ in range(8)} == {0, 1}
    assert broker.end_offset("t", 3) == 1      # sealed log still addressable
    assert broker.appended_total("t") == 1
    with pytest.raises(ValueError):
        broker.repartition("t", 0)


def test_engine_migration_pause_recorded_and_drains():
    exp = AdaptationExperiment(
        machine="serverless", scaling_policy="usl", rate=dict(STEP),
        horizon_s=60.0, max_partitions=16, migration_s_per_delta=0.2,
        seed=0, **USL_SERVERLESS)
    metrics = MetricRegistry()
    res = run_adaptation(exp, metrics)
    assert res.drained and res.scale_events > 0
    migrations = metrics.events(res.run_id, kind="migrate")
    assert migrations, "scale events must charge a migration cost event"
    assert all(ev.attrs["duration"] > 0 for ev in migrations)
    assert res.processed == res.produced


# -- control loop -------------------------------------------------------------

def _usl_policy(initial=2, max_partitions=16, **kw):
    fit = USLFit(sigma=0.0, kappa=3e-4, gamma=1.94, r2=1.0, rmse=0.0, n_obs=0)
    scaler = Autoscaler(fit, AutoscalePolicy(max_partitions=max_partitions),
                        current=initial)
    return USLPredictivePolicy(scaler, **kw)


def test_control_loop_converges_on_step_trace():
    """After the step the loop settles inside the hysteresis band and never
    provisions past the USL peak."""
    exp = AdaptationExperiment(
        machine="serverless", scaling_policy="usl", rate=dict(STEP),
        horizon_s=120.0, max_partitions=16, seed=0, **USL_SERVERLESS)
    res = run_adaptation(exp)
    alloc = np.array(res.alloc_trace)
    lag = np.array(res.lag_trace)
    fit = USLFit(sigma=exp.usl_sigma, kappa=exp.usl_kappa,
                 gamma=exp.usl_gamma, r2=1.0, rmse=0.0, n_obs=0)
    peak = Autoscaler(fit, AutoscalePolicy(
        max_partitions=exp.max_partitions)).usable_peak_n()
    assert alloc[:, 1].max() <= peak                    # never past the peak
    assert res.drained and res.slo_violations == 0
    # settled: allocation constant over the last quarter of the horizon,
    # and above the pre-step allocation
    tail = alloc[alloc[:, 0] > 0.75 * exp.horizon_s][:, 1]
    pre = alloc[alloc[:, 0] < 35.0][:, 1]
    assert len(set(tail)) == 1
    assert tail[0] > pre.max()
    assert lag[-1, 1] <= exp.slo_lag


def test_predictive_policy_holds_capacity_under_backlog():
    policy = _usl_policy(initial=8, downscale_lag=16, stabilization_s=0.0)
    hold = policy.decide(ControlObservation(
        t=10.0, lag=200, arrival_rate=1.0, completion_rate=5.0, allocation=8))
    assert hold == 8          # demand says shrink, backlog says hold
    down = policy.decide(ControlObservation(
        t=12.0, lag=0, arrival_rate=1.0, completion_rate=5.0, allocation=8))
    assert down < 8           # backlog cleared: hysteresis allows release


def test_reactive_and_static_policies():
    reactive = ReactiveLagPolicy(hi_lag=32, lo_lag=4, max_partitions=8)
    up = reactive.decide(ControlObservation(
        t=0.0, lag=50, arrival_rate=5.0, completion_rate=2.0, allocation=3))
    down = reactive.decide(ControlObservation(
        t=2.0, lag=0, arrival_rate=1.0, completion_rate=1.0, allocation=3))
    hold = reactive.decide(ControlObservation(
        t=4.0, lag=16, arrival_rate=1.0, completion_rate=1.0, allocation=3))
    assert (up, down, hold) == (4, 2, 3)
    static = StaticPolicy(5)
    assert static.decide(ControlObservation(
        t=0.0, lag=999, arrival_rate=50.0, completion_rate=0.0,
        allocation=5)) == 5


def test_adaptation_cell_bit_identical_under_fixed_seed():
    exp = AdaptationExperiment(
        machine="wrangler", scaling_policy="reactive",
        rate=dict(kind="burst", base_hz=1.0, burst_hz=6.0, burst_len_s=10.0,
                  mean_gap_s=25.0, seed=3),
        horizon_s=90.0, max_partitions=8, policy="update_locked", seed=1)
    a = run_adaptation(exp)
    b = run_adaptation(exp)
    assert a.alloc_trace == b.alloc_trace
    assert a.lag_trace == b.lag_trace
    assert a.cost_integral == b.cost_integral
    assert a.slo_violations == b.slo_violations
    assert a.des_events == b.des_events


def test_adaptation_requires_usl_params_for_predictive():
    with pytest.raises(ValueError, match="usl"):
        run_adaptation(AdaptationExperiment(
            machine="serverless", scaling_policy="usl", horizon_s=10.0))


def test_adaptation_cells_cache_and_cost_estimate(tmp_path):
    from repro.core.streaminsight import ResultCache, estimated_cost
    exp = AdaptationExperiment(
        machine="serverless", scaling_policy="static", rate=dict(STEP),
        horizon_s=30.0, max_partitions=4, seed=0)
    assert estimated_cost([exp]) > 0
    res = run_adaptation(exp)
    cache = ResultCache(tmp_path)
    cache.put(exp, res)
    roundtrip = cache.get(exp)
    assert roundtrip is not None
    assert dataclasses.asdict(roundtrip) == dataclasses.asdict(res)


# -- online USL estimator (property tests via the hypothesis shim) ------------

def _fit(sigma, kappa, gamma):
    return USLFit(sigma=sigma, kappa=kappa, gamma=gamma, r2=1.0, rmse=0.0,
                  n_obs=0)


@given(sigma=st.floats(0.0, 0.3), kappa=st.floats(1e-5, 5e-3),
       gamma=st.floats(0.5, 20.0))
@settings(max_examples=8, deadline=None)
def test_online_estimator_converges_on_stationary_data(sigma, kappa, gamma):
    """Fed noise-free saturated observations from a stationary USL system,
    a re-fit reproduces the generating model across the sampled N range —
    even when warm-started from (and prior-anchored to) a wrong fit."""
    prior = _fit(0.0, 1e-4, gamma * 1.7)      # deliberately wrong prior
    est = OnlineUSLEstimator(prior, window=64, half_life_s=500.0)
    levels = [1, 2, 4, 8]
    for i in range(64):
        n = levels[i % len(levels)]
        rate = float(usl_throughput(n, sigma, kappa, gamma))
        assert est.observe(t=2.0 * i, n=n, rate=rate, lag=1000)
    fit = est.refit(now=128.0)
    for n in levels:
        truth = float(usl_throughput(n, sigma, kappa, gamma))
        assert fit.predict(n) == pytest.approx(truth, rel=0.05)


@given(half_life=st.floats(5.0, 120.0))
@settings(max_examples=8, deadline=None)
def test_online_estimator_recency_weights_strictly_favor_recent(half_life):
    """Weights are strictly increasing in observation time, so every
    post-drift sample outweighs every pre-drift one."""
    est = OnlineUSLEstimator(_fit(0.0, 1e-4, 2.0), window=64,
                             half_life_s=half_life)
    for i in range(20):                       # pre-drift
        est.observe(t=float(i), n=2, rate=4.0, lag=100)
    for i in range(20, 30):                   # post-drift
        est.observe(t=float(i) + 10.0, n=2, rate=2.0, lag=100)
    w = est.observation_weights(now=50.0)
    assert np.all(np.diff(w) > 0)             # strictly increasing in t
    assert w[:20].max() < w[20:].min()        # post-drift strictly favored


def test_online_estimator_refit_tracks_drift():
    """After a drift, the recency-weighted re-fit follows the post-drift
    system, not the (more numerous) pre-drift observations."""
    pre, post = _fit(0.0, 1e-4, 4.0), _fit(0.0, 1e-4, 1.5)
    est = OnlineUSLEstimator(pre, window=128, half_life_s=20.0,
                             prior_weight=0.25)
    for i in range(40):                       # 80 s of pre-drift evidence
        n = [2, 4, 8][i % 3]
        est.observe(t=2.0 * i, n=n, rate=float(post.predict(n)) * (4.0 / 1.5),
                    lag=1000)
    for i in range(40, 55):                   # 30 s of post-drift evidence
        n = [2, 4, 8][i % 3]
        est.observe(t=2.0 * i, n=n, rate=float(post.predict(n)), lag=1000)
    fit = est.refit(now=110.0)
    for n in (2, 4, 8):
        err_post = abs(fit.predict(n) - post.predict(n))
        err_pre = abs(fit.predict(n) - pre.predict(n))
        assert err_post < err_pre


def test_online_estimator_rejects_unsaturated_windows():
    """A window where the consumer merely kept up (no real queue) proves
    only a lower bound: it is recorded iff it beats the model's prediction,
    and plain keep-up windows are rejected — admitting them drags gamma
    down in a self-confirming spiral."""
    est = OnlineUSLEstimator(_fit(0.0, 1e-4, 2.0), busy_lag=4,
                             saturation_factor=2.0)
    # saturated: lag well above in-flight ceiling -> equality sample
    assert est.observe(t=0.0, n=4, rate=5.0, lag=20)
    # keeping up at rate below prediction -> rejected
    assert not est.observe(t=2.0, n=4, rate=5.0, lag=2)
    # keeping up ABOVE prediction (capacity drifted up) -> informative bound
    assert est.observe(t=4.0, n=4, rate=9.5, lag=2)
    # idle / nonsense windows
    assert not est.observe(t=6.0, n=4, rate=0.0, lag=50)
    assert not est.observe(t=8.0, n=0, rate=3.0, lag=50)
    assert est.rejected == 3
    assert len(est) == 2


def test_online_estimator_refit_interval_and_min_obs():
    est = OnlineUSLEstimator(_fit(0.0, 1e-4, 2.0), refit_interval_s=10.0,
                             min_obs=4)
    for i in range(3):
        est.observe(t=float(i), n=2, rate=4.0, lag=50)
    assert est.maybe_refit(now=3.0) is None          # too few observations
    est.observe(t=3.0, n=4, rate=7.0, lag=50)
    assert est.maybe_refit(now=4.0) is not None      # first refit: no wait
    assert est.maybe_refit(now=5.0) is None          # interval not elapsed
    assert est.maybe_refit(now=15.0) is not None
    assert est.refits == 2


# -- drifting-cost workload: frozen vs online-refit ---------------------------

DRIFT_KNOBS = dict(
    machine="serverless", max_partitions=16, seed=0, horizon_s=150.0,
    drift_t_s=40.0, drift_factor=1.8,
    rate=dict(kind="step", base_hz=2.0, high_hz=12.0, t_step=25.0,
              t_end=120.0),
    stabilization_s=0.0, scale_down_hysteresis=0.08, headroom=0.0,
    catchup_horizon_s=8.0, refit_interval_s=5.0, refit_half_life_s=25.0,
    max_step_up=2, **USL_SERVERLESS)


def test_drifting_cost_online_beats_frozen():
    """Mid-run per-message cost shift: the frozen fit under-provisions into
    a perpetually violating saturated equilibrium; the online re-fit
    eliminates the violations at cost parity (see fig8 for why strictly
    lower cost additionally requires USL curvature, i.e. the HPC
    platform)."""
    frozen = run_adaptation(AdaptationExperiment(
        scaling_policy="usl", **DRIFT_KNOBS))
    metrics = MetricRegistry()
    online = run_adaptation(AdaptationExperiment(
        scaling_policy="usl_online", **DRIFT_KNOBS), metrics)
    assert online.refits > 0
    assert online.slo_violations < frozen.slo_violations
    assert online.slo_violations <= 2 and frozen.slo_violations > 20
    assert online.cost_integral <= frozen.cost_integral * 1.08
    assert online.drained and frozen.drained
    # every refit is traced with the updated coefficients
    refit_events = metrics.events(online.run_id, kind="refit")
    assert len(refit_events) == online.refits
    # the re-fitted gamma tracked the drift (true post-drift ~ 1.94/1.8)
    final_gamma = refit_events[-1].attrs["gamma"]
    assert final_gamma < 1.7


def test_drift_requires_usl_params_for_online_policy():
    with pytest.raises(ValueError, match="usl"):
        run_adaptation(AdaptationExperiment(
            machine="serverless", scaling_policy="usl_online", horizon_s=10.0))


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="engine"):
        run_adaptation(AdaptationExperiment(
            machine="serverless", scaling_policy="static", engine="quantum",
            horizon_s=5.0))


# -- wall-clock (threaded-engine) adaptation path -----------------------------

THREADED_KNOBS = dict(
    machine="serverless",              # platform knob unused by the local path
    engine="threaded", horizon_s=10.0, control_interval_s=0.5, slo_lag=24,
    initial_partitions=1, max_partitions=6, static_partitions=6,
    catchup_horizon_s=2.0, stabilization_s=3.0, seed=0,
    usl_sigma=0.02, usl_kappa=1e-4, usl_gamma=20.0)   # ~50 ms/message


@pytest.mark.slow
def test_threaded_adaptation_runs_and_accounts():
    """The wall-clock path end to end: real ticker thread, elastic local
    backend, open-loop wall producer — every produced message accounted,
    traces populated, the loop actually scaled."""
    exp = AdaptationExperiment(
        scaling_policy="usl",
        rate=dict(kind="step", base_hz=5.0, high_hz=40.0, t_step=4.0),
        **THREADED_KNOBS)
    res = run_adaptation(exp)
    assert res.drained
    assert res.processed == res.produced > 0
    assert res.ticks >= 10
    assert res.scale_events >= 1 and res.final_allocation > 1
    assert len(res.alloc_trace) == res.ticks
    # traces are run-relative wall seconds inside the (padded) horizon
    ts = [t for t, _v in res.alloc_trace]
    assert 0.0 < ts[0] < 2.0 and ts[-1] < exp.horizon_s + 5.0


@pytest.mark.slow
def test_threaded_adaptation_reproduces_sim_policy_ranking():
    """The fig8 policy ranking — predictive beats reactive on violations,
    and is cheaper than static-peak — holds on the wall clock, with the
    sim twin of the same scenario agreeing (clock-independent orderings,
    no absolute wall timings)."""
    rate = dict(kind="step", base_hz=5.0, high_hz=40.0, t_step=4.0)

    def run_policies(engine_kind):
        out = {}
        for sp in ("usl", "reactive", "static"):
            knobs = dict(THREADED_KNOBS, engine=engine_kind)
            if engine_kind == "sim":
                # the sim twin realizes the same ~50 ms/message service
                # via the KMeans cost model instead of a sleep
                knobs.update(points=1000, centroids=280)
            out[sp] = run_adaptation(AdaptationExperiment(
                scaling_policy=sp, rate=dict(rate), **knobs))
        return out

    for engine_kind in ("sim", "threaded"):
        res = run_policies(engine_kind)
        for r in res.values():
            assert r.drained, f"{engine_kind} run failed to drain"
        assert res["usl"].slo_violations <= res["reactive"].slo_violations, \
            f"{engine_kind}: predictive worse than reactive"
        assert res["usl"].cost_integral < res["static"].cost_integral, \
            f"{engine_kind}: predictive not cheaper than static-peak"
