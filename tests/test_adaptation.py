"""EILC adaptation loop: rate programs, elastic backends, live control loop.

Covers the closed-loop subsystem end to end: composable time-varying rate
programs (production matches the trace integral), elastic ``scale_to``
semantics on both simulated platforms (cold starts on serverless growth,
queue/grant delay on HPC growth), broker live resharding, the state-
migration pause in the engine, control-loop convergence on a step trace,
and determinism of whole adaptation cells.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.autoscale import (Autoscaler, AutoscalePolicy,
                                  ControlObservation, ReactiveLagPolicy,
                                  StaticPolicy, USLPredictivePolicy)
from repro.core.metrics import MetricRegistry
from repro.core.miniapp import AdaptationExperiment, run_adaptation
from repro.core.usl import USLFit
from repro.pilot.api import (ComputeUnitDescription, PilotComputeService,
                             PilotDescription, TaskProfile)
from repro.sim.des import Simulator
from repro.streaming.broker import Broker
from repro.streaming.producer import (BurstyRate, ConstantRate, DiurnalRate,
                                      RampRate, RateProgram, StepRate,
                                      SyntheticProducer,
                                      rate_program_from_spec)

# fitted serverless scenario model (pts=8000, c=1024; see fig8's
# characterization pass) — constants so these tests stay sweep-free
USL_SERVERLESS = dict(usl_sigma=0.0, usl_kappa=3.0e-4, usl_gamma=1.94)

STEP = dict(kind="step", base_hz=2.0, high_hz=12.0, t_step=40.0)


# -- rate programs -----------------------------------------------------------

def test_rate_program_exact_integrals():
    assert ConstantRate(5.0).mean_messages(10, 30) == pytest.approx(100.0)
    step = StepRate(2.0, 20.0, t_step=30.0)
    assert step.mean_messages(0, 60) == pytest.approx(2 * 30 + 20 * 30)
    ramp = RampRate(2.0, 10.0, t0=10.0, t1=50.0)
    assert ramp.mean_messages(0, 60) == pytest.approx(2 * 10 + 6 * 40 + 10 * 10)
    diurnal = DiurnalRate(10.0, 0.5, period_s=60.0)
    assert diurnal.mean_messages(0, 60) == pytest.approx(600.0)   # full period
    # every exact integral agrees with the generic numeric fallback
    for prog in (step, ramp, diurnal, BurstyRate(2.0, 25.0, 8.0, 30.0, seed=1)):
        exact = prog.mean_messages(3.0, 97.0)
        numeric = RateProgram.mean_messages(prog, 3.0, 97.0)
        assert exact == pytest.approx(numeric, rel=0.05)


def test_rate_program_composition_and_specs():
    a = rate_program_from_spec({"kind": "constant", "rate_hz": 3.0})
    b = rate_program_from_spec(STEP)
    combo = a + 2.0 * b
    assert combo.rate(50.0) == pytest.approx(3.0 + 2 * 12.0)
    assert combo.mean_messages(0, 60) == pytest.approx(
        a.mean_messages(0, 60) + 2 * b.mean_messages(0, 60))
    via_spec = rate_program_from_spec(
        {"kind": "sum", "parts": [
            {"kind": "constant", "rate_hz": 3.0},
            {"kind": "scale", "factor": 2.0, "part": dict(STEP)}]})
    for t in (0.0, 35.0, 45.0, 59.0):
        assert via_spec.rate(t) == pytest.approx(combo.rate(t))
    with pytest.raises(ValueError):
        rate_program_from_spec({"kind": "nope"})
    with pytest.raises(ValueError):
        rate_program_from_spec("not a spec")


def test_bursty_rate_deterministic_from_seed():
    a = BurstyRate(2.0, 10.0, 10.0, 25.0, seed=7)
    b = BurstyRate(2.0, 10.0, 10.0, 25.0, seed=7)
    ts = np.linspace(0.0, 300.0, 600)
    assert [a.rate(float(t)) for t in ts] == [b.rate(float(t)) for t in ts]
    assert a.mean_messages(0, 300) == pytest.approx(b.mean_messages(0, 300))


def test_open_loop_producer_matches_trace_integral():
    """Produced message count over the horizon tracks ∫ r dt."""
    sim = Simulator(seed=0)
    broker = Broker()
    broker.create_topic("t", 4)
    program = rate_program_from_spec(STEP)
    horizon = 120.0
    producer = SyntheticProducer(
        sim, broker, "t", msg_factory=lambda i: (None, i, 100),
        n_messages=10_000, run_id="r", metrics=MetricRegistry(),
        rate_program=program, horizon_s=horizon)
    producer.start()
    sim.run()
    expected = program.mean_messages(0.0, horizon)
    assert producer.sent == pytest.approx(expected, rel=0.05)
    assert producer.done and producer.appended == producer.sent


# -- elastic scale_to ---------------------------------------------------------

def _pilot(resource, partitions):
    pcs = PilotComputeService(seed=0)
    pilot = pcs.submit_pilot(PilotDescription(
        resource=resource, partitions=partitions, concurrency=partitions))
    return pcs, pilot


def test_serverless_scale_up_pays_cold_starts():
    pcs, pilot = _pilot("serverless://aws-sim", 2)
    backend = pilot.backend
    prof = TaskProfile(flops=1e9)
    cus = [pilot.submit_compute_unit(ComputeUnitDescription(profile=prof))
           for _ in range(2)]
    pilot.wait_all(None)
    assert all(cu.attrs["cold"] for cu in cus)          # first round: all cold
    assert backend.scale_to(pilot, 4) == 4
    cus2 = [pilot.submit_compute_unit(ComputeUnitDescription(profile=prof))
            for _ in range(4)]
    pilot.wait_all(None)
    colds = sorted((cu.attrs["container"], cu.attrs["cold"]) for cu in cus2)
    # surviving containers are warm; the two grown ones pay a cold start
    assert colds == [(0, False), (1, False), (2, True), (3, True)]
    # cold containers really are slower on first use
    cold_rt = [cu.runtime for cu in cus2 if cu.attrs["cold"]]
    warm_rt = [cu.runtime for cu in cus2 if not cu.attrs["cold"]]
    assert min(cold_rt) > max(warm_rt)


def test_serverless_scale_down_retires_containers():
    pcs, pilot = _pilot("serverless://aws-sim", 4)
    backend = pilot.backend
    prof = TaskProfile(flops=1e8)
    for _ in range(4):
        pilot.submit_compute_unit(ComputeUnitDescription(profile=prof))
    pilot.wait_all(None)
    backend.scale_to(pilot, 1)
    assert backend.allocation(pilot) == 1
    cus = [pilot.submit_compute_unit(ComputeUnitDescription(profile=prof))
           for _ in range(3)]
    pilot.wait_all(None)
    assert len({cu.attrs["container"] for cu in cus}) == 1   # pool of one


def test_hpc_scale_up_waits_out_grant_delay():
    pcs, pilot = _pilot("hpc://wrangler-sim", 1)
    backend = pilot.backend
    prof = TaskProfile(flops=1e9)
    pilot.submit_compute_unit(ComputeUnitDescription(profile=prof)).wait(None)
    t0 = backend.sim.now
    backend.scale_to(pilot, 2)
    cu = pilot.submit_compute_unit(
        ComputeUnitDescription(profile=prof, partition=1))
    cu.wait(None)
    grant = backend._pilots[pilot.uid]["cfg"]["grant_delay_s"]
    assert cu.start_ts >= t0 + grant       # queued until the scheduler grant


def test_hpc_scale_down_requeues_orphans():
    pcs, pilot = _pilot("hpc://wrangler-sim", 4)
    backend = pilot.backend
    prof = TaskProfile(flops=2e9)
    cus = [pilot.submit_compute_unit(
        ComputeUnitDescription(profile=prof, partition=p)) for p in range(8)]
    backend.scale_to(pilot, 2)
    pilot.wait_all(None)
    assert all(cu.state.name == "DONE" for cu in cus)    # nothing lost
    assert backend.allocation(pilot) == 2


# -- broker resharding + engine migration ------------------------------------

def test_broker_repartition_grow_and_seal():
    broker = Broker()
    broker.create_topic("t", 2)
    broker.repartition("t", 4)
    assert broker.num_partitions("t") == 4
    assert broker.total_partitions("t") == 4
    # shrink seals: routing covers only the active prefix, logs survive
    broker.append("t", "x", ts=0.0, partition=3)
    broker.repartition("t", 2)
    assert broker.num_partitions("t") == 2
    assert broker.total_partitions("t") == 4
    assert {broker.partition_for("t", None) for _ in range(8)} == {0, 1}
    assert broker.end_offset("t", 3) == 1      # sealed log still addressable
    assert broker.appended_total("t") == 1
    with pytest.raises(ValueError):
        broker.repartition("t", 0)


def test_engine_migration_pause_recorded_and_drains():
    exp = AdaptationExperiment(
        machine="serverless", scaling_policy="usl", rate=dict(STEP),
        horizon_s=60.0, max_partitions=16, migration_s_per_delta=0.2,
        seed=0, **USL_SERVERLESS)
    metrics = MetricRegistry()
    res = run_adaptation(exp, metrics)
    assert res.drained and res.scale_events > 0
    migrations = metrics.events(res.run_id, kind="migrate")
    assert migrations, "scale events must charge a migration cost event"
    assert all(ev.attrs["duration"] > 0 for ev in migrations)
    assert res.processed == res.produced


# -- control loop -------------------------------------------------------------

def _usl_policy(initial=2, max_partitions=16, **kw):
    fit = USLFit(sigma=0.0, kappa=3e-4, gamma=1.94, r2=1.0, rmse=0.0, n_obs=0)
    scaler = Autoscaler(fit, AutoscalePolicy(max_partitions=max_partitions),
                        current=initial)
    return USLPredictivePolicy(scaler, **kw)


def test_control_loop_converges_on_step_trace():
    """After the step the loop settles inside the hysteresis band and never
    provisions past the USL peak."""
    exp = AdaptationExperiment(
        machine="serverless", scaling_policy="usl", rate=dict(STEP),
        horizon_s=120.0, max_partitions=16, seed=0, **USL_SERVERLESS)
    res = run_adaptation(exp)
    alloc = np.array(res.alloc_trace)
    lag = np.array(res.lag_trace)
    fit = USLFit(sigma=exp.usl_sigma, kappa=exp.usl_kappa,
                 gamma=exp.usl_gamma, r2=1.0, rmse=0.0, n_obs=0)
    peak = Autoscaler(fit, AutoscalePolicy(
        max_partitions=exp.max_partitions)).usable_peak_n()
    assert alloc[:, 1].max() <= peak                    # never past the peak
    assert res.drained and res.slo_violations == 0
    # settled: allocation constant over the last quarter of the horizon,
    # and above the pre-step allocation
    tail = alloc[alloc[:, 0] > 0.75 * exp.horizon_s][:, 1]
    pre = alloc[alloc[:, 0] < 35.0][:, 1]
    assert len(set(tail)) == 1
    assert tail[0] > pre.max()
    assert lag[-1, 1] <= exp.slo_lag


def test_predictive_policy_holds_capacity_under_backlog():
    policy = _usl_policy(initial=8, downscale_lag=16, stabilization_s=0.0)
    hold = policy.decide(ControlObservation(
        t=10.0, lag=200, arrival_rate=1.0, completion_rate=5.0, allocation=8))
    assert hold == 8          # demand says shrink, backlog says hold
    down = policy.decide(ControlObservation(
        t=12.0, lag=0, arrival_rate=1.0, completion_rate=5.0, allocation=8))
    assert down < 8           # backlog cleared: hysteresis allows release


def test_reactive_and_static_policies():
    reactive = ReactiveLagPolicy(hi_lag=32, lo_lag=4, max_partitions=8)
    up = reactive.decide(ControlObservation(
        t=0.0, lag=50, arrival_rate=5.0, completion_rate=2.0, allocation=3))
    down = reactive.decide(ControlObservation(
        t=2.0, lag=0, arrival_rate=1.0, completion_rate=1.0, allocation=3))
    hold = reactive.decide(ControlObservation(
        t=4.0, lag=16, arrival_rate=1.0, completion_rate=1.0, allocation=3))
    assert (up, down, hold) == (4, 2, 3)
    static = StaticPolicy(5)
    assert static.decide(ControlObservation(
        t=0.0, lag=999, arrival_rate=50.0, completion_rate=0.0,
        allocation=5)) == 5


def test_adaptation_cell_bit_identical_under_fixed_seed():
    exp = AdaptationExperiment(
        machine="wrangler", scaling_policy="reactive",
        rate=dict(kind="burst", base_hz=1.0, burst_hz=6.0, burst_len_s=10.0,
                  mean_gap_s=25.0, seed=3),
        horizon_s=90.0, max_partitions=8, policy="update_locked", seed=1)
    a = run_adaptation(exp)
    b = run_adaptation(exp)
    assert a.alloc_trace == b.alloc_trace
    assert a.lag_trace == b.lag_trace
    assert a.cost_integral == b.cost_integral
    assert a.slo_violations == b.slo_violations
    assert a.des_events == b.des_events


def test_adaptation_requires_usl_params_for_predictive():
    with pytest.raises(ValueError, match="usl"):
        run_adaptation(AdaptationExperiment(
            machine="serverless", scaling_policy="usl", horizon_s=10.0))


def test_adaptation_cells_cache_and_cost_estimate(tmp_path):
    from repro.core.streaminsight import ResultCache, estimated_cost
    exp = AdaptationExperiment(
        machine="serverless", scaling_policy="static", rate=dict(STEP),
        horizon_s=30.0, max_partitions=4, seed=0)
    assert estimated_cost([exp]) > 0
    res = run_adaptation(exp)
    cache = ResultCache(tmp_path)
    cache.put(exp, res)
    roundtrip = cache.get(exp)
    assert roundtrip is not None
    assert dataclasses.asdict(roundtrip) == dataclasses.asdict(res)
