"""Suite-wide setup: import paths, the hypothesis fallback shim, and the
wall-clock deadline helper.

Runs before any test module is collected, so the ``from hypothesis import
...`` lines in the property-test modules resolve even where hypothesis is
not installable (the shim in ``_hypothesis_compat`` is registered in
``sys.modules`` only when the real package is absent).

``wait_until`` is the suite's condition-polling primitive for wall-clock
(threaded-engine) tests: every wait is a *condition with a deadline*,
never a bare ``time.sleep`` — sleep-based waits are exactly the flake
source the adaptation suite audit removed before the threaded path landed.
"""

import os
import sys
import time
from pathlib import Path

_HERE = Path(__file__).resolve().parent
for p in (str(_HERE), str(_HERE.parent / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import _hypothesis_compat  # noqa: E402

_hypothesis_compat.install()

# Opt-in lock-order instrumentation: when SIMLINT_LOCKWATCH_OUT names an
# output path, every threading.Lock/RLock/Condition created by this test
# session is tracked and the acquisition graph is dumped there at session
# end (see repro.analysis.lockwatch).  Installed this early so locks built
# at module-import time (engine/broker singletons in fixtures) are caught.
from repro.analysis import lockwatch as _lockwatch  # noqa: E402

_LOCKWATCH = _lockwatch.install_from_env()


def pytest_sessionfinish(session, exitstatus):
    if _LOCKWATCH is not None:
        _LOCKWATCH.uninstall()
        _LOCKWATCH.dump(os.environ[_lockwatch.ENV_OUT])


def wait_until(condition, timeout: float = 10.0, interval: float = 0.005,
               message: str = "condition") -> None:
    """Poll ``condition()`` until it is truthy or ``timeout`` wall seconds
    elapse (then ``TimeoutError``).  Import from conftest in wall-clock
    tests instead of sleeping a fixed interval and hoping."""
    deadline = time.perf_counter() + timeout
    while time.perf_counter() < deadline:
        if condition():
            return
        time.sleep(interval)
    raise TimeoutError(f"{message} not met within {timeout}s")
