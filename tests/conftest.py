"""Suite-wide setup: import paths and the hypothesis fallback shim.

Runs before any test module is collected, so the ``from hypothesis import
...`` lines in the property-test modules resolve even where hypothesis is
not installable (the shim in ``_hypothesis_compat`` is registered in
``sys.modules`` only when the real package is absent).
"""

import sys
from pathlib import Path

_HERE = Path(__file__).resolve().parent
for p in (str(_HERE), str(_HERE.parent / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import _hypothesis_compat  # noqa: E402

_hypothesis_compat.install()
