"""Tests for the discrete-event simulation core."""

import numpy as np
import pytest

from repro.sim.des import SharedResource, SimLock, Simulator


def test_event_ordering_and_clock():
    sim = Simulator()
    order = []
    sim.schedule(2.0, lambda: order.append(("b", sim.now)))
    sim.schedule(1.0, lambda: order.append(("a", sim.now)))
    sim.schedule(3.0, lambda: order.append(("c", sim.now)))
    sim.run()
    assert order == [("a", 1.0), ("b", 2.0), ("c", 3.0)]
    assert sim.now == 3.0


def test_same_time_fifo():
    sim = Simulator()
    order = []
    for i in range(5):
        sim.schedule(1.0, lambda i=i: order.append(i))
    sim.run()
    assert order == [0, 1, 2, 3, 4]


def test_cancel():
    sim = Simulator()
    fired = []
    ev = sim.schedule(1.0, lambda: fired.append(1))
    sim.cancel(ev)
    sim.run()
    assert fired == []


def test_run_until_predicate():
    sim = Simulator()
    hits = []

    def tick():
        hits.append(sim.now)
        sim.schedule(1.0, tick)

    sim.schedule(0.0, tick)
    sim.run_until(predicate=lambda: len(hits) >= 5)
    assert len(hits) == 5


def test_determinism_same_seed():
    def run(seed):
        sim = Simulator(seed=seed)
        vals = []
        for _ in range(10):
            vals.append(sim.lognormal_jitter(1.0, 0.2))
        return vals

    assert run(7) == run(7)
    assert run(7) != run(8)


def test_lognormal_jitter_mean_preserving():
    sim = Simulator(seed=0)
    xs = np.array([sim.lognormal_jitter(2.0, 0.1) for _ in range(4000)])
    assert xs.mean() == pytest.approx(2.0, rel=0.02)
    assert sim.lognormal_jitter(3.0, 0.0) == 3.0


# -- SharedResource: processor sharing ------------------------------------

def test_shared_resource_single_flow():
    sim = Simulator()
    res = SharedResource(sim, capacity=100.0)
    done = []
    res.submit(200.0, lambda: done.append(sim.now))
    sim.run()
    assert done == [pytest.approx(2.0)]


def test_shared_resource_two_equal_flows_halve_bandwidth():
    sim = Simulator()
    res = SharedResource(sim, capacity=100.0)
    done = {}
    res.submit(100.0, lambda: done.setdefault("a", sim.now))
    res.submit(100.0, lambda: done.setdefault("b", sim.now))
    sim.run()
    # both share 100 units/s -> each runs at 50 -> done at t=2
    assert done["a"] == pytest.approx(2.0)
    assert done["b"] == pytest.approx(2.0)


def test_shared_resource_late_arrival():
    sim = Simulator()
    res = SharedResource(sim, capacity=100.0)
    done = {}
    res.submit(100.0, lambda: done.setdefault("a", sim.now))
    sim.schedule(0.5, lambda: res.submit(25.0, lambda: done.setdefault("b", sim.now)))
    sim.run()
    # a: 50 units alone (0.5s); shares rate 50 while b active (25 units in
    # [0.5, 1.0]); back to full rate after b leaves -> 25 units in 0.25s
    # b: arrives 0.5, rate 50 -> 25 units in 0.5s -> t=1.0
    assert done["b"] == pytest.approx(1.0)
    assert done["a"] == pytest.approx(1.25)


def test_shared_resource_conservation():
    """Total completion time of k equal concurrent flows = k * single."""
    for k in [1, 2, 4, 8]:
        sim = Simulator()
        res = SharedResource(sim, capacity=10.0)
        done = []
        for _ in range(k):
            res.submit(10.0, lambda: done.append(sim.now))
        sim.run()
        assert max(done) == pytest.approx(k * 1.0)


# -- SimLock ----------------------------------------------------------------

def test_lock_serializes_fifo():
    sim = Simulator()
    lock = SimLock(sim)
    order = []

    def worker(name, hold):
        def acquired():
            order.append((name, sim.now))
            sim.schedule(hold, lock.release)
        lock.acquire(acquired)

    worker("a", 1.0)
    worker("b", 1.0)
    worker("c", 1.0)
    sim.run()
    assert [n for n, _ in order] == ["a", "b", "c"]
    assert [t for _, t in order] == [pytest.approx(0.0), pytest.approx(1.0),
                                     pytest.approx(2.0)]
