"""Attention-path equivalences: chunked==full, windows, GQA, padding."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ModelConfig
from repro.models import attention as A

KEY = jax.random.PRNGKey(0)


def make_cfg(heads=4, kv=2, dh=16, window=0, chunk=16, heads_p=0, kv_p=0):
    return ModelConfig(
        name="t", family="dense", n_layers=1, d_model=heads * dh,
        n_heads=heads, n_kv_heads=kv, d_ff=4 * heads * dh, vocab_size=64,
        d_head=dh, local_window=window, attn_chunk=chunk,
        n_heads_padded=heads_p, n_kv_heads_padded=kv_p)


def run_both(cfg, B=2, S=64, window=0, seed=0):
    p = A.attention_init(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(seed + 1),
                                (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    full, _ = A.attend_full(p, cfg, x, pos, window)
    chunked, _ = A.attend_chunked(p, cfg, x, pos, window)
    return np.asarray(full), np.asarray(chunked)


@given(heads=st.sampled_from([2, 4, 8]), kv_ratio=st.sampled_from([1, 2]),
       s_chunks=st.integers(2, 4), seed=st.integers(0, 100))
@settings(max_examples=12, deadline=None)
def test_chunked_matches_full_causal(heads, kv_ratio, s_chunks, seed):
    kv = max(1, heads // kv_ratio)
    cfg = make_cfg(heads=heads, kv=kv, chunk=16)
    full, chunked = run_both(cfg, S=16 * s_chunks, seed=seed)
    np.testing.assert_allclose(chunked, full, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [8, 16, 24])
def test_chunked_matches_full_local_window(window):
    cfg = make_cfg(window=window, chunk=16)
    full, chunked = run_both(cfg, S=64, window=window)
    np.testing.assert_allclose(chunked, full, rtol=2e-4, atol=2e-4)


def test_padded_heads_are_inert():
    """A padded config must produce exactly the same outputs as unpadded
    with the same real-head weights."""
    cfg = make_cfg(heads=3, kv=1, dh=8)
    cfgp = dataclasses.replace(cfg, n_heads_padded=4, n_kv_heads_padded=1)
    p = A.attention_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    pp = A.attention_init(jax.random.PRNGKey(7), cfgp, jnp.float32)
    # copy real-head weights into the padded layout
    pp = dict(pp)
    pp["wq"] = pp["wq"].at[:, :3].set(p["wq"])
    pp["wo"] = pp["wo"].at[:3].set(p["wo"])
    pp["wk"], pp["wv"] = p["wk"], p["wv"]
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(32, dtype=jnp.int32)[None], (2, 32))
    out, _ = A.attend_full(p, cfg, x, pos)
    outp, _ = A.attend_full(pp, cfgp, x, pos)
    np.testing.assert_allclose(np.asarray(outp), np.asarray(out),
                               rtol=1e-5, atol=1e-5)


def test_ring_cache_decode_matches_full_cache():
    """Local-attention ring buffer gives identical logits to a full cache
    once the window is the only visible history."""
    W = 8
    cfg = make_cfg(window=W, chunk=64)
    p = A.attention_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 1, 24
    xs = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    # reference: full-seq local attention
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    ref, _ = A.attend_full(p, cfg, xs, pos, window=W)
    # decode one token at a time through the ring cache
    cache = A.init_cache(cfg, B, W, window=W, dtype=jnp.float32)
    outs = []
    for t in range(S):
        o, cache = A.decode_step(p, cfg, xs[:, t:t + 1], cache,
                                 jnp.int32(t), window=W)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_prefill_into_ring_cache_alignment():
    """Prefill longer than the window, then decode: must equal pure decode."""
    W = 8
    cfg = make_cfg(window=W, chunk=8)
    p = A.attention_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 1, 16
    xs = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (B, S + 1, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cache = A.init_cache(cfg, B, W, window=W, dtype=jnp.float32)
    _, cache_pf = A.prefill_into_cache(p, cfg, xs[:, :S], pos, cache, window=W)
    out_pf, _ = A.decode_step(p, cfg, xs[:, S:S + 1], cache_pf,
                              jnp.int32(S), window=W)
    # oracle: token-by-token decode
    cache2 = A.init_cache(cfg, B, W, window=W, dtype=jnp.float32)
    for t in range(S):
        _, cache2 = A.decode_step(p, cfg, xs[:, t:t + 1], cache2,
                                  jnp.int32(t), window=W)
    out_ref, _ = A.decode_step(p, cfg, xs[:, S:S + 1], cache2,
                               jnp.int32(S), window=W)
    np.testing.assert_allclose(np.asarray(out_pf), np.asarray(out_ref),
                               rtol=2e-4, atol=2e-4)
